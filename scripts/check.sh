#!/usr/bin/env bash
# Repo health check: tier-1 tests + fast-mode smoke benches.
#
# Usage: scripts/check.sh
#   - runs the full pytest suite (tier-1 verify from ROADMAP.md)
#   - runs the sweep-engine + table benches in REPRO_BENCH_FAST mode
#     (shrunk n_runs/n_steps; completes in well under a minute)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo
echo "== smoke benches (REPRO_BENCH_FAST=1) =="
REPRO_BENCH_FAST=1 python -m benchmarks.run sweep table1 table2 cliff zoo

echo
echo "check.sh: OK"
