#!/usr/bin/env bash
# Repo health check: hygiene + tier-1 tests + fast-mode smoke benches.
#
# Usage: scripts/check.sh
#   - fails if cache dirs (__pycache__ / .pytest_cache / .hypothesis)
#     ever become git-tracked
#   - runs the full pytest suite (tier-1 verify from ROADMAP.md)
#   - runs the sweep-engine + table + coherence-service + content-plane
#     benches in REPRO_BENCH_FAST mode (shrunk n_runs/n_steps/rounds;
#     completes in well under a minute)
#   - runs the metrics-conformance smoke (launcher --verify-metrics:
#     live telemetry counters bit-compared against a trace replay)
#   - replays the committed BENCH baselines through the perf gate
#     (plumbing check; CI's bench-gate job does the fresh-run gating)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repo hygiene =="
# covers every directory, benchmarks/ and tests/ included; the second
# alternative catches stray compiled files OUTSIDE a __pycache__ dir,
# which the directory pattern alone misses
tracked_caches=$(git ls-files | grep -E '(^|/)(__pycache__|\.pytest_cache|\.hypothesis|\.mypy_cache|\.ruff_cache|[^/]*\.egg-info)(/|$)|\.py[co]$' || true)
if [ -n "$tracked_caches" ]; then
  echo "ERROR: cache artifacts are git-tracked (extend .gitignore and \`git rm -r --cached\` them):"
  echo "$tracked_caches"
  exit 1
fi
echo "no tracked cache artifacts"

echo
echo "== tier-1 tests =="
python -m pytest -x -q

echo
echo "== smoke benches (REPRO_BENCH_FAST=1) =="
REPRO_BENCH_FAST=1 python -m benchmarks.run sweep table1 table2 cliff zoo service content

echo
echo "== metrics conformance smoke (--verify-metrics) =="
python -m repro.launch.service --family uniform --clients 6 --artifacts 3 \
  --artifact-tokens 32 --rounds 6 --verify-metrics

echo
echo "== bench gate (baseline replay) =="
python scripts/bench_gate.py --replay-baseline

echo
echo "check.sh: OK"
