#!/usr/bin/env python
"""Perf gate: pin the BENCH_*.json trajectory so banked speed can't
silently erode.

Compares a *fresh* set of benchmark payloads against the committed
baselines (``BENCH_sweep.json`` / ``BENCH_workloads.json`` /
``BENCH_service.json`` at the repo root) with explicit tolerances, and
exits non-zero on drift.  CI's ``bench-gate`` job runs it two ways:

  1. ``--run-benches`` (with ``REPRO_BENCH_FAST=1``): run the sweep +
     zoo benches and gate the fresh payloads.  Savings are
     deterministic simulation statistics, so they are gated even
     cross-mode (fast grid vs committed full grid) with a widened
     tolerance; raw throughput is machine-dependent, so cross-machine
     it is gated via the self-normalized fused-vs-seed-loop speedup
     plus an absolute sanity floor.
  2. ``--replay-baseline --inject-throughput-regression 0.05``: replay
     the committed baseline as the "fresh" payload with a synthetic 5%
     throughput regression injected - the gate MUST go red (the CI
     step asserts the non-zero exit), proving the comparator can see a
     regression before one ever lands.

Checks (see ``--help`` for every tolerance knob):

  structural   compilations == baseline (one-compilation property),
               zero steady-state recompiles, devices >= 1, family set
               unchanged
  savings      per-family |fresh - baseline| <= tol
               (same-mode: --savings-tol; cross-mode: --savings-tol-x)
  throughput   same-mode / replay: fused & zoo sims/s and speedup
               within --throughput-rel-tol of baseline;
               cross-mode: speedup >= --min-speedup and sims/s >=
               --throughput-floor-frac x baseline
  service      family set + >= 32 concurrent clients + acceptance
               (savings >= floor, oracle replay bit-exact); per-family
               savings within --service-savings-tol(-x); p50/p99
               within --latency-factor x baseline (cross-mode OR'd
               with the --latency-ceiling-ms pathology bound);
               decisions/s >= --throughput-floor-frac x baseline
  sharded      every family's K-shard ledger bit-identical to its
               plain-broker run; sharded savings within
               --shard-savings-tol of the plain rows; decision-plane
               capacity monotone in K (--shard-capacity-tol per step,
               gated within the fresh payload - capacity is
               machine-dependent)
  telemetry    observability plane must be near-free: telemetry-on
               p50/p99 <= telemetry-off x (1 + --telemetry-overhead-tol)
               + --telemetry-abs-eps-ms.  Both variants come from the
               SAME fresh payload (same machine, same warmed program),
               so this is gated same- and cross-mode alike; the
               --inject-telemetry-overhead self-test proves the
               comparator can see a hot-path slowdown.  A fast-mode
               payload's rows are single-repeat noise, so there the
               bound degrades to the --latency-factor pathology
               ceiling.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
RESULTS_DIR = REPO_ROOT / "benchmarks" / "results"
BASELINES = {
    "sweep": REPO_ROOT / "BENCH_sweep.json",
    "zoo": REPO_ROOT / "BENCH_workloads.json",
    "service": REPO_ROOT / "BENCH_service.json",
    "content": REPO_ROOT / "BENCH_content.json",
}
#: benchmarks/results payload file per baseline key
RESULT_FILES = {
    "sweep": "sweep_engine.json",
    "zoo": "workload_zoo.json",
    "service": "service_bench.json",
    "content": "content_plane.json",
}
#: fresh fast-mode payloads written for CI artifact upload
FRESH_OUT = {
    "sweep": RESULTS_DIR / "BENCH_sweep.fresh.json",
    "zoo": RESULTS_DIR / "BENCH_workloads.fresh.json",
    "service": RESULTS_DIR / "BENCH_service.fresh.json",
    "content": RESULTS_DIR / "BENCH_content.fresh.json",
}


class Gate:
    """Accumulates PASS/FAIL lines; red if any check failed."""

    def __init__(self) -> None:
        self.failures: list[str] = []

    def check(self, ok: bool, label: str, detail: str) -> None:
        print(f"  {'PASS' if ok else 'FAIL'}  {label}: {detail}")
        if not ok:
            self.failures.append(f"{label}: {detail}")


def _load(path: pathlib.Path) -> dict:
    if not path.exists():
        sys.exit(f"bench_gate: missing baseline {path} - run "
                 f"`python -m benchmarks.run sweep zoo service` (full "
                 f"mode) and commit the BENCH_*.json files")
    return json.loads(path.read_text())


def _run_benches() -> dict:
    """Run the BENCH-producing modules in-process and collect their
    payloads (the ``extra`` blob of benchmarks/results/<module>.json is
    exactly the BENCH payload)."""
    sys.path.insert(0, str(REPO_ROOT))
    sys.path.insert(0, str(REPO_ROOT / "src"))
    from benchmarks import (content_plane, service_bench,  # noqa: E402
                            sweep_engine, workload_zoo)
    sweep_engine.run()
    workload_zoo.run()
    service_bench.run()
    content_plane.run()
    fresh = {
        name: json.loads(
            (RESULTS_DIR / fname).read_text())["extra"]
        for name, fname in RESULT_FILES.items()
    }
    for name, payload in fresh.items():
        FRESH_OUT[name].parent.mkdir(parents=True, exist_ok=True)
        FRESH_OUT[name].write_text(json.dumps(payload, indent=2,
                                              default=float))
    return fresh


def _inject(fresh: dict, throughput_pct: float, savings_drift: float,
            latency_factor: float, bytes_pct: float = 0.0,
            shard_pct: float = 0.0, telemetry_pct: float = 0.0) -> dict:
    """Apply a synthetic regression to the fresh payloads (gate
    self-test: the comparator must flag it)."""
    f = json.loads(json.dumps(fresh, default=float))  # deep copy
    scale = 1.0 - throughput_pct
    f["sweep"]["fused"]["sims_per_s"] *= scale
    f["sweep"]["speedup_steady"] *= scale
    f["zoo"]["sims_per_s"] *= scale
    for fam in f["zoo"]["families"]:
        fam["savings_mean"] -= savings_drift
    for fam in f["service"]["families"]:
        fam["throughput_dps"] *= scale
        fam["savings_vs_broadcast"] -= savings_drift
        fam["p50_ms"] *= latency_factor
        fam["p99_ms"] *= latency_factor
    f["service"]["acceptance"]["savings"] -= savings_drift
    if bytes_pct:
        # bloat every cell's shipped delta bytes and recompute the
        # derived columns - savings floors and (for a large enough
        # bloat) strict dominance must go red
        for cell in f["content"]["cells"]:
            cell["delta_bytes"] *= (1.0 + bytes_pct)
            cell["savings_vs_full"] = 1.0 - (cell["delta_bytes"]
                                             / cell["full_bytes"])
            cell["savings_vs_broadcast"] = 1.0 - (
                cell["delta_bytes"] / cell["broadcast_bytes"])
            cell["strictly_dominates"] = bool(
                cell["delta_bytes"] < cell["full_bytes"]
                < cell["broadcast_bytes"])
        for fam, agg in f["content"]["per_family"].items():
            cells = [c for c in f["content"]["cells"]
                     if c["family"] == fam]
            agg["min_savings_vs_full"] = min(c["savings_vs_full"]
                                             for c in cells)
            agg["min_savings_vs_broadcast"] = min(
                c["savings_vs_broadcast"] for c in cells)
    if shard_pct:
        # collapse the capacity curve: each K step LOSES shard_pct vs
        # its predecessor, so monotonicity must go red for any
        # shard_pct > --shard-capacity-tol
        scaling = sorted(f["service"]["sharded"]["uniform_scaling"],
                         key=lambda r: r["shards"])
        for prev, cur in zip(scaling, scaling[1:]):
            cur["capacity_dps"] = prev["capacity_dps"] * (1.0 - shard_pct)
    if telemetry_pct:
        # slow down the telemetry-on hot path: the on-row latencies
        # bloat by (1+PCT) and the derived overhead fractions are
        # recomputed - red for any PCT > --telemetry-overhead-tol
        tel = f["service"].get("telemetry_overhead", {})
        rows = {bool(r["telemetry"]): r for r in tel.get("rows", ())}
        if True in rows and False in rows:
            on, off = rows[True], rows[False]
            on["p50_ms"] *= (1.0 + telemetry_pct)
            on["p99_ms"] *= (1.0 + telemetry_pct)
            on["throughput_dps"] /= (1.0 + telemetry_pct)
            tel["p50_overhead_frac"] = (on["p50_ms"] / off["p50_ms"]) - 1.0
            tel["p99_overhead_frac"] = (on["p99_ms"] / off["p99_ms"]) - 1.0
            tel["throughput_overhead_frac"] = (
                1.0 - on["throughput_dps"] / off["throughput_dps"])
    return f


def run_gate(fresh: dict, base: dict, args) -> int:
    gate = Gate()
    same_mode = all(fresh[k].get("fast_mode") == base[k].get("fast_mode")
                    for k in RESULT_FILES)
    savings_tol = args.savings_tol if same_mode else args.savings_tol_x
    mode = "same-grid" if same_mode else "cross-mode (fast vs full)"
    print(f"bench-gate: comparing {mode}")

    # --- structural: the one-compilation property is load-bearing
    print("[structural]")
    fs, bs = fresh["sweep"], base["sweep"]
    fz, bz = fresh["zoo"], base["zoo"]
    gate.check(fs["fused"]["compilations"] <= bs["fused"]["compilations"],
               "sweep.compilations",
               f"{fs['fused']['compilations']} <= "
               f"{bs['fused']['compilations']}")
    gate.check(fs["fused"]["recompilations_steady"] == 0,
               "sweep.recompilations_steady",
               str(fs["fused"]["recompilations_steady"]))
    gate.check(fz["compilations"] <= bz["compilations"],
               "zoo.compilations",
               f"{fz['compilations']} <= {bz['compilations']}")
    gate.check(fz["recompilations_steady"] == 0,
               "zoo.recompilations_steady",
               str(fz["recompilations_steady"]))
    gate.check(fs.get("devices", 0) >= 1 and fz.get("devices", 0) >= 1,
               "devices column",
               f"sweep={fs.get('devices')} zoo={fz.get('devices')}")
    f_fams = [f["family"] for f in fz["families"]]
    b_fams = [f["family"] for f in bz["families"]]
    gate.check(f_fams == b_fams, "zoo.families",
               f"{f_fams} vs {b_fams}")

    # --- savings: deterministic seeded statistics
    print(f"[savings]  tol ±{savings_tol:.3f} abs")
    b_by_fam = {f["family"]: f for f in bz["families"]}
    for fam in fz["families"]:
        b = b_by_fam.get(fam["family"])
        if b is None:
            continue
        delta = fam["savings_mean"] - b["savings_mean"]
        gate.check(abs(delta) <= savings_tol,
                   f"zoo.savings[{fam['family']}]",
                   f"{fam['savings_mean']:.4f} vs {b['savings_mean']:.4f}"
                   f" (delta {delta:+.4f})")

    # --- throughput
    if same_mode:
        rel = args.throughput_rel_tol
        print(f"[throughput]  rel tol -{rel:.0%} vs baseline")
        for label, got, want in (
                ("sweep.fused.sims_per_s", fs["fused"]["sims_per_s"],
                 bs["fused"]["sims_per_s"]),
                ("sweep.speedup_steady", fs["speedup_steady"],
                 bs["speedup_steady"]),
                ("zoo.sims_per_s", fz["sims_per_s"], bz["sims_per_s"])):
            gate.check(got >= want * (1.0 - rel), label,
                       f"{got:.1f} >= {want * (1.0 - rel):.1f} "
                       f"(baseline {want:.1f})")
    else:
        print(f"[throughput]  cross-machine: speedup >= "
              f"{args.min_speedup:.1f}x, sims/s floor "
              f"{args.throughput_floor_frac:.0%} of baseline")
        gate.check(fs["speedup_steady"] >= args.min_speedup,
                   "sweep.speedup_steady",
                   f"{fs['speedup_steady']:.1f}x >= "
                   f"{args.min_speedup:.1f}x (fused grid must beat the "
                   f"per-cell seed loop)")
        for label, got, want in (
                ("sweep.fused.sims_per_s", fs["fused"]["sims_per_s"],
                 bs["fused"]["sims_per_s"]),
                ("zoo.sims_per_s", fz["sims_per_s"], bz["sims_per_s"])):
            floor = want * args.throughput_floor_frac
            gate.check(got >= floor, label,
                       f"{got:.1f} >= {floor:.1f} (sanity floor)")

    # --- coherence service: latency + savings + acceptance
    fsv, bsv = fresh["service"], base["service"]
    svc_tol = (args.service_savings_tol if same_mode
               else args.service_savings_tol_x)
    print(f"[service]  savings tol ±{svc_tol:.3f} abs, "
          f"p50/p99 <= {args.latency_factor:.1f}x baseline"
          + ("" if same_mode
             else f" or {args.latency_ceiling_ms:.0f}ms ceiling"))
    f_sfams = [f["family"] for f in fsv["families"]]
    b_sfams = [f["family"] for f in bsv["families"]]
    gate.check(f_sfams == b_sfams, "service.families",
               f"{f_sfams} vs {b_sfams}")
    gate.check(fsv["grid"]["n_clients"] >= 32, "service.n_clients",
               f"{fsv['grid']['n_clients']} >= 32 concurrent clients")
    accept = fsv.get("acceptance", {})
    gate.check(bool(accept.get("oracle_replay", {}).get("bit_exact")),
               "service.oracle_replay",
               "captured trace replays bit-exactly through "
               f"{accept.get('oracle_replay', {}).get('implementations')}")
    gate.check(accept.get("savings", 0.0) >= accept.get(
                   "min_savings", 0.80),
               "service.acceptance.savings",
               f"{accept.get('savings', 0.0):.4f} >= "
               f"{accept.get('min_savings', 0.80):.2f} "
               f"(uniform V=0.10, lazy)")
    b_by_sfam = {f["family"]: f for f in bsv["families"]}
    for fam in fsv["families"]:
        b = b_by_sfam.get(fam["family"])
        if b is None:
            continue
        delta = fam["savings_vs_broadcast"] - b["savings_vs_broadcast"]
        gate.check(abs(delta) <= svc_tol,
                   f"service.savings[{fam['family']}]",
                   f"{fam['savings_vs_broadcast']:.4f} vs "
                   f"{b['savings_vs_broadcast']:.4f} "
                   f"(delta {delta:+.4f})")
        for pct in ("p50_ms", "p99_ms"):
            ceiling = b[pct] * args.latency_factor
            if not same_mode:
                # cross-machine: CI latency is noisy - pathology bound
                ceiling = max(ceiling, args.latency_ceiling_ms)
            gate.check(fam[pct] <= ceiling,
                       f"service.{pct}[{fam['family']}]",
                       f"{fam[pct]:.3f} <= {ceiling:.3f} "
                       f"(baseline {b[pct]:.3f})")
        floor = b["throughput_dps"] * args.throughput_floor_frac
        gate.check(fam["throughput_dps"] >= floor,
                   f"service.throughput[{fam['family']}]",
                   f"{fam['throughput_dps']:.1f} >= {floor:.1f} "
                   f"(sanity floor)")

    # --- sharded authority plane: capacity scaling + ledger identity.
    # All checks are internal to the fresh payload (capacity is
    # machine-dependent, so there is no cross-baseline comparison;
    # savings ARE compared against the fresh plain rows, which the
    # blocks above already pinned to the baseline).
    sh = fsv.get("sharded", {})
    print(f"[sharded]  capacity monotone in K (tol "
          f"-{args.shard_capacity_tol:.0%} per step), savings within "
          f"±{args.shard_savings_tol:.3f} of the plain rows")
    gate.check(bool(sh), "sharded.section",
               "BENCH_service.json carries the sharded block")
    if sh:
        gate.check(all(f.get("bit_identical_to_plain")
                       for f in sh["families"]),
                   "sharded.bit_identity",
                   f"all {len(sh['families'])} families bit-identical "
                   f"to the plain broker at K={max(sh['ks'])}")
        f_by_fam = {f["family"]: f for f in fsv["families"]}
        for fam in sh["families"]:
            plain = f_by_fam.get(fam["family"])
            if plain is None:
                continue
            delta = (fam["savings_vs_broadcast"]
                     - plain["savings_vs_broadcast"])
            gate.check(abs(delta) <= args.shard_savings_tol,
                       f"sharded.savings[{fam['family']}]",
                       f"{fam['savings_vs_broadcast']:.4f} vs plain "
                       f"{plain['savings_vs_broadcast']:.4f} "
                       f"(delta {delta:+.4f})")
        scaling = sorted(sh["uniform_scaling"],
                         key=lambda r: r["shards"])
        for prev, cur in zip(scaling, scaling[1:]):
            floor = prev["capacity_dps"] * (1.0 - args.shard_capacity_tol)
            gate.check(cur["capacity_dps"] >= floor,
                       f"sharded.capacity[K={cur['shards']}]",
                       f"{cur['capacity_dps']:.1f} >= {floor:.1f} "
                       f"(K={prev['shards']}: "
                       f"{prev['capacity_dps']:.1f})")
        if len(scaling) >= 2:
            gate.check(scaling[-1]["capacity_dps"]
                       > scaling[0]["capacity_dps"],
                       "sharded.capacity_scales",
                       f"K={scaling[-1]['shards']} "
                       f"{scaling[-1]['capacity_dps']:.1f} > "
                       f"K={scaling[0]['shards']} "
                       f"{scaling[0]['capacity_dps']:.1f}")

    # --- telemetry overhead: both variants live in the SAME fresh
    # payload (same machine, same warmed decide program), so absolute
    # latency noise cancels and the bound holds cross-mode too.  The
    # tight bound needs the full grid's repeated/medianed rows; a
    # fast-mode payload measures ONE repeat of a tiny grid (pure
    # scheduler noise), so there the check degrades to the same
    # pathology factor the absolute latency check uses.
    fast_rows = bool(fsv.get("fast_mode"))
    if fast_rows:
        tel_factor = args.latency_factor
        eps = args.telemetry_abs_eps_ms
        print(f"[telemetry]  on <= off x {tel_factor:.1f} "
              f"(fast-mode payload: single-repeat rows, pathology "
              f"bound only) + {eps:.3f}ms abs")
    else:
        tel_factor = 1.0 + args.telemetry_overhead_tol
        eps = args.telemetry_abs_eps_ms
        print(f"[telemetry]  on <= off x (1 + "
              f"{args.telemetry_overhead_tol:.0%}) + {eps:.3f}ms abs")
    tel = fsv.get("telemetry_overhead", {})
    t_rows = {bool(r["telemetry"]): r for r in tel.get("rows", ())}
    gate.check(True in t_rows and False in t_rows,
               "telemetry.section",
               "fresh payload carries telemetry-off AND telemetry-on "
               f"rows (got modes {sorted(t_rows)})")
    if True in t_rows and False in t_rows:
        on, off = t_rows[True], t_rows[False]
        for pct in ("p50_ms", "p99_ms"):
            ceiling = off[pct] * tel_factor + eps
            gate.check(on[pct] <= ceiling,
                       f"telemetry.{pct}",
                       f"on {on[pct]:.3f} <= {ceiling:.3f} "
                       f"(off {off[pct]:.3f})")
        gate.check(on.get("savings_vs_broadcast")
                   == off.get("savings_vs_broadcast"),
                   "telemetry.savings_invariant",
                   "token accounting identical with the obs plane on "
                   f"({on.get('savings_vs_broadcast'):.4f})")

    # --- content plane: delta coherence byte savings
    fc, bc = fresh["content"], base["content"]
    print(f"[content]  delta < full < broadcast on every cell; "
          + (f"min per-family savings-vs-full tol ±{savings_tol:.3f} "
             f"abs" if same_mode else
             "cross-mode: per-family min savings must stay positive "
             "(fast grids have 4x fewer steps, so re-fetch counts - "
             "and with them the savings magnitude - are not "
             "comparable across modes)"))
    f_cfams = fc["grid"]["families"]
    b_cfams = bc["grid"]["families"]
    gate.check(f_cfams == b_cfams, "content.families",
               f"{f_cfams} vs {b_cfams}")
    bad = [c for c in fc["cells"]
           if not (c["delta_bytes"] < c["full_bytes"]
                   < c["broadcast_bytes"])]
    gate.check(not bad, "content.strict_dominance",
               f"{len(bad)} of {len(fc['cells'])} cells violate "
               f"delta < full < broadcast"
               + (f" (e.g. {bad[0]['family']} chunk="
                  f"{bad[0]['chunk_tokens']} loc="
                  f"{bad[0]['write_locality']} V={bad[0]['volatility']})"
                  if bad else ""))
    gate.check(all(c["compilations"] == 1
                   and c["recompilations_steady"] == 0
                   for c in fc["compilations"]),
               "content.compilations",
               f"one compilation per chunk size, zero steady retraces: "
               f"{fc['compilations']}")
    for fam, b_agg in bc["per_family"].items():
        f_agg = fc["per_family"].get(fam)
        if f_agg is None:
            continue
        if same_mode:
            delta = (f_agg["min_savings_vs_full"]
                     - b_agg["min_savings_vs_full"])
            gate.check(delta >= -args.savings_tol,
                       f"content.savings_vs_full[{fam}]",
                       f"{f_agg['min_savings_vs_full']:.4f} vs "
                       f"baseline {b_agg['min_savings_vs_full']:.4f} "
                       f"(delta {delta:+.4f})")
        else:
            gate.check(f_agg["min_savings_vs_full"] > 0,
                       f"content.savings_vs_full[{fam}]",
                       f"{f_agg['min_savings_vs_full']:.4f} > 0 "
                       f"(cross-mode positivity floor; baseline full "
                       f"grid: {b_agg['min_savings_vs_full']:.4f})")

    if gate.failures:
        print(f"\nbench-gate: RED - {len(gate.failures)} check(s) "
              f"failed:")
        for f in gate.failures:
            print(f"  - {f}")
        return 1
    print("\nbench-gate: GREEN")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--run-benches", action="store_true",
                     help="run the sweep+zoo benches now (honors "
                     "REPRO_BENCH_FAST) and gate the fresh payloads")
    src.add_argument("--replay-baseline", action="store_true",
                     help="use the committed baselines as the fresh "
                     "payloads (plumbing / injection self-test)")
    src.add_argument("--results-dir", type=pathlib.Path,
                     help="gate existing benchmarks/results payloads "
                     "(sweep_engine.json / workload_zoo.json)")
    ap.add_argument("--inject-throughput-regression", type=float,
                    default=0.0, metavar="PCT",
                    help="scale fresh throughput by (1-PCT) before "
                    "comparing - the gate must go red (self-test)")
    ap.add_argument("--inject-savings-drift", type=float, default=0.0,
                    metavar="ABS",
                    help="subtract ABS from every fresh family "
                    "savings_mean (self-test)")
    ap.add_argument("--inject-latency-regression", type=float,
                    default=1.0, metavar="FACTOR",
                    help="multiply fresh service p50/p99 by FACTOR "
                    "before comparing - the gate must go red "
                    "(self-test; use FACTOR > --latency-factor)")
    ap.add_argument("--inject-bytes-regression", type=float,
                    default=0.0, metavar="PCT",
                    help="bloat every content-plane cell's delta_bytes "
                    "by (1+PCT) and recompute savings/dominance - the "
                    "gate must go red (self-test)")
    ap.add_argument("--inject-shard-regression", type=float,
                    default=0.0, metavar="PCT",
                    help="make each shard-count step LOSE PCT capacity "
                    "vs its predecessor - the gate must go red for "
                    "PCT > --shard-capacity-tol (self-test)")
    ap.add_argument("--inject-telemetry-overhead", type=float,
                    default=0.0, metavar="PCT",
                    help="bloat the telemetry-on row's p50/p99 by "
                    "(1+PCT) - the gate must go red for PCT > "
                    "--telemetry-overhead-tol (self-test)")
    ap.add_argument("--savings-tol", type=float, default=0.005,
                    help="same-grid per-family savings tolerance, "
                    "absolute (default 0.005 - savings are "
                    "deterministic at fixed grid+seeds)")
    ap.add_argument("--savings-tol-x", type=float, default=0.08,
                    help="cross-mode savings tolerance (fast grid vs "
                    "full baseline; measured drift is <= 0.04)")
    ap.add_argument("--throughput-rel-tol", type=float, default=0.03,
                    help="same-grid relative throughput tolerance "
                    "(default 0.03: a 5%% regression is red)")
    ap.add_argument("--min-speedup", type=float, default=50.0,
                    help="cross-machine floor on fused-vs-seed-loop "
                    "speedup - the machine-normalized throughput gate "
                    "CI relies on cross-mode (measured 590x-1700x in "
                    "fast mode; a fused-path slowdown of >~12x goes "
                    "red)")
    ap.add_argument("--throughput-floor-frac", type=float, default=0.02,
                    help="cross-machine absolute sims/s (and service "
                    "decisions/s) sanity floor, as a fraction of "
                    "baseline")
    ap.add_argument("--service-savings-tol", type=float, default=0.02,
                    help="same-grid per-family service savings "
                    "tolerance, absolute (lockstep rounds are "
                    "deterministic modulo rare batch splits)")
    ap.add_argument("--service-savings-tol-x", type=float, default=0.10,
                    help="cross-mode service savings tolerance "
                    "(fast-mode rounds vs full baseline)")
    ap.add_argument("--latency-factor", type=float, default=3.0,
                    help="service p50/p99 must stay within this factor "
                    "of baseline (same-grid; cross-mode it is OR'd "
                    "with --latency-ceiling-ms)")
    ap.add_argument("--latency-ceiling-ms", type=float, default=500.0,
                    help="cross-machine absolute service-latency "
                    "pathology bound (ms)")
    ap.add_argument("--shard-capacity-tol", type=float, default=0.10,
                    help="per-step tolerance on the K-shard capacity "
                    "curve: capacity(K_next) >= capacity(K) x (1-tol) "
                    "(capacity is self-normalized decide-busy makespan, "
                    "so it is gated within the fresh payload, not "
                    "cross-machine)")
    ap.add_argument("--shard-savings-tol", type=float, default=0.02,
                    help="sharded rows' savings must stay within this "
                    "absolute tolerance of the plain rows (ledgers are "
                    "bit-identical, so drift can only come from batch "
                    "accounting)")
    ap.add_argument("--telemetry-overhead-tol", type=float, default=0.10,
                    help="telemetry-on p50/p99 must stay within this "
                    "relative fraction of telemetry-off (same payload, "
                    "same machine - the obs hot path must be near-free)")
    ap.add_argument("--telemetry-abs-eps-ms", type=float, default=0.05,
                    help="absolute epsilon (ms) added to the telemetry "
                    "latency ceiling - guards sub-ms baselines against "
                    "scheduler jitter flakes")
    args = ap.parse_args(argv)

    base = {k: _load(p) for k, p in BASELINES.items()}
    if args.replay_baseline:
        fresh = json.loads(json.dumps(base, default=float))
    elif args.results_dir:
        fresh = {
            name: json.loads((args.results_dir /
                              fname).read_text())["extra"]
            for name, fname in RESULT_FILES.items()
        }
    else:
        fresh = _run_benches()

    if (args.inject_throughput_regression or args.inject_savings_drift
            or args.inject_latency_regression != 1.0
            or args.inject_bytes_regression
            or args.inject_shard_regression
            or args.inject_telemetry_overhead):
        print(f"bench-gate: INJECTING synthetic regression "
              f"(throughput -{args.inject_throughput_regression:.0%}, "
              f"savings -{args.inject_savings_drift}, "
              f"latency x{args.inject_latency_regression:.1f}, "
              f"delta bytes +{args.inject_bytes_regression:.0%}, "
              f"shard capacity -{args.inject_shard_regression:.0%}/step, "
              f"telemetry +{args.inject_telemetry_overhead:.0%})")
        fresh = _inject(fresh, args.inject_throughput_regression,
                        args.inject_savings_drift,
                        args.inject_latency_regression,
                        args.inject_bytes_regression,
                        args.inject_shard_regression,
                        args.inject_telemetry_overhead)

    return run_gate(fresh, base, args)


if __name__ == "__main__":
    sys.exit(main())
