"""Paper SS8.8: pointer-semantics strategy mismatch.

Under pointer-reference architectures (agents resolve artifact pointers
every step; cold caches; high churn) lazy's value proposition collapses:
each stale-check miss is a full fetch, while eager's push-on-commit keeps
cache occupancy near-perfect.  The paper reports eager 16,798 tokens /
97.7% CHR vs lazy 341,036 / 41.0% - a ~20x gap.  The qualitative
practitioner rule under test: pointer deployments should prefer eager.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_scenario, fmt_pct, md_table,
                               timed, write_results)
from repro.core import acs
from repro.sim import pointer_semantics_scenario, run_scenario

PAPER = {"eager": (16798, 97.7), "lazy": (341036, 41.0)}


def run() -> list[BenchRow]:
    scn = bench_scenario(pointer_semantics_scenario())
    rows, table = [], []
    totals = {}
    for name, code in [("eager", acs.EAGER), ("lazy", acs.LAZY)]:
        res, us = timed(run_scenario, scn.with_strategy(code),
                        warmup=1, iters=1)
        st = res.stats
        totals[name] = st.sync_tokens_mean
        table.append([
            name, f"{st.sync_tokens_mean:,.0f}",
            fmt_pct(st.cache_hit_rate_mean, st.cache_hit_rate_std),
            f"{st.push_tokens_mean:,.0f}",
            f"{PAPER[name][0]:,} / {PAPER[name][1]}%",
        ])
        rows.append(BenchRow(
            name=f"pointer/{name}",
            us_per_call=us / scn.n_runs,
            derived=(f"sync_tokens={st.sync_tokens_mean:,.0f} "
                     f"CHR={st.cache_hit_rate_mean * 100:.1f}%")))
    ratio = totals["lazy"] / totals["eager"]
    md = ("### SS8.8 - pointer semantics: strategy-selection mismatch\n\n"
          + md_table(["Strategy", "sync_tokens (critical path)",
                      "Cache hit rate", "background push tokens",
                      "paper (tokens / CHR)"], table)
          + f"\nlazy / eager synchronous-cost ratio: {ratio:.1f}x "
          "(paper: ~20x). sync_tokens counts demand fetches that stall "
          "the agent; eager's push-on-commit bytes are asynchronous "
          "background traffic (reported separately). Practitioner rule "
          "holds: pointer-semantics deployments should prefer eager or "
          "access-count.\n")
    write_results("pointer_semantics", rows, md,
                  extra={"lazy_over_eager": ratio})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
