"""Content-plane benchmark: delta coherence bytes-on-wire across
chunk size x write-locality x volatility x the six workload families.

Every (family, locality, volatility) cell of a given chunk size shares
one static signature, so the whole slab - broadcast baseline included -
runs as ONE compiled ``(variant x workload x run)`` XLA program with
the rate matrices AND the write-locality scalars as traced axes
(``engine.compare_workloads``); the compile count is asserted via
``engine.trace_counter`` (one compilation per chunk size, zero
steady-state retraces).

Three byte columns per cell:

  * ``broadcast_bytes``  - per-step full rebroadcast (the paper's
    baseline, in wire bytes);
  * ``full_bytes``       - whole-artifact lazy: the SAME miss sequence
    as delta coherence, shipping the whole artifact per fill;
  * ``delta_bytes``      - chunk-granular delta coherence: only chunks
    whose authority version moved past the reader's chunk vector ship.

The acceptance surface: ``delta < full < broadcast`` (strict) on every
cell of the full grid - delta coherence must strictly dominate
whole-artifact lazy for all six families at V in {0.05, 0.10, 0.25,
0.50}.  Writes ``BENCH_content.json`` at the repo root (schema in
``benchmarks/README.md``), gated by ``scripts/bench_gate.py``.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax

from benchmarks.common import (BenchRow, bench_points, bench_runs,
                               bench_steps, fast_mode, fmt_pct, md_table,
                               provenance, write_results)
from repro.sim import engine, workloads

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_content.json"

#: the measured grid (fast mode shrinks runs/steps and thins the
#: chunk/locality axes, never the families or the volatility points -
#: the acceptance criterion needs all of both).
N_AGENTS = 8
N_ARTIFACTS = 6
N_RUNS = 10
N_STEPS = 40
ARTIFACT_TOKENS = 4096
CHUNK_TOKENS = (256, 512, 1024)
LOCALITIES = (0.1, 0.25, 0.5)
VOLATILITIES = (0.05, 0.10, 0.25, 0.50)
FAMILIES = tuple(workloads.FAMILIES)


def _grid_workloads(chunk_tokens: int, localities, volatilities):
    """Every (family x locality x volatility) cell at one chunk size -
    one static signature, one compilation."""
    cells = []
    for family in FAMILIES:
        base = workloads.make(
            family, n_agents=N_AGENTS, n_artifacts=N_ARTIFACTS,
            n_runs=bench_runs(N_RUNS), artifact_tokens=ARTIFACT_TOKENS,
            n_steps=bench_steps(N_STEPS), chunk_tokens=chunk_tokens)
        for loc in localities:
            for v in volatilities:
                cells.append((family, loc, v,
                              base.with_volatility(v)
                                  .with_locality(loc)))
    return cells


def run() -> list[BenchRow]:
    chunk_axis = bench_points(CHUNK_TOKENS)
    loc_axis = bench_points(LOCALITIES)
    rows_payload = []
    compilations = []
    sims_per_s = None

    for ct in chunk_axis:
        cells = _grid_workloads(ct, loc_axis, VOLATILITIES)
        zoo = [w for _, _, _, w in cells]
        n_episodes = len(zoo) * 2 * zoo[0].n_runs
        with engine.trace_counter() as tc:
            t0 = time.perf_counter()
            cmps = engine.compare_workloads(zoo)
            cold_s = time.perf_counter() - t0
            n_compiles = tc.count
            t0 = time.perf_counter()
            cmps = engine.compare_workloads(zoo)
            steady_s = time.perf_counter() - t0
            recompiles = tc.count - n_compiles
        compilations.append({"chunk_tokens": ct,
                             "compilations": n_compiles,
                             "recompilations_steady": recompiles,
                             "cold_s": cold_s, "steady_s": steady_s})
        sims_per_s = n_episodes / steady_s
        for (family, loc, v, w), cmp_ in zip(cells, cmps):
            co, bc = cmp_.coherent, cmp_.broadcast
            rows_payload.append({
                "family": family,
                "chunk_tokens": ct,
                "write_locality": loc,
                "volatility": v,
                "effective_volatility": w.effective_volatility(),
                "broadcast_bytes": bc.delta_bytes_mean,
                "full_bytes": co.full_bytes_mean,
                "delta_bytes": co.delta_bytes_mean,
                "n_chunks_fetched": co.n_chunks_fetched_mean,
                "savings_vs_full": 1.0 - (co.delta_bytes_mean
                                          / co.full_bytes_mean),
                "savings_vs_broadcast": 1.0 - (co.delta_bytes_mean
                                               / bc.delta_bytes_mean),
                "strictly_dominates": bool(
                    co.delta_bytes_mean < co.full_bytes_mean
                    < bc.delta_bytes_mean),
            })

    violations = [r for r in rows_payload if not r["strictly_dominates"]]
    if violations:
        raise AssertionError(
            f"delta coherence failed strict dominance on "
            f"{len(violations)} cell(s), e.g. {violations[0]}")

    per_family = {}
    for fam in FAMILIES:
        cells = [r for r in rows_payload if r["family"] == fam]
        per_family[fam] = {
            "min_savings_vs_full": min(r["savings_vs_full"]
                                       for r in cells),
            "mean_savings_vs_full": sum(r["savings_vs_full"]
                                        for r in cells) / len(cells),
            "min_savings_vs_broadcast": min(r["savings_vs_broadcast"]
                                            for r in cells),
            "n_cells": len(cells),
        }

    payload = {
        "schema_version": 1,
        "fast_mode": fast_mode(),
        "provenance": provenance(),
        "backend": jax.default_backend(),
        "devices": engine.shard_plan(
            len(FAMILIES) * len(loc_axis) * len(VOLATILITIES),
            bench_runs(N_RUNS)).devices,
        "grid": {
            "families": list(FAMILIES),
            "chunk_tokens": list(chunk_axis),
            "write_localities": list(loc_axis),
            "volatilities": list(VOLATILITIES),
            "n_agents": N_AGENTS,
            "n_artifacts": N_ARTIFACTS,
            "n_runs": bench_runs(N_RUNS),
            "n_steps": bench_steps(N_STEPS),
            "artifact_tokens": ARTIFACT_TOKENS,
            "strategy": "lazy",
        },
        "compilations": compilations,
        "sims_per_s": sims_per_s,
        "per_family": per_family,
        "cells": rows_payload,
        "acceptance": {
            "strict_dominance_all_cells": True,
            "n_cells": len(rows_payload),
        },
    }
    if not fast_mode():
        # repo-root artifact = cross-PR trajectory; smoke runs (shrunk
        # grid, opt-level-0 compiles) must not clobber it.
        BENCH_JSON.write_text(json.dumps(payload, indent=2,
                                         default=float))

    mid_ct = chunk_axis[len(chunk_axis) // 2]
    table = []
    for fam in FAMILIES:
        cells = [r for r in rows_payload
                 if r["family"] == fam and r["chunk_tokens"] == mid_ct]
        best = max(cells, key=lambda r: r["savings_vs_full"])
        worst = min(cells, key=lambda r: r["savings_vs_full"])
        table.append([
            fam, f"{mid_ct}",
            fmt_pct(per_family[fam]["min_savings_vs_full"]),
            fmt_pct(best["savings_vs_full"]),
            f"loc={worst['write_locality']} V={worst['volatility']}",
            fmt_pct(per_family[fam]["min_savings_vs_broadcast"]),
        ])
    md = ("### Content plane - delta coherence bytes-on-wire\n\n"
          + md_table(["family", "chunk", "min sav vs full",
                      "best sav vs full", "worst cell",
                      "min sav vs broadcast"], table)
          + f"\nGrid: {len(rows_payload)} cells "
          f"({len(chunk_axis)} chunk sizes x {len(loc_axis)} "
          f"localities x {len(VOLATILITIES)} volatilities x "
          f"{len(FAMILIES)} families), one compilation per chunk size "
          f"({[c['compilations'] for c in compilations]}), "
          f"{sims_per_s:,.0f} sims/s steady.  Strict dominance "
          f"delta < full < broadcast holds on every cell.\n")

    rows = [BenchRow(
        name=f"content/{fam}",
        us_per_call=0.0,
        derived=f"min_savings_vs_full="
                f"{per_family[fam]['min_savings_vs_full'] * 100:.1f}%")
        for fam in FAMILIES]
    rows.append(BenchRow(
        name="content/engine", us_per_call=0.0,
        derived=f"cells={len(rows_payload)} "
                f"compiles={[c['compilations'] for c in compilations]}"))
    write_results("content_plane", rows, md, extra=payload)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
