"""Paper Table 5: step-count scaling with fixed write budget (SS8.7).

The theorem's central structural claim: T_broadcast grows O(S) while
T_coherent grows only with the (fixed) write count - the S multiplier is
eliminated.  W ~= 2 writes per artifact, so V = 2/S varies with S.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, fmt_k, fmt_pct, md_table, timed,
                               write_results)
from repro.core.theorem import savings_lower_bound_uniform
from repro.sim import SCALING_STEPS, step_scaling_scenario, compare

PAPER = {5: 85.8, 10: 90.3, 20: 93.1, 40: 95.0, 50: 95.5, 100: 96.2}


def run() -> list[BenchRow]:
    rows, table = [], []
    coherent_costs = {}
    for s in SCALING_STEPS:
        scn = step_scaling_scenario(s)
        cmp_, us = timed(compare, scn, warmup=1, iters=1)
        lb = max(0.0, savings_lower_bound_uniform(
            scn.acs.n_agents, s, scn.acs.volatility))
        lb_str = fmt_pct(lb) if lb > 0 else "0% (bound<0)"
        coherent_costs[s] = cmp_.coherent.total_tokens_mean
        table.append([
            s, fmt_k(cmp_.broadcast.total_tokens_mean),
            fmt_k(cmp_.coherent.total_tokens_mean),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            lb_str, f"{PAPER[s]:.1f}%",
        ])
        rows.append(BenchRow(
            name=f"table5/S={s}",
            us_per_call=us / (scn.n_runs * 2),
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" paper={PAPER[s]}%")))
    growth = coherent_costs[100] / coherent_costs[5]
    md = ("### Table 5 - step-count scaling (fixed W ~= 2, n = 4, "
          "m = 3, |d| = 4096)\n\n" + md_table(
              ["S steps", "T_broadcast", "T_coherent", "Savings (sim)",
               "Formula LB", "paper"], table)
          + f"\nT_coherent grows {growth:.1f}x over a 20x step range "
          "(paper: 5.1x) - the operational signature of eliminating "
          "the S multiplier; T_broadcast grows 19x (linear).\n")
    write_results("table5_step_scaling", rows, md,
                  extra={"coherent_growth_20x_steps": growth})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
