"""Paper Table 5: step-count scaling with fixed write budget (SS8.7).

The theorem's central structural claim: T_broadcast grows O(S) while
T_coherent grows only with the (fixed) write count - the S multiplier is
eliminated.  W ~= 2 writes per artifact, so V = 2/S varies with S.

One ``compare_grid`` call over all step counts (S is static - it sets
the scan length); the jit cache makes repeats free.

Timing note: one fused program runs every cell, so ``us_per_call`` is
the grid-average per-episode time repeated on each row - per-cell
attribution does not exist post-fusion.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_points, bench_scenario,
                               fmt_k, fmt_pct, md_table, timed,
                               write_results)
from repro.core.theorem import savings_lower_bound_uniform
from repro.sim import SCALING_STEPS, compare_grid, step_scaling_scenario

PAPER = {5: 85.8, 10: 90.3, 20: 93.1, 40: 95.0, 50: 95.5, 100: 96.2}


def run() -> list[BenchRow]:
    steps = bench_points(SCALING_STEPS)
    # cap_steps=False: S is the swept axis of this table
    scns = [bench_scenario(step_scaling_scenario(s), cap_steps=False)
            for s in steps]
    cmps, us = timed(compare_grid, scns, warmup=1, iters=1)
    n_episodes = sum(s.n_runs * 2 for s in scns)
    rows, table = [], []
    coherent_costs = {}
    for s, scn, cmp_ in zip(steps, scns, cmps):
        lb = max(0.0, savings_lower_bound_uniform(
            scn.acs.n_agents, s, scn.acs.volatility))
        lb_str = fmt_pct(lb) if lb > 0 else "0% (bound<0)"
        coherent_costs[s] = cmp_.coherent.total_tokens_mean
        table.append([
            s, fmt_k(cmp_.broadcast.total_tokens_mean),
            fmt_k(cmp_.coherent.total_tokens_mean),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            lb_str, f"{PAPER[s]:.1f}%",
        ])
        rows.append(BenchRow(
            name=f"table5/S={s}",
            us_per_call=us / n_episodes,
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" paper={PAPER[s]}%")))
    growth = coherent_costs[steps[-1]] / coherent_costs[steps[0]]
    md = ("### Table 5 - step-count scaling (fixed W ~= 2, n = 4, "
          "m = 3, |d| = 4096)\n\n" + md_table(
              ["S steps", "T_broadcast", "T_coherent", "Savings (sim)",
               "Formula LB", "paper"], table)
          + f"\nT_coherent grows {growth:.1f}x over a 20x step range "
          "(paper: 5.1x) - the operational signature of eliminating "
          "the S multiplier; T_broadcast grows 19x (linear).\n")
    write_results("table5_step_scaling", rows, md,
                  extra={"coherent_growth_20x_steps": growth})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
