"""Assemble the SSDry-run / SSRoofline tables from dryrun JSON.

    PYTHONPATH=src python -m benchmarks.roofline_report [baseline]

Default reads dryrun.json -> roofline.md; with the ``baseline`` arg
reads dryrun_baseline.json -> roofline_baseline.md.
"""

from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS / "dryrun.json"

HBM_LIMIT = 16 * 2 ** 30  # v5e per-chip


def fmt_s(x: float) -> str:
    if x >= 0.01:
        return f"{x:.3f}"
    return f"{x:.2e}"


def main() -> None:
    baseline = len(sys.argv) > 1 and sys.argv[1] == "baseline"
    src = RESULTS / ("dryrun_baseline.json" if baseline
                     else "dryrun.json")
    out_name = "roofline_baseline.md" if baseline else "roofline.md"
    data = json.loads(src.read_text())
    single = {k: v for k, v in data.items() if "pod16x16" in k
              and v.get("status") == "ok"}
    multi = {k: v for k, v in data.items() if "pod2x16x16" in k}
    failed = {k: v for k, v in data.items()
              if v.get("status") != "ok"}

    lines = ["## SSRoofline - per (arch x shape), single-pod 16x16 "
             "(256 chips)\n",
             "Terms in seconds/step: compute = FLOPs/(chips x 197e12); "
             "memory = HBM bytes/(chip x 819e9); collective = "
             "HLO-collective bytes/(chip x 50e9). useful = "
             "MODEL_FLOPS (6*N_active*D train / 2*N*D inference) / "
             "analytic total.\n",
             "| arch | shape | compute s | memory s | collective s | "
             "bound | useful | bytes/dev (GB) | fits 16GB | "
             "one-line fix |", "|" + "---|" * 10]
    for key in sorted(single):
        v = single[key]
        arch, shape, _ = key.split("|")
        mem_gb = v["bytes_per_device"]["total_bytes_per_device"] / 2**30
        fits = "yes" if mem_gb * 2**30 <= HBM_LIMIT else f"NO"
        fix = suggest_fix(v)
        lines.append(
            f"| {arch} | {shape} | {fmt_s(v['compute_s'])} | "
            f"{fmt_s(v['memory_s'])} | {fmt_s(v['collective_s'])} | "
            f"**{v['dominant']}** | {v['useful_ratio']:.2f} | "
            f"{mem_gb:.1f} | {fits} | {fix} |")

    lines.append("\n## SSDry-run - multi-pod 2x16x16 (512 chips) "
                 "compile pass\n")
    lines.append("| cell | status | bytes/dev (GB) | collectives "
                 "GB/dev | compile s |")
    lines.append("|---|---|---|---|---|")
    for key in sorted(multi):
        v = multi[key]
        if v.get("status") == "ok":
            mem_gb = (v["bytes_per_device"]["total_bytes_per_device"]
                      / 2**30)
            lines.append(
                f"| {key} | ok | {mem_gb:.1f} | "
                f"{v['collective_gbytes']:.2f} | {v['compile_s']} |")
        else:
            lines.append(f"| {key} | FAILED: {v.get('error', '?')[:60]} "
                         f"| - | - | - |")
    if failed:
        lines.append(f"\n{len(failed)} failed cells (details above).")

    out = "\n".join(lines) + "\n"
    (RESULTS / out_name).write_text(out)
    n_ok = len(single) + sum(1 for v in multi.values()
                             if v.get("status") == "ok")
    print(f"wrote {out_name}: {len(single)} single-pod cells, "
          f"{len(multi)} multi-pod cells, {len(failed)} failures")


def suggest_fix(v: dict) -> str:
    """One sentence on what would move the dominant term down."""
    dom = v["dominant"]
    by = v.get("collective_by_op", {})
    if dom == "collective":
        top = max(by, key=by.get) if by else "all-reduce"
        return (f"cut {top} volume (overlap/reduce-scatter fusion, "
                "bf16 AR payloads)")
    if dom == "memory":
        parts = v.get("bytes_by_part", {})
        top = max(parts, key=parts.get) if parts else "weights"
        if top == "kv_cache":
            return "shrink KV stream (MLA/paged cache, int8 KV)"
        if top == "optimizer":
            return "bf16 moments + wider ZeRO sharding"
        return "quantized weight stream / larger batch per chip"
    # compute
    parts = v.get("flops_by_part", {})
    top = max(parts, key=parts.get) if parts else "param_matmuls"
    if top == "attn_scores":
        return "causal-tile skipping in the flash kernel (~2x scores)"
    if top == "lm_head":
        return "vocab-factorized head or sampled softmax"
    return "larger per-chip batch to raise MXU occupancy"


if __name__ == "__main__":
    main()
