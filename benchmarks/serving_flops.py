"""Beyond-paper benchmark: coherence savings in *prefill compute*.

The paper measures token billing; on a TPU serving fleet the same
redundancy is prefill FLOPs.  This benchmark drives the coherent
serving runtime (real prefix-cache semantics on a zoo backbone) under
the SS8.1 workload and reports FLOPs savings for:

  broadcast  - naive full rebroadcast (baseline)
  lazy       - the paper's recommended strategy
  lazy + volatility-sorted prefix layout (beyond-paper: most-volatile
               artifacts last -> invalidations trash the shortest KV
               suffix)
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_steps, md_table, timed,
                               write_results)
from repro.configs import ARCHS, n_active_params, smoke_config
from repro.runtime.coherent_serving import (CoherentServingSystem,
                                            run_workload)

ARCH = "qwen3-1.7b"
N_AGENTS, N_ARTIFACTS, TOKENS, STEPS = 4, 3, 4096, 40
#: skewed per-artifact volatility (plan doc / analysis doc / scratchpad)
#: in the pessimal registration order (most volatile first) - the case
#: a static layout cannot fix and write-moves-to-back converges out of.
VOLATILITIES = [0.50, 0.10, 0.02]


def _run(sorted_layout: bool):
    cfg = smoke_config(ARCH)
    system = CoherentServingSystem(
        cfg, N_AGENTS,
        {f"artifact-{i}": list(range(1, TOKENS + 1))
         for i in range(N_ARTIFACTS)},
        strategy="lazy", volatility_sorted=sorted_layout,
        n_active_params=n_active_params(ARCHS[ARCH]))
    return run_workload(system, bench_steps(STEPS), VOLATILITIES,
                        seed=20260306)


def run() -> list[BenchRow]:
    rows, table = [], []
    stats, us = timed(_run, False, warmup=0, iters=1)
    stats_sorted, us2 = timed(_run, True, warmup=0, iters=1)
    for name, st, t in [("lazy", stats, us),
                        ("lazy+volatility-sorted-suffix", stats_sorted, us2)]:
        table.append([
            name, f"{st.prefill_tokens:,}",
            f"{st.broadcast_tokens:,}",
            f"{st.token_savings:.1%}",
            f"{st.prefill_flops:.3e}",
            f"{st.flops_savings:.1%}",
        ])
        rows.append(BenchRow(
            name=f"serving/{name}", us_per_call=t,
            derived=(f"flops_savings={st.flops_savings * 100:.1f}% "
                     f"token_savings={st.token_savings * 100:.1f}%")))
    extra_pp = (stats_sorted.flops_savings - stats.flops_savings) * 100
    md = ("### Beyond-paper: prefill-compute savings in the serving "
          f"runtime ({ARCH} backbone, n=4, m=3, |d|=4096, "
          f"per-artifact V={VOLATILITIES})\n\n"
          + md_table(["strategy", "prefill tokens", "broadcast tokens",
                      "token savings", "prefill FLOPs",
                      "FLOPs savings"], table)
          + f"\nThe volatility-sorted-suffix prefix layout adds {extra_pp:+.1f} "
          "pp of FLOPs savings on top of lazy coherence (hot artifacts "
          "migrate to the back, so invalidations land on the shortest "
          "KV suffix).\n")
    write_results("serving_flops", rows, md,
                  extra={"sorted_gain_pp": extra_pp})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
