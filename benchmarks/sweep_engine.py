"""Sweep-engine micro-benchmark: compile count + steady-state throughput.

Measures the one-compilation fleet-sweep path (``sweep_volatility``:
one fused (variant x volatility x run) XLA program, module-level jit
cache) against the pre-fusion per-cell loop it replaced (fresh
``jax.jit`` closure per (volatility, variant) cell - every sweep
retraced every cell).

Reports, for a V-point x 2-strategy (broadcast + lazy) x n_runs grid:

  * ``compilations``   - episode-program traces (engine.trace_count)
  * ``cold_s``         - first call, compile included
  * ``steady_s``       - repeat call, caches warm
  * ``sims_per_s``     - episodes / steady_s

Writes ``BENCH_sweep.json`` at the repo root (schema documented in
``benchmarks/README.md``) so the perf trajectory is tracked across PRs,
plus the usual markdown/JSON under ``benchmarks/results/``.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax

from benchmarks.common import (BenchRow, bench_iters, bench_points,
                               bench_scenario, fast_mode, md_table,
                               provenance, write_results)
from repro.core import acs
from repro.sim import cliff_scenario, resolve_tick_backend, sweep_volatility
from repro.sim import engine

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_sweep.json"

#: The acceptance grid: 4 volatilities x (broadcast + lazy).
VOLATILITIES = (0.05, 0.10, 0.25, 0.50)


def _vols() -> tuple:
    return bench_points(VOLATILITIES)


def _seed_vols() -> tuple:
    # The seed-loop baseline exists to demonstrate per-cell retracing;
    # in fast mode one volatility (2 compiles) is demonstration enough.
    vols = _vols()
    return vols[:1] if len(vols) < len(VOLATILITIES) else vols


def _seed_loop(base_scn) -> None:
    """The pre-fusion path, reproduced as the baseline: one fresh
    ``jax.jit`` program per (volatility, variant) cell, two separate
    launches per comparison.  Fresh jit closures retrace on *every*
    sweep - exactly what the seed engine paid."""
    for scn in engine.sweep_cells(base_scn, _seed_vols()):
        keys = engine._grid_keys([scn.seed], scn.n_runs)[0]
        for strat in (acs.BROADCAST, scn.acs.strategy):
            cfg = dataclasses.replace(scn.acs, strategy=strat)

            def batch(ks, _cfg=cfg):
                engine._note_trace()
                return jax.vmap(
                    lambda k: engine._episode_metrics(_cfg, k))(ks)

            jax.block_until_ready(jax.jit(batch)(keys))


def _fused(base_scn) -> None:
    sweep_volatility(base_scn, _vols())


def run() -> list[BenchRow]:
    base = bench_scenario(cliff_scenario(VOLATILITIES[0]))
    n_episodes = len(_vols()) * 2 * base.n_runs
    iters = bench_iters(3)

    def measure(fn, n_eps, always_cold=False):
        engine.clear_compile_cache()
        engine.reset_trace_count()
        t0 = time.perf_counter()
        fn(base)
        cold_s = time.perf_counter() - t0
        compilations = engine.trace_count()
        if always_cold and iters <= 1:
            # Fresh jit closures retrace on every call, so for this path
            # cold IS steady; skip the redundant re-measure in fast mode.
            steady_s = cold_s
        else:
            steady = []
            for _ in range(iters):
                t0 = time.perf_counter()
                fn(base)
                steady.append(time.perf_counter() - t0)
            steady_s = sorted(steady)[len(steady) // 2]
        return {
            "compilations": compilations,
            "recompilations_steady": engine.trace_count() - compilations,
            "cold_s": cold_s,
            "steady_s": steady_s,
            "n_episodes": n_eps,
            "sims_per_s": n_eps / steady_s,
        }

    seed_eps = len(_seed_vols()) * 2 * base.n_runs
    seed_loop = measure(_seed_loop, seed_eps, always_cold=True)
    # The seed loop keeps retracing in steady state (fresh closures);
    # its per-sweep compile count is the honest recurring cost.
    fused = measure(_fused, n_episodes)
    fused["compile_s"] = max(0.0, fused["cold_s"] - fused["steady_s"])
    speedup = seed_loop["sims_per_s"] and (
        fused["sims_per_s"] / seed_loop["sims_per_s"])
    # The mesh slice the fused grid actually ran on (schema v2): device
    # count plus the sharded axis (null = single-device program).
    plan = engine.shard_plan(len(_vols()), base.n_runs)

    payload = {
        "schema_version": 2,
        "fast_mode": fast_mode(),
        "provenance": provenance(),
        "grid": {
            "volatilities": list(_vols()),
            "strategies": ["broadcast", "lazy"],
            "n_runs": base.n_runs,
            "n_steps": base.acs.n_steps,
            "n_agents": base.acs.n_agents,
            "n_artifacts": base.acs.n_artifacts,
            "n_episodes": n_episodes,
        },
        "backend": jax.default_backend(),
        "tick_backend": resolve_tick_backend(base.acs, n_episodes),
        "devices": plan.devices,
        "shard_axis": plan.axis,
        "seed_loop": seed_loop,
        "fused": fused,
        "speedup_steady": speedup,
    }
    if not fast_mode():
        # The repo-root artifact is the cross-PR perf trajectory; smoke
        # runs (shrunk grid, opt-level-0 compiles) must not clobber it.
        BENCH_JSON.write_text(json.dumps(payload, indent=2,
                                         default=float))

    table = [
        ["seed loop (per-cell jit)", seed_loop["compilations"],
         f"{seed_loop['cold_s']:.3f}", f"{seed_loop['steady_s']:.3f}",
         f"{seed_loop['sims_per_s']:.1f}"],
        ["fused one-program sweep", fused["compilations"],
         f"{fused['cold_s']:.3f}", f"{fused['steady_s']:.3f}",
         f"{fused['sims_per_s']:.1f}"],
    ]
    md = ("### Sweep engine - compile count and steady-state throughput\n\n"
          + md_table(["path", "compilations", "cold s", "steady s",
                      "sims/s"], table)
          + f"\nSteady-state speedup: {speedup:.1f}x "
          f"(grid: {len(_vols())} volatilities x 2 strategies x "
          f"{base.n_runs} runs; backend {payload['backend']}, tick "
          f"{payload['tick_backend']}, devices {plan.devices}"
          f"{f' sharding {plan.axis}' if plan.axis else ''}).\n")
    rows = [
        BenchRow(name="sweep/seed_loop",
                 us_per_call=seed_loop["steady_s"] * 1e6 / seed_eps,
                 derived=f"compiles={seed_loop['compilations']}"),
        BenchRow(name="sweep/fused",
                 us_per_call=fused["steady_s"] * 1e6 / n_episodes,
                 derived=(f"compiles={fused['compilations']}"
                          f" speedup={speedup:.1f}x")),
    ]
    write_results("sweep_engine", rows, md, extra=payload)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
