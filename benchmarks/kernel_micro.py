"""Kernel microbenchmarks (interpret-mode wall time is NOT a TPU number;
the derived column carries the roofline-relevant arithmetic intensity,
which is platform-independent and feeds SSPerf reasoning)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (BenchRow, fast_mode, md_table, timed,
                               write_results)
from repro.kernels import ref


def _ai_attention(b, hq, hkv, l, d):
    flops = 4 * b * hq * l * l * d  # qk^T + pv
    bytes_ = 2 * (b * hq * l * d + 2 * b * hkv * l * d + b * hq * l * d)
    return flops / bytes_


def _ai_decode(b, hq, hkv, l, d):
    flops = 4 * b * hq * l * d
    bytes_ = 2 * (b * hq * d + 2 * b * hkv * l * d + b * hq * d)
    return flops / bytes_


def run() -> list[BenchRow]:
    rows, table = [], []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)

    # flash attention: prefill shape (bf16)
    b, hq, hkv, l, d = 1, 8, 2, (256 if fast_mode() else 1024), 128
    q = jax.random.normal(ks[0], (b, hq, l, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, hkv, l, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, hkv, l, d), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
    _, us = timed(lambda: jax.block_until_ready(fn(q, k, v)))
    ai = _ai_attention(b, hq, hkv, l, d)
    table.append(["flash_attention (prefill 1k, bf16)", f"{us:,.0f}",
                  f"{ai:,.0f} FLOP/B", "compute-bound (MXU)"])
    rows.append(BenchRow("kernels/flash_attention", us,
                         f"arith_intensity={ai:,.0f}flop/B"))

    # decode attention: 32k cache (4k in fast mode)
    l = 4096 if fast_mode() else 32768
    qd = jax.random.normal(ks[0], (1, hq, d), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (1, hkv, l, d), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (1, hkv, l, d), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v))
    _, us = timed(lambda: jax.block_until_ready(fn(qd, kc, vc)))
    ai = _ai_decode(1, hq, hkv, l, d)
    table.append(["decode_attention (32k cache, bf16)", f"{us:,.0f}",
                  f"{ai:.1f} FLOP/B", "memory-bound (HBM stream)"])
    rows.append(BenchRow("kernels/decode_attention", us,
                         f"arith_intensity={ai:.1f}flop/B"))

    # rmsnorm
    rows_n = 1024 if fast_mode() else 4096
    x = jax.random.normal(ks[0], (rows_n, 4096), jnp.bfloat16)
    w = jnp.ones((4096,), jnp.bfloat16)
    fn = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    _, us = timed(lambda: jax.block_until_ready(fn(x, w)))
    table.append(["rmsnorm (4096x4096, bf16)", f"{us:,.0f}",
                  "~0.5 FLOP/B", "memory-bound; fusion saves 1 pass"])
    rows.append(BenchRow("kernels/rmsnorm", us, "memory-bound"))

    # mesi tick over a fleet of simulations
    from repro.kernels.mesi_transition import mesi_tick_pallas
    B, n, m = (256 if fast_mode() else 1024), 4, 3
    import numpy as np
    rng = np.random.default_rng(0)
    args = [jnp.asarray(rng.integers(0, 2, (B, n, m)).astype(np.int32)),
            jnp.ones((B, m), jnp.int32),
            jnp.zeros((B, n, m), jnp.int32),
            jnp.zeros((B, n, m), jnp.int32),
            jnp.asarray(rng.integers(0, 2, (B, n)).astype(np.int32)),
            jnp.asarray(rng.integers(0, m, (B, n)).astype(np.int32)),
            jnp.asarray(rng.integers(0, 2, (B, n)).astype(np.int32))]
    fn = jax.jit(lambda *a: mesi_tick_pallas(
        *a, artifact_tokens=4096, interpret=True))
    _, us = timed(lambda: jax.block_until_ready(fn(*args)))
    table.append([f"mesi_tick ({B} sims/tick, interpret)", f"{us:,.0f}",
                  f"{B / max(us, 1e-9) * 1e6:,.0f} sims/s",
                  "fleet-scale DES hot loop"])
    rows.append(BenchRow("kernels/mesi_tick", us,
                         f"sims_per_tick={B}"))

    md = ("### Kernel microbenchmarks (CPU interpret mode - "
          "correctness platform, not TPU wall-time)\n\n"
          + md_table(["kernel", "us/call", "derived", "roofline note"],
                     table))
    write_results("kernel_micro", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
