"""Paper Table 2: strategy comparison under Scenario B (V = 0.10, SS8.2).

Strategy is a static code (it selects transition *code paths*, not
data), so each strategy is one fused broadcast+coherent program; the
jit cache means re-running the table recompiles nothing.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_points, bench_scenario,
                               fmt_k, fmt_pct, md_table, timed,
                               write_results)
from repro.core import acs
from repro.sim import SCENARIOS, compare

PAPER = {  # T_sync (K tokens), savings% from the paper's Table 2
    "eager": (132.7, 93.3),
    "lazy": (152.3, 92.3),
    "ttl": (589.8, 70.2),
    "access_count": (155.2, 92.2),
}
STRATEGIES = [("eager", acs.EAGER), ("lazy", acs.LAZY), ("ttl", acs.TTL),
              ("access_count", acs.ACCESS_COUNT)]


def run() -> list[BenchRow]:
    scn = bench_scenario(SCENARIOS["B"])
    rows, table = [], []
    bc = compare(scn, acs.LAZY).broadcast  # shared broadcast baseline
    table.append(["broadcast baseline",
                  fmt_k(bc.total_tokens_mean, bc.total_tokens_std),
                  "-", "full rebroadcast every step", "-"])
    for name, code in bench_points(STRATEGIES):
        cmp_, us = timed(compare, scn, code, warmup=1, iters=1)
        table.append([
            name,
            fmt_k(cmp_.coherent.total_tokens_mean,
                  cmp_.coherent.total_tokens_std),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            f"CHR {fmt_pct(cmp_.chr_mean)}",
            f"{PAPER[name][1]:.1f}%",
        ])
        rows.append(BenchRow(
            name=f"table2/{name}",
            us_per_call=us / (scn.n_runs * 2),
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" paper={PAPER[name][1]}%")))
    md = ("### Table 2 - strategy comparison, Scenario B (V = 0.10)\n\n"
          + md_table(["Strategy", "T_sync", "Savings", "Notes",
                      "paper savings"], table))
    write_results("table2_strategies", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
