"""Paper Table 3: token cost vs agent count, Scenario B volatility (SS8.5).

Agent count is shape-determining (static), so each n compiles its own
program - but one ``compare_grid`` call runs them all and the jit cache
makes repeats free.

Timing note: one fused program runs every cell, so ``us_per_call`` is
the grid-average per-episode time repeated on each row - per-cell
attribution does not exist post-fusion.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_points, bench_scenario,
                               fmt_k, fmt_pct, md_table, timed,
                               write_results)
from repro.core.theorem import savings_lower_bound_uniform
from repro.sim import (SCALING_AGENT_COUNTS, agent_scaling_scenario,
                       compare_grid)

PAPER = {2: 95.5, 4: 92.3, 8: 88.2, 16: 84.1}


def run() -> list[BenchRow]:
    counts = bench_points(SCALING_AGENT_COUNTS)
    scns = [bench_scenario(agent_scaling_scenario(n)) for n in counts]
    cmps, us = timed(compare_grid, scns, warmup=1, iters=1)
    n_episodes = sum(s.n_runs * 2 for s in scns)
    rows, table = [], []
    for n, scn, cmp_ in zip(counts, scns, cmps):
        lb = savings_lower_bound_uniform(n, scn.acs.n_steps,
                                         scn.acs.volatility)
        table.append([
            n, fmt_k(cmp_.broadcast.total_tokens_mean),
            fmt_k(cmp_.coherent.total_tokens_mean,
                  cmp_.coherent.total_tokens_std),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            fmt_pct(lb), f"{PAPER[n]:.1f}%",
        ])
        rows.append(BenchRow(
            name=f"table3/n={n}",
            us_per_call=us / n_episodes,
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" LB={lb * 100:.1f}% paper={PAPER[n]}%")))
        assert cmp_.savings_mean > lb, "savings must beat theorem LB"
    md = ("### Table 3 - scaling: token cost vs agent count "
          "(V = 0.10, S = 40)\n\n" + md_table(
              ["n agents", "T_broadcast", "T_coherent", "Savings",
               "Formula LB", "paper"], table))
    write_results("table3_agent_scaling", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
