"""Paper Table 3: token cost vs agent count, Scenario B volatility (SS8.5)."""

from __future__ import annotations

from benchmarks.common import (BenchRow, fmt_k, fmt_pct, md_table, timed,
                               write_results)
from repro.core.theorem import savings_lower_bound_uniform
from repro.sim import SCALING_AGENT_COUNTS, agent_scaling_scenario, compare

PAPER = {2: 95.5, 4: 92.3, 8: 88.2, 16: 84.1}


def run() -> list[BenchRow]:
    rows, table = [], []
    for n in SCALING_AGENT_COUNTS:
        scn = agent_scaling_scenario(n)
        cmp_, us = timed(compare, scn, warmup=1, iters=1)
        lb = savings_lower_bound_uniform(n, scn.acs.n_steps,
                                         scn.acs.volatility)
        table.append([
            n, fmt_k(cmp_.broadcast.total_tokens_mean),
            fmt_k(cmp_.coherent.total_tokens_mean,
                  cmp_.coherent.total_tokens_std),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            fmt_pct(lb), f"{PAPER[n]:.1f}%",
        ])
        rows.append(BenchRow(
            name=f"table3/n={n}",
            us_per_call=us / (scn.n_runs * 2),
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" LB={lb * 100:.1f}% paper={PAPER[n]}%")))
        assert cmp_.savings_mean > lb, "savings must beat theorem LB"
    md = ("### Table 3 - scaling: token cost vs agent count "
          "(V = 0.10, S = 40)\n\n" + md_table(
              ["n agents", "T_broadcast", "T_coherent", "Savings",
               "Formula LB", "paper"], table))
    write_results("table3_agent_scaling", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
