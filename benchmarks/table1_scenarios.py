"""Paper Table 1: token synchronization cost by scenario (SS8.2).

Broadcast vs lazy invalidation over the four canonical workloads
(V in {0.05, 0.10, 0.25, 0.50}), 10 seeded runs, population sigma.

Fused sweep path: the four scenarios share one static configuration, so
``compare_grid`` runs the whole (variant x scenario x run) grid as a
single XLA program - one compilation, one launch.

Timing note: one fused program runs every cell, so ``us_per_call`` is
the grid-average per-episode time repeated on each row - per-cell
attribution does not exist post-fusion.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_scenario, fmt_k, fmt_pct,
                               md_table, timed, write_results)
from repro.sim import SCENARIOS, compare_grid

PAPER = {  # savings%, CRR, CHR% from the paper's Table 1
    "A": (95.0, 0.050, 79.4),
    "B": (92.3, 0.077, 66.8),
    "C": (88.3, 0.117, 51.1),
    "D": (84.2, 0.158, 34.6),
}


def run() -> list[BenchRow]:
    keys = list(SCENARIOS)
    scns = [bench_scenario(SCENARIOS[k]) for k in keys]
    cmps, us = timed(compare_grid, scns, warmup=1, iters=1)
    n_episodes = sum(s.n_runs * 2 for s in scns)
    rows, table = [], []
    for key, scn, cmp_ in zip(keys, scns, cmps):
        table.append([
            scn.name, f"{scn.acs.volatility:.2f}",
            fmt_k(cmp_.broadcast.total_tokens_mean,
                  cmp_.broadcast.total_tokens_std),
            fmt_k(cmp_.coherent.total_tokens_mean,
                  cmp_.coherent.total_tokens_std),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            f"{cmp_.crr:.3f}",
            fmt_pct(cmp_.chr_mean, cmp_.chr_std),
            f"{PAPER[key][0]:.1f}% / {PAPER[key][2]:.1f}%",
        ])
        rows.append(BenchRow(
            name=f"table1/{key}",
            us_per_call=us / n_episodes,
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" paper={PAPER[key][0]}%")))
    md = ("### Table 1 - token synchronization cost by scenario "
          "(10 runs, lazy vs broadcast)\n\n" + md_table(
              ["Scenario", "V", "T_broadcast", "T_coherent", "Savings",
               "CRR", "CHR", "paper (sav/CHR)"], table))
    write_results("table1_scenarios", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
