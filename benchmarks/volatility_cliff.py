"""Paper SS8.3: the volatility cliff that does not materialize.

The lower-bound formula predicts savings collapse at V* = 1 - n/S = 0.9
(n = 4, S = 40); simulation shows ~80% savings persisting through V = 1.0
because (a) writes spread over m = 3 artifacts and (b) lazy deferred
fetch collapses consecutive writes into one re-fetch.

Fused sweep path: volatility is a traced axis, so the entire 8-point
sweep (broadcast + lazy, 10 runs each) is ONE compiled XLA program.

Timing note: one fused program runs every cell, so ``us_per_call`` is
the grid-average per-episode time repeated on each row - per-cell
attribution does not exist post-fusion.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_points, bench_scenario,
                               fmt_pct, md_table, timed, write_results)
from repro.core.theorem import (savings_lower_bound_uniform,
                                volatility_cliff)
from repro.sim import CLIFF_VOLATILITIES, cliff_scenario, compare_grid

PAPER = {0.01: 97.1, 0.05: 95.0, 0.10: 92.4, 0.25: 88.3,
         0.50: 84.3, 0.75: 82.2, 0.90: 81.1, 1.00: 80.6}


def run() -> list[BenchRow]:
    vols = bench_points(CLIFF_VOLATILITIES)
    scns = [bench_scenario(cliff_scenario(v)) for v in vols]
    cmps, us = timed(compare_grid, scns, warmup=1, iters=1)
    n_episodes = sum(s.n_runs * 2 for s in scns)
    rows, table = [], []
    at_cliff = None
    for v, scn, cmp_ in zip(vols, scns, cmps):
        lb = savings_lower_bound_uniform(scn.acs.n_agents,
                                         scn.acs.n_steps, v)
        table.append([
            f"{v:.2f}", fmt_pct(lb),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            f"{PAPER[v]:.1f}%",
        ])
        if v >= 0.90:
            at_cliff = cmp_.savings_mean
        rows.append(BenchRow(
            name=f"cliff/V={v}",
            us_per_call=us / n_episodes,
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" LB={lb * 100:.1f}% paper={PAPER[v]}%")))
    vstar = volatility_cliff(scns[0].acs.n_agents, scns[0].acs.n_steps)
    md = ("### SS8.3 - the volatility cliff "
          f"(n = {scns[0].acs.n_agents}, S = {scns[0].acs.n_steps}, "
          f"predicted V* = {vstar:.2f})\n\n" + md_table(
              ["V", "Formula lower bound", "Observed savings (10 runs)",
               "paper observed"], table)
          + f"\nAt V = V* = {vstar:.1f} the observed savings are "
          f"{at_cliff * 100:.1f}% - the predicted collapse does not "
          "materialize (lazy deferred-fetch collapse; per-artifact "
          "write rate is V/m).\n")
    write_results("volatility_cliff", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
