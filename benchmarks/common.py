"""Shared benchmark harness utilities.

Every benchmark module exposes ``run() -> list[BenchRow]`` and writes a
markdown rendering of its table to ``benchmarks/results/<module>.md``
plus raw JSON to ``benchmarks/results/<module>.json``; ``benchmarks.run``
aggregates all modules and prints the ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import subprocess
import time
from typing import Callable, Sequence

RESULTS_DIR = pathlib.Path(__file__).resolve().parent / "results"
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

#: Fast-mode caps (CI smoke check: ``REPRO_BENCH_FAST=1``).
FAST_MAX_RUNS = 3
FAST_MAX_STEPS = 10
FAST_MAX_ITERS = 1


def fast_mode() -> bool:
    """True when ``REPRO_BENCH_FAST=1``: shrink n_runs/n_steps so the
    full ``python -m benchmarks.run`` finishes in under a minute."""
    return os.environ.get("REPRO_BENCH_FAST", "0") == "1"


def bench_runs(n_runs: int) -> int:
    """Cap per-configuration run count in fast mode."""
    return min(n_runs, FAST_MAX_RUNS) if fast_mode() else n_runs


def bench_steps(n_steps: int) -> int:
    """Cap episode step count in fast mode."""
    return min(n_steps, FAST_MAX_STEPS) if fast_mode() else n_steps


def bench_iters(iters: int) -> int:
    """Cap timing repetitions in fast mode."""
    return min(iters, FAST_MAX_ITERS) if fast_mode() else iters


def bench_points(seq: Sequence) -> tuple:
    """Thin a sweep axis to its endpoints in fast mode.  Compile cost is
    per static shape, so smoke checks keep only the first and last point
    of shape-changing sweeps (agent counts, step counts, K values)."""
    seq = tuple(seq)
    if not fast_mode() or len(seq) <= 2:
        return seq
    return (seq[0], seq[-1])


def bench_scenario(scn, cap_steps: bool = True):
    """Apply fast-mode caps to a ``ScenarioConfig`` (no-op otherwise).

    Pass ``cap_steps=False`` when the benchmark sweeps the step count
    itself (table5): capping would silently collapse the swept axis.
    """
    if not fast_mode():
        return scn
    scn = dataclasses.replace(scn, n_runs=bench_runs(scn.n_runs))
    if cap_steps:
        scn = dataclasses.replace(
            scn, acs=dataclasses.replace(
                scn.acs, n_steps=bench_steps(scn.acs.n_steps)))
    return scn


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        if out.returncode == 0 and sha:
            dirty = subprocess.run(
                ["git", "status", "--porcelain"], cwd=REPO_ROOT,
                capture_output=True, text=True, timeout=10)
            return sha + ("-dirty" if dirty.stdout.strip() else "")
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def provenance() -> dict:
    """Machine/run metadata stamped into every ``BENCH_*.json``: git
    sha, jax version, device kind/count and backend mode.  Makes a
    committed baseline's provenance auditable - the perf gate relaxes
    tolerances when fresh and baseline numbers come from different
    machines, and this block is how a reader tells which case a
    comparison was."""
    import jax
    devices = jax.devices()
    return {
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": len(devices),
        "fast_mode": fast_mode(),
    }


class PhaseClock:
    """Wall-clock accounting per benchmark phase: ``with clock.phase(
    "families"): ...`` accumulates seconds into ``clock.phases``,
    serialized next to the provenance block so a regression in *setup*
    cost (compiles, warmup, oracle replay) is visible even when the
    timed rows stay flat."""

    def __init__(self) -> None:
        self.phases: dict = {}
        self._t0 = time.perf_counter()

    def phase(self, name: str):
        clock = self

        class _Phase:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                clock.phases[name] = (clock.phases.get(name, 0.0)
                                      + time.perf_counter() - self.t0)
                return False

        return _Phase()

    def report(self) -> dict:
        out = dict(self.phases)
        out["total_s"] = time.perf_counter() - self._t0
        return out


@dataclasses.dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    """Return (result, us_per_call) - median of ``iters`` timed calls."""
    result = None
    for _ in range(warmup):
        result = fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        result = fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return result, times[len(times) // 2] * 1e6


def write_results(module_name: str, rows: Sequence[BenchRow],
                  markdown: str, extra: dict | None = None) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    if fast_mode():
        # Provenance: smoke artifacts must not pass for full-grid runs.
        markdown = ("> **REPRO_BENCH_FAST=1 smoke run** - shrunk grid, "
                    "not paper-comparable.\n\n" + markdown)
    payload = {
        "fast_mode": fast_mode(),
        "rows": [dataclasses.asdict(r) for r in rows],
        "extra": extra or {},
    }
    (RESULTS_DIR / f"{module_name}.json").write_text(
        json.dumps(payload, indent=2, default=float))
    (RESULTS_DIR / f"{module_name}.md").write_text(markdown)


def md_table(headers: Sequence[str], rows: Sequence[Sequence]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out) + "\n"


def fmt_k(tokens: float, std: float | None = None) -> str:
    if std is None:
        return f"{tokens / 1e3:,.1f} K"
    return f"{tokens / 1e3:,.1f} ± {std / 1e3:.1f} K"


def fmt_pct(x: float, std: float | None = None) -> str:
    if std is None:
        return f"{x * 100:.1f}%"
    return f"{x * 100:.1f}% ± {std * 100:.1f}%"
