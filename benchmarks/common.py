"""Shared benchmark harness utilities.

Every benchmark module exposes ``run() -> list[BenchRow]`` and writes a
markdown rendering of its table to ``benchmarks/results/<module>.md``
plus raw JSON to ``benchmarks/results/<module>.json``; ``benchmarks.run``
aggregates all modules and prints the ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable, Sequence

RESULTS_DIR = pathlib.Path(__file__).resolve().parent / "results"


@dataclasses.dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    """Return (result, us_per_call) - median of ``iters`` timed calls."""
    result = None
    for _ in range(warmup):
        result = fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        result = fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return result, times[len(times) // 2] * 1e6


def write_results(module_name: str, rows: Sequence[BenchRow],
                  markdown: str, extra: dict | None = None) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    payload = {
        "rows": [dataclasses.asdict(r) for r in rows],
        "extra": extra or {},
    }
    (RESULTS_DIR / f"{module_name}.json").write_text(
        json.dumps(payload, indent=2, default=float))
    (RESULTS_DIR / f"{module_name}.md").write_text(markdown)


def md_table(headers: Sequence[str], rows: Sequence[Sequence]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out) + "\n"


def fmt_k(tokens: float, std: float | None = None) -> str:
    if std is None:
        return f"{tokens / 1e3:,.1f} K"
    return f"{tokens / 1e3:,.1f} ± {std / 1e3:.1f} K"


def fmt_pct(x: float, std: float | None = None) -> str:
    if std is None:
        return f"{x * 100:.1f}%"
    return f"{x * 100:.1f}% ± {std * 100:.1f}%"
