"""Coherence-service load benchmark: concurrent-client throughput,
decision latency and token savings vs broadcast.

Drives the asyncio broker (``repro.service``) with 32 concurrent
clients per workload family in lockstep rounds (a round = one SS8.1
orchestration step, which makes the broadcast baseline exact and the
coherent token totals deterministic for a fixed seed).  The
``uniform`` row is the paper's homogeneous scenario at V=0.10 under
the lazy strategy - the acceptance row: its savings must clear 80%
and its captured decision trace must replay **bit-exactly** through
the four-way differential oracle (protocol / vectorized ACS / Pallas
kernel / model checker).

Writes ``BENCH_service.json`` at the repo root (schema in
``benchmarks/README.md``) so service latency/savings are tracked and
perf-gated across PRs (``scripts/bench_gate.py``).
"""

from __future__ import annotations

import asyncio
import json
import pathlib

import jax

from benchmarks.common import (BenchRow, bench_steps, fast_mode, fmt_pct,
                               md_table, write_results)
from repro.service import (BrokerConfig, CoherenceBroker, drive_workload,
                           verify_broker)
from repro.service.batching import resolve_decide_backend
from repro.sim import workloads

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_service.json"

#: the measured service grid (fast mode shrinks rounds, never clients -
#: the acceptance criterion is >= 32 *concurrent* clients).
N_CLIENTS = 32
N_ARTIFACTS = 6
N_ROUNDS = 40
ARTIFACT_TOKENS = 4096
STRATEGY = "lazy"
MIN_ACCEPT_SAVINGS = 0.80

#: benchmark families: the acceptance row plus the structured zoo.
FAMILIES = ("uniform", "bursty", "zipf", "hierarchical", "rag",
            "pipeline", "ping_pong")
FAMILY_SEEDS = {f: 20260701 + i for i, f in enumerate(FAMILIES)}


def _workload(family: str, n_rounds: int):
    from repro.launch.service import build_workload
    return build_workload(
        family, n_clients=N_CLIENTS, n_artifacts=N_ARTIFACTS,
        artifact_tokens=ARTIFACT_TOKENS, n_rounds=n_rounds,
        seed=FAMILY_SEEDS[family])


def _broker_config() -> BrokerConfig:
    return BrokerConfig(
        n_agents=N_CLIENTS,
        artifacts=tuple(f"artifact-{d}" for d in range(N_ARTIFACTS)),
        artifact_tokens=ARTIFACT_TOKENS, strategy=STRATEGY)


async def _measure_family(family: str, n_rounds: int,
                          verify: bool) -> dict:
    w = _workload(family, n_rounds)
    async with CoherenceBroker(_broker_config()) as broker:
        rep = await drive_workload(broker, w, n_rounds,
                                   seed=FAMILY_SEEDS[family])
        stats = broker.stats()
        row = {
            "family": family,
            "name": w.name,
            "description": w.description,
            "effective_volatility": w.effective_volatility(),
            "actions": rep.n_actions,
            "batches": stats["n_batches"],
            "mean_batch": stats["mean_batch"],
            "throughput_dps": rep.throughput_dps,
            "p50_ms": rep.latency_ms(50),
            "p99_ms": rep.latency_ms(99),
            "coherent_tokens": rep.coherent_tokens,
            "broadcast_tokens": rep.broadcast_tokens,
            "savings_vs_broadcast": rep.savings_vs_broadcast,
            "cache_hit_rate": stats["cache_hit_rate"],
        }
        if verify:
            report = verify_broker(broker, name=f"service:{family}")
            row["oracle_replay"] = {
                "bit_exact": True,
                "implementations": list(report.implementations),
                "n_actions": report.trace.n_actions,
            }
        return row


async def _warmup() -> None:
    """Compile the decision program outside the timed runs (the jit
    cache is keyed on the static broker config, so the measured brokers
    reuse it)."""
    w = _workload("uniform", 2)
    async with CoherenceBroker(_broker_config()) as broker:
        await drive_workload(broker, w, 2, seed=0)


def run() -> list:
    n_rounds = bench_steps(N_ROUNDS)
    cfg = _broker_config()
    decide_backend = resolve_decide_backend(cfg.acs_config())
    asyncio.run(_warmup())

    rows_payload = []
    for family in FAMILIES:
        rows_payload.append(asyncio.run(_measure_family(
            family, n_rounds, verify=(family == "uniform"))))

    accept_row = rows_payload[0]
    assert accept_row["family"] == "uniform"
    if accept_row["savings_vs_broadcast"] < MIN_ACCEPT_SAVINGS:
        raise AssertionError(
            f"acceptance: uniform V=0.10 lazy savings "
            f"{accept_row['savings_vs_broadcast']:.3f} < "
            f"{MIN_ACCEPT_SAVINGS}")

    payload = {
        "schema_version": 1,
        "fast_mode": fast_mode(),
        "backend": jax.default_backend(),
        "decide_backend": decide_backend,
        "grid": {
            "families": list(FAMILIES),
            "n_clients": N_CLIENTS,
            "n_artifacts": N_ARTIFACTS,
            "n_rounds": n_rounds,
            "artifact_tokens": ARTIFACT_TOKENS,
            "strategy": STRATEGY,
        },
        "families": rows_payload,
        "acceptance": {
            "family": "uniform",
            "volatility": 0.10,
            "strategy": STRATEGY,
            "n_clients": N_CLIENTS,
            "min_savings": MIN_ACCEPT_SAVINGS,
            "savings": accept_row["savings_vs_broadcast"],
            "oracle_replay": accept_row["oracle_replay"],
        },
    }
    if not fast_mode():
        # repo-root artifact = cross-PR trajectory; smoke runs must not
        # clobber it.
        BENCH_JSON.write_text(json.dumps(payload, indent=2,
                                         default=float))

    table = [[r["family"], f"{r['effective_volatility']:.3f}",
              f"{r['throughput_dps']:,.0f}",
              f"{r['p50_ms']:.2f} / {r['p99_ms']:.2f}",
              fmt_pct(r["savings_vs_broadcast"]),
              fmt_pct(r["cache_hit_rate"])]
             for r in rows_payload]
    accept_oracle = accept_row["oracle_replay"]
    md = ("### Coherence service - concurrent-client load benchmark\n\n"
          + md_table(["family", "eff. V", "decisions/s",
                      "p50/p99 ms", "savings", "CHR"], table)
          + f"\n{N_CLIENTS} concurrent clients x {n_rounds} rounds per "
          f"family, strategy {STRATEGY}, decide backend "
          f"{decide_backend}.  Acceptance: uniform V=0.10 savings "
          f"{accept_row['savings_vs_broadcast']:.1%} (floor "
          f"{MIN_ACCEPT_SAVINGS:.0%}); captured trace replayed "
          f"bit-exactly through "
          f"{', '.join(accept_oracle['implementations'])}.\n")

    rows = [BenchRow(
        name=f"service/{r['family']}",
        us_per_call=1e6 / max(r["throughput_dps"], 1e-9),
        derived=(f"savings={r['savings_vs_broadcast'] * 100:.1f}% "
                 f"p99={r['p99_ms']:.2f}ms"))
        for r in rows_payload]
    write_results("service_bench", rows, md, extra=payload)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
