"""Coherence-service load benchmark: concurrent-client throughput,
decision latency and token savings vs broadcast.

Drives the asyncio broker (``repro.service``) with 32 concurrent
clients per workload family in lockstep rounds (a round = one SS8.1
orchestration step, which makes the broadcast baseline exact and the
coherent token totals deterministic for a fixed seed).  The
``uniform`` row is the paper's homogeneous scenario at V=0.10 under
the lazy strategy - the acceptance row: its savings must clear 80%
and its captured decision trace must replay **bit-exactly** through
the four-way differential oracle (protocol / vectorized ACS / Pallas
kernel / model checker).

The sharded section re-runs every family on the K=4 authority plane
(4 directory shards, 4 L1 hosts, via the topology-neutral
``service.connect``) and asserts the token ledger is **bit-identical**
to the plain broker's - sharding is a deployment knob, not a semantics
knob - then sweeps K in {1, 2, 4} on the uniform family to show
decision-plane *capacity* (actions / max-over-shards decide-busy, the
makespan metric from ``LoadReport.capacity_dps``) scaling with K at
unchanged savings.

The telemetry-overhead section runs the uniform family twice - once
with the observability plane (``repro.obs``) disabled, once with it on
- and records both latency profiles; the perf gate's ``[telemetry]``
section enforces that telemetry-on p50/p99 stay within tolerance of
telemetry-off on the same machine (``--telemetry`` picks which
variants to measure).

Writes ``BENCH_service.json`` at the repo root (schema v4 in
``benchmarks/README.md``) so service latency/savings/capacity and the
telemetry overhead are tracked and perf-gated across PRs
(``scripts/bench_gate.py``).
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import pathlib

import jax

from benchmarks.common import (BenchRow, PhaseClock, bench_iters,
                               bench_steps, fast_mode, fmt_pct, md_table,
                               provenance, write_results)
from repro.service import (BrokerConfig, CoherenceBroker, CoherenceConfig,
                           connect, drive_workload, verify_broker)
from repro.service.batching import resolve_decide_backend

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_service.json"

#: the measured service grid (fast mode shrinks rounds, never clients -
#: the acceptance criterion is >= 32 *concurrent* clients).
N_CLIENTS = 32
N_ARTIFACTS = 6
N_ROUNDS = 40
ARTIFACT_TOKENS = 4096
STRATEGY = "lazy"
MIN_ACCEPT_SAVINGS = 0.80

#: sharded authority plane: K values for the uniform capacity sweep and
#: the per-family bit-identity pass (always at SHARD_KS[-1]).
SHARD_KS = (1, 2, 4)
N_HOSTS = 4

#: benchmark families: the acceptance row plus the structured zoo.
FAMILIES = ("uniform", "bursty", "zipf", "hierarchical", "rag",
            "pipeline", "ping_pong")
FAMILY_SEEDS = {f: 20260701 + i for i, f in enumerate(FAMILIES)}


def _workload(family: str, n_rounds: int):
    from repro.launch.service import build_workload
    return build_workload(
        family, n_clients=N_CLIENTS, n_artifacts=N_ARTIFACTS,
        artifact_tokens=ARTIFACT_TOKENS, n_rounds=n_rounds,
        seed=FAMILY_SEEDS[family])


def _broker_config(telemetry: bool = True) -> BrokerConfig:
    return CoherenceConfig.make(
        N_CLIENTS, tuple(f"artifact-{d}" for d in range(N_ARTIFACTS)),
        artifact_tokens=ARTIFACT_TOKENS, strategy=STRATEGY,
        telemetry=telemetry).broker_view()


def _coherence_config(shards: int) -> CoherenceConfig:
    """Layered config for the sharded rows: K directory shards, N_HOSTS
    L1 placement domains, same core knobs as the plain rows."""
    return CoherenceConfig.make(
        N_CLIENTS, tuple(f"artifact-{d}" for d in range(N_ARTIFACTS)),
        artifact_tokens=ARTIFACT_TOKENS, strategy=STRATEGY,
        shards=shards, hosts=N_HOSTS)


async def _measure_family(family: str, n_rounds: int,
                          keep_broker: bool = False) -> tuple:
    w = _workload(family, n_rounds)
    async with CoherenceBroker(_broker_config()) as broker:
        rep = await drive_workload(broker, w, n_rounds,
                                   seed=FAMILY_SEEDS[family])
        stats = broker.stats()
        row = {
            "family": family,
            "name": w.name,
            "description": w.description,
            "effective_volatility": w.effective_volatility(),
            "actions": rep.n_actions,
            "batches": stats["decision"]["n_batches"],
            "mean_batch": stats["decision"]["mean_batch"],
            "throughput_dps": rep.throughput_dps,
            "p50_ms": rep.latency_ms(50),
            "p99_ms": rep.latency_ms(99),
            "coherent_tokens": rep.coherent_tokens,
            "broadcast_tokens": rep.broadcast_tokens,
            "savings_vs_broadcast": rep.savings_vs_broadcast,
            "cache_hit_rate": stats["ledger"]["cache_hit_rate"],
        }
        return (row, dataclasses.astuple(broker.ledger),
                broker if keep_broker else None)


async def _measure_sharded(family: str, n_rounds: int, shards: int,
                           plain_ledger: tuple,
                           keep_broker: bool = False) -> tuple:
    """One family on the K-shard authority plane via ``connect``.

    Asserts the token ledger is bit-identical to the plain broker's run
    of the same workload (sharding must not change a single accounting
    bit) and reports the capacity metric + L1/L2 fill split."""
    w = _workload(family, n_rounds)
    async with connect(_coherence_config(shards)) as broker:
        rep = await drive_workload(broker, w, n_rounds,
                                   seed=FAMILY_SEEDS[family])
        stats = broker.stats()
        ledger = dataclasses.astuple(broker.ledger)
        if ledger != plain_ledger:
            raise AssertionError(
                f"sharded K={shards} {family}: ledger diverged from the "
                f"plain broker ({ledger} vs {plain_ledger})")
        l1 = stats.get("l1", {})
        row = {
            "family": family,
            "shards": shards,
            "hosts": N_HOSTS,
            "actions": rep.n_actions,
            "coherent_tokens": rep.coherent_tokens,
            "savings_vs_broadcast": rep.savings_vs_broadcast,
            "capacity_dps": rep.capacity_dps,
            "decide_busy_s": list(rep.decide_busy_s),
            "l1_fills": l1.get("l1_fills", 0),
            "l2_fills": l1.get("l2_fills", 0),
            "l1_fill_rate": l1.get("l1_fill_rate", 0.0),
            "bit_identical_to_plain": True,
        }
        return row, broker if keep_broker else None


async def _measure_overhead(n_rounds: int, telemetry: bool) -> dict:
    """One uniform-family run with the observability plane on or off.

    Telemetry changes no static shapes, so both variants reuse the
    decide program compiled by ``_warmup`` - the delta is pure Python
    bookkeeping (counter increments, span records) on the hot path."""
    w = _workload("uniform", n_rounds)
    cfg = _broker_config(telemetry=telemetry)
    async with CoherenceBroker(cfg) as broker:
        rep = await drive_workload(broker, w, n_rounds,
                                   seed=FAMILY_SEEDS["uniform"])
        return {
            "telemetry": telemetry,
            "actions": rep.n_actions,
            "throughput_dps": rep.throughput_dps,
            "p50_ms": rep.latency_ms(50),
            "p99_ms": rep.latency_ms(99),
            "decide_busy_s": broker.decide_busy_s,
            "savings_vs_broadcast": rep.savings_vs_broadcast,
        }


def _overhead_section(n_rounds: int, mode: str) -> dict:
    """The telemetry-overhead rows: uniform family, telemetry off vs on,
    median-of-repeats per variant.  ``mode`` in {both, on, off} picks
    the variants; overhead ratios need both.  Each latency/throughput
    field is the component-wise median across repeats - the tail (p99)
    sees ~ms GC/scheduler spikes on either variant, and inheriting a
    single row's unlucky tail would make the gate flap."""
    variants = {"both": (False, True),
                "off": (False,), "on": (True,)}[mode]
    iters = bench_iters(5)
    rows = []
    for on in variants:
        repeats = [asyncio.run(_measure_overhead(n_rounds, on))
                   for _ in range(iters)]
        mid = len(repeats) // 2
        med = dict(sorted(repeats, key=lambda r: r["p50_ms"])[mid])
        for field in ("p50_ms", "p99_ms", "throughput_dps"):
            med[field] = sorted(r[field] for r in repeats)[mid]
        med["repeats"] = len(repeats)
        med["p50_ms_all"] = [r["p50_ms"] for r in repeats]
        med["p99_ms_all"] = [r["p99_ms"] for r in repeats]
        rows.append(med)
    section = {"family": "uniform", "n_rounds": n_rounds,
               "mode": mode, "rows": rows}
    if len(variants) == 2:
        off, on = rows[0], rows[1]
        section["p50_overhead_frac"] = (on["p50_ms"] / off["p50_ms"]) - 1.0
        section["p99_overhead_frac"] = (on["p99_ms"] / off["p99_ms"]) - 1.0
        section["throughput_overhead_frac"] = (
            1.0 - on["throughput_dps"] / off["throughput_dps"])
    return section


async def _warmup() -> None:
    """Compile the plain decision program outside the timed runs (the
    jit cache is keyed on the static broker config, so the measured
    brokers reuse it)."""
    w = _workload("uniform", 2)
    async with CoherenceBroker(_broker_config()) as broker:
        await drive_workload(broker, w, 2, seed=0)


async def _warmup_sharded(shards: int) -> None:
    """Per-K warmup: each shard decides over its own artifact subset -
    a different static shape, so a separate jit-cache entry."""
    w = _workload("uniform", 2)
    async with connect(_coherence_config(shards)) as broker:
        await drive_workload(broker, w, 2, seed=0)


def _oracle_row(broker, name: str) -> dict:
    report = verify_broker(broker, name=name)
    return {
        "bit_exact": True,
        "implementations": list(report.implementations),
        "n_actions": report.trace.n_actions,
    }


def run(telemetry_mode: str = "both") -> list:
    n_rounds = bench_steps(N_ROUNDS)
    cfg = _broker_config()
    decide_backend = resolve_decide_backend(cfg.acs_config())
    clock = PhaseClock()
    with clock.phase("warmup"):
        asyncio.run(_warmup())

    rows_payload, plain_ledgers = [], {}
    uniform_broker = None
    with clock.phase("families"):
        for family in FAMILIES:
            row, ledger, broker = asyncio.run(_measure_family(
                family, n_rounds, keep_broker=(family == "uniform")))
            rows_payload.append(row)
            plain_ledgers[family] = ledger
            uniform_broker = uniform_broker or broker

    # telemetry overhead while the plain decide program is still warm
    # (same static shape with telemetry on or off, so no extra compile).
    with clock.phase("telemetry"):
        telemetry_overhead = _overhead_section(n_rounds, telemetry_mode)

    # sharded plane: every family at K=SHARD_KS[-1] must be
    # bit-identical to its plain run (asserted inside), the uniform
    # family additionally sweeps K for the capacity-scaling rows.
    # Caches are cleared between sections: a full run compiles the
    # plain program + one decide program per shard shape + the oracle
    # replay legs, which together can exhaust the CPU LLVM code arena
    # in one process (same reason tests/conftest.py clears caches
    # between modules).  Each section re-warms its own programs, so
    # the timed rows never include a compile.
    k_max = SHARD_KS[-1]
    with clock.phase("sharded"):
        jax.clear_caches()
        asyncio.run(_warmup_sharded(k_max))
        sharded_rows, sharded_uniform_broker = [], None
        for family in FAMILIES:
            row, broker = asyncio.run(_measure_sharded(
                family, n_rounds, k_max, plain_ledgers[family],
                keep_broker=(family == "uniform")))
            sharded_rows.append(row)
            sharded_uniform_broker = sharded_uniform_broker or broker
        scaling_rows = []
        for k in SHARD_KS:
            if k == k_max:
                continue
            jax.clear_caches()
            asyncio.run(_warmup_sharded(k))
            scaling_rows.append(asyncio.run(_measure_sharded(
                "uniform", n_rounds, k, plain_ledgers["uniform"]))[0])
        scaling_rows.append(sharded_rows[0])
        scaling_rows.sort(key=lambda r: r["shards"])

    # oracle replays last, each against a fresh code arena: the
    # four-way legs (pallas interpret + model check) are the biggest
    # compiles of the whole bench.
    with clock.phase("oracle"):
        jax.clear_caches()
        rows_payload[0]["oracle_replay"] = _oracle_row(
            uniform_broker, "service:uniform")
        jax.clear_caches()
        sharded_rows[0]["oracle_replay"] = _oracle_row(
            sharded_uniform_broker, f"service:uniform:K{k_max}")

    accept_row = rows_payload[0]
    assert accept_row["family"] == "uniform"
    if accept_row["savings_vs_broadcast"] < MIN_ACCEPT_SAVINGS:
        raise AssertionError(
            f"acceptance: uniform V=0.10 lazy savings "
            f"{accept_row['savings_vs_broadcast']:.3f} < "
            f"{MIN_ACCEPT_SAVINGS}")

    payload = {
        "schema_version": 4,
        "fast_mode": fast_mode(),
        "provenance": provenance(),
        "phases": clock.report(),
        "backend": jax.default_backend(),
        "decide_backend": decide_backend,
        "grid": {
            "families": list(FAMILIES),
            "n_clients": N_CLIENTS,
            "n_artifacts": N_ARTIFACTS,
            "n_rounds": n_rounds,
            "artifact_tokens": ARTIFACT_TOKENS,
            "strategy": STRATEGY,
        },
        "families": rows_payload,
        "sharded": {
            "ks": list(SHARD_KS),
            "n_hosts": N_HOSTS,
            "families": sharded_rows,
            "uniform_scaling": scaling_rows,
        },
        "telemetry_overhead": telemetry_overhead,
        "acceptance": {
            "family": "uniform",
            "volatility": 0.10,
            "strategy": STRATEGY,
            "n_clients": N_CLIENTS,
            "min_savings": MIN_ACCEPT_SAVINGS,
            "savings": accept_row["savings_vs_broadcast"],
            "oracle_replay": accept_row["oracle_replay"],
        },
    }
    if not fast_mode():
        # repo-root artifact = cross-PR trajectory; smoke runs must not
        # clobber it.
        BENCH_JSON.write_text(json.dumps(payload, indent=2,
                                         default=float))

    table = [[r["family"], f"{r['effective_volatility']:.3f}",
              f"{r['throughput_dps']:,.0f}",
              f"{r['p50_ms']:.2f} / {r['p99_ms']:.2f}",
              fmt_pct(r["savings_vs_broadcast"]),
              fmt_pct(r["cache_hit_rate"])]
             for r in rows_payload]
    shard_table = [[f"K={r['shards']}",
                    f"{r['capacity_dps']:,.0f}",
                    fmt_pct(r["savings_vs_broadcast"]),
                    str(r["l1_fills"]), str(r["l2_fills"]),
                    fmt_pct(r["l1_fill_rate"])]
                   for r in scaling_rows]
    accept_oracle = accept_row["oracle_replay"]
    md = ("### Coherence service - concurrent-client load benchmark\n\n"
          + md_table(["family", "eff. V", "decisions/s",
                      "p50/p99 ms", "savings", "CHR"], table)
          + f"\n{N_CLIENTS} concurrent clients x {n_rounds} rounds per "
          f"family, strategy {STRATEGY}, decide backend "
          f"{decide_backend}.  Acceptance: uniform V=0.10 savings "
          f"{accept_row['savings_vs_broadcast']:.1%} (floor "
          f"{MIN_ACCEPT_SAVINGS:.0%}); captured trace replayed "
          f"bit-exactly through "
          f"{', '.join(accept_oracle['implementations'])}.\n"
          "\n### Sharded authority plane - uniform capacity sweep\n\n"
          + md_table(["shards", "capacity dec/s", "savings",
                      "L1 fills", "L2 fills", "L1 rate"], shard_table)
          + f"\nK directory shards x {N_HOSTS} L1 hosts; capacity = "
          f"actions / max-over-shards decide-busy (the decision-plane "
          f"makespan under shard-per-host deployment).  Every family's "
          f"K={k_max} token ledger is bit-identical to its plain-broker "
          f"run; the uniform K={k_max} trace additionally replayed "
          f"through the cross-shard + L1/L2 conformance legs.\n")

    tel_table = [[("on" if r["telemetry"] else "off"),
                  f"{r['throughput_dps']:,.0f}",
                  f"{r['p50_ms']:.3f}", f"{r['p99_ms']:.3f}",
                  f"{r['decide_busy_s']:.3f}"]
                 for r in telemetry_overhead["rows"]]
    md += ("\n### Telemetry overhead - uniform family, obs plane "
           "off vs on\n\n"
           + md_table(["telemetry", "decisions/s", "p50 ms", "p99 ms",
                       "decide busy s"], tel_table))
    if "p50_overhead_frac" in telemetry_overhead:
        md += (f"\np50 overhead "
               f"{telemetry_overhead['p50_overhead_frac']:+.1%}, p99 "
               f"{telemetry_overhead['p99_overhead_frac']:+.1%} "
               f"(median of {telemetry_overhead['rows'][0]['repeats']} "
               f"repeats; gate: within 10% + absolute epsilon, "
               f"``scripts/bench_gate.py [telemetry]``).\n")

    rows = [BenchRow(
        name=f"service/{r['family']}",
        us_per_call=1e6 / max(r["throughput_dps"], 1e-9),
        derived=(f"savings={r['savings_vs_broadcast'] * 100:.1f}% "
                 f"p99={r['p99_ms']:.2f}ms"))
        for r in rows_payload]
    rows += [BenchRow(
        name=f"service/uniform@K{r['shards']}",
        us_per_call=1e6 / max(r["capacity_dps"], 1e-9),
        derived=(f"savings={r['savings_vs_broadcast'] * 100:.1f}% "
                 f"l1_rate={r['l1_fill_rate'] * 100:.1f}%"))
        for r in scaling_rows]
    rows += [BenchRow(
        name=f"service/telemetry_{'on' if r['telemetry'] else 'off'}",
        us_per_call=1e6 / max(r["throughput_dps"], 1e-9),
        derived=f"p50={r['p50_ms']:.3f}ms p99={r['p99_ms']:.3f}ms")
        for r in telemetry_overhead["rows"]]
    write_results("service_bench", rows, md, extra=payload)
    return rows


if __name__ == "__main__":
    import argparse
    parser = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    parser.add_argument(
        "--telemetry", choices=("both", "on", "off"), default="both",
        help="which observability variants the overhead section "
             "measures (overhead ratios need 'both')")
    args = parser.parse_args()
    for r in run(telemetry_mode=args.telemetry):
        print(r.csv())
