"""Beyond-paper: the price of bounded staleness.

The paper proves Invariant 3 (agents never reason on artifact state
more than K steps stale) and notes K=0 degenerates to sequential
consistency "eliminating the token savings" (SS4.4 Consistency model) -
but never quantifies the savings-vs-K curve.  This benchmark sweeps the
enforcement budget K on Scenario B: each access whose entry has gone
unvalidated for more than K of the agent's own actions triggers a
12-token version check (full re-fetch only if the canonical version
moved), so small K buys freshness with validation traffic, not
rebroadcast.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_points, bench_scenario,
                               fmt_pct, md_table, timed, write_results)
from repro.sim import SCENARIOS, compare

K_VALUES = (1, 2, 3, 5, 8, 0)   # 0 = enforcement off (paper's default)


def run() -> list[BenchRow]:
    rows, table = [], []
    base = None
    for k in bench_points(K_VALUES):
        scn = bench_scenario(SCENARIOS["B"]).with_overrides(
            max_stale_steps=k)
        cmp_, us = timed(compare, scn, warmup=1, iters=1)
        label = str(k) if k else "off"
        if k == 0:
            base = cmp_.savings_mean
        table.append([
            label,
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            f"{cmp_.coherent.signal_tokens_mean / 1e3:.1f} K",
            fmt_pct(cmp_.chr_mean),
        ])
        rows.append(BenchRow(
            name=f"staleness/K={label}",
            us_per_call=us / (scn.n_runs * 2),
            derived=f"savings={cmp_.savings_mean * 100:.1f}%"))
    md = ("### Beyond-paper - savings vs staleness budget K "
          "(Scenario B, V = 0.10)\n\n"
          + md_table(["K (max stale actions)", "Savings",
                      "signal tokens", "CHR"], table)
          + "\nEnforcing Invariant 3 costs only validation signals "
          "(12 tokens/check): even K=1 keeps savings within ~1pp of "
          "unenforced lazy coherence, because a version check is "
          "~340x cheaper than the 4096-token re-fetch broadcast pays. "
          "The paper's K=0-kills-savings remark applies to *synchronous "
          "authority reads*, not to check-then-fetch enforcement.\n")
    write_results("staleness_tradeoff", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
