"""Workload-zoo benchmark: savings-vs-broadcast per workload family.

Runs the heterogeneous workload generator (``repro.sim.workloads``)
through the fused engine: the whole zoo - every family, broadcast
baseline included - is ONE compiled (variant x workload x run) XLA
program with the rate matrices as traced axes
(``engine.compare_workloads``), and the compile count is asserted via
``engine.trace_counter``.

Writes ``BENCH_workloads.json`` at the repo root (schema in
``benchmarks/README.md``) so per-family savings are tracked across
PRs, plus the usual markdown/JSON under ``benchmarks/results/``.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax

from benchmarks.common import (BenchRow, bench_iters, bench_runs,
                               bench_steps, fast_mode, fmt_pct, md_table,
                               provenance, write_results)
from repro.sim import engine, resolve_tick_backend, workloads

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_workloads.json"

#: the measured zoo grid (fast mode shrinks runs/steps, never families).
N_AGENTS = 8
N_ARTIFACTS = 6
N_RUNS = 10
N_STEPS = 40
ARTIFACT_TOKENS = 4096


def _zoo() -> list[workloads.Workload]:
    return workloads.zoo(
        n_agents=N_AGENTS, n_artifacts=N_ARTIFACTS,
        n_runs=bench_runs(N_RUNS), artifact_tokens=ARTIFACT_TOKENS,
        n_steps=bench_steps(N_STEPS))


def run() -> list[BenchRow]:
    zoo = _zoo()
    n_episodes = len(zoo) * 2 * zoo[0].n_runs
    # resolved with the same batch compare_workloads sizes the coherent
    # half on (broadcast never takes the kernel), so the payload records
    # the route the episodes actually ran.
    tick_backend = resolve_tick_backend(zoo[0].acs,
                                        len(zoo) * zoo[0].n_runs)
    iters = bench_iters(3)

    with engine.trace_counter() as tc:
        t0 = time.perf_counter()
        cmps = engine.compare_workloads(zoo)
        cold_s = time.perf_counter() - t0
        compilations = tc.count
        steady = []
        for _ in range(iters):
            t0 = time.perf_counter()
            cmps = engine.compare_workloads(zoo)
            steady.append(time.perf_counter() - t0)
        steady_s = sorted(steady)[len(steady) // 2]
        recompiles = tc.count - compilations

    families = []
    for w, cmp_ in zip(zoo, cmps):
        families.append({
            "family": w.family,
            "name": w.name,
            "description": w.description,
            "effective_volatility": w.effective_volatility(),
            "broadcast_total_mean": cmp_.broadcast.total_tokens_mean,
            "coherent_total_mean": cmp_.coherent.total_tokens_mean,
            "coherent_sync_mean": cmp_.coherent.sync_tokens_mean,
            "coherent_push_mean": cmp_.coherent.push_tokens_mean,
            "savings_mean": cmp_.savings_mean,
            "savings_std": cmp_.savings_std,
            "crr": cmp_.crr,
            "cache_hit_rate_mean": cmp_.chr_mean,
        })

    # The mesh slice the fused zoo ran on (schema v2): device count
    # plus the sharded axis (null = single-device program).
    plan = engine.shard_plan(len(zoo), zoo[0].n_runs)

    payload = {
        "schema_version": 2,
        "fast_mode": fast_mode(),
        "provenance": provenance(),
        "grid": {
            "families": [w.family for w in zoo],
            "n_agents": N_AGENTS,
            "n_artifacts": N_ARTIFACTS,
            "n_runs": zoo[0].n_runs,
            "n_steps": zoo[0].acs.n_steps,
            "artifact_tokens": ARTIFACT_TOKENS,
            "strategy": "lazy",
            "n_episodes": n_episodes,
        },
        "backend": jax.default_backend(),
        "tick_backend": tick_backend,
        "devices": plan.devices,
        "shard_axis": plan.axis,
        "compilations": compilations,
        "recompilations_steady": recompiles,
        "cold_s": cold_s,
        "steady_s": steady_s,
        "sims_per_s": n_episodes / steady_s,
        "families": families,
    }
    if not fast_mode():
        # repo-root artifact = cross-PR trajectory; smoke runs (shrunk
        # grid, opt-level-0 compiles) must not clobber it.
        BENCH_JSON.write_text(json.dumps(payload, indent=2,
                                         default=float))

    table = [[f["family"], f"{f['effective_volatility']:.3f}",
              f"{f['broadcast_total_mean'] / 1e3:,.1f} K",
              f"{f['coherent_total_mean'] / 1e3:,.1f} K",
              fmt_pct(f["savings_mean"], f["savings_std"]),
              fmt_pct(f["cache_hit_rate_mean"])]
             for f in families]
    md = ("### Workload zoo - savings vs broadcast per family\n\n"
          + md_table(["family", "eff. V", "broadcast", "coherent",
                      "savings", "CHR"], table)
          + f"\nOne fused program: {compilations} compilation(s) for "
          f"{len(zoo)} families x 2 variants x {zoo[0].n_runs} runs "
          f"({payload['sims_per_s']:.1f} sims/s steady; backend "
          f"{payload['backend']}, tick {payload['tick_backend']}, "
          f"devices {plan.devices}"
          f"{f' sharding {plan.axis}' if plan.axis else ''}).\n")

    rows = [BenchRow(
        name=f"zoo/{f['family']}",
        us_per_call=steady_s * 1e6 / n_episodes,
        derived=f"savings={f['savings_mean'] * 100:.1f}%")
        for f in families]
    rows.append(BenchRow(name="zoo/engine",
                         us_per_call=steady_s * 1e6 / n_episodes,
                         derived=f"compiles={compilations}"))
    write_results("workload_zoo", rows, md, extra=payload)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
