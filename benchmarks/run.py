"""Benchmark harness entry point - one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run table1 cliff

Prints ``name,us_per_call,derived`` CSV rows; each module also writes
markdown + JSON under ``benchmarks/results/`` (consumed by
EXPERIMENTS.md).
"""

from __future__ import annotations

import os

if os.environ.get("REPRO_BENCH_FAST", "0") == "1":
    # Smoke mode: compile time dominates the suite on CPU; dialing XLA's
    # backend optimization down ~30% per program changes no integer
    # token counters.  Must happen before jax initializes.
    os.environ["XLA_FLAGS"] = ("--xla_backend_optimization_level=0 "
                               + os.environ.get("XLA_FLAGS", ""))

import importlib
import sys
import traceback

#: module name -> short alias
MODULES = {
    "table1_scenarios": "table1",
    "table2_strategies": "table2",
    "table3_agent_scaling": "table3",
    "table4_artifact_size": "table4",
    "table5_step_scaling": "table5",
    "volatility_cliff": "cliff",
    "workload_zoo": "zoo",
    "content_plane": "content",
    "pointer_semantics": "pointer",
    "prompt_cache_amplification": "promptcache",
    "staleness_tradeoff": "staleness",
    "serving_flops": "serving",
    "service_bench": "service",
    "kernel_micro": "kernels",
    # last: its cold-compile measurement clears the jit caches, which
    # would force the modules after it to recompile warm programs.
    "sweep_engine": "sweep",
}


def main() -> None:
    selected = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failures = []
    for mod_name, alias in MODULES.items():
        if selected and alias not in selected and mod_name not in selected:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except ImportError as e:  # optional module not yet available
            print(f"{alias},0.00,SKIPPED import error: {e}")
            continue
        try:
            for row in mod.run():
                print(row.csv())
        except Exception as e:
            failures.append((alias, e))
            traceback.print_exc()
            print(f"{alias},0.00,FAILED {type(e).__name__}: {e}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark module(s) failed")


if __name__ == "__main__":
    main()
