"""Paper SS8.4: provider-side prompt-caching amplification.

Broadcast re-embeds artifact contents every step, so the provider cache
prefix is invalidated whenever an artifact changed (hit rate ~ 1 - V);
coherent prompts carry O(1) references, keeping the structural prefix
stable (hit rate -> 1).  At 50-90% per-hit discounts this amplifies the
effective savings beyond raw token reduction.
"""

from __future__ import annotations

from benchmarks.common import BenchRow, md_table, write_results
from repro.core.theorem import prompt_cache_amplification


def run() -> list[BenchRow]:
    rows, table = [], []
    for v in (0.05, 0.10, 0.25, 0.50):
        for discount in (0.5, 0.9):
            a = prompt_cache_amplification(v, discount)
            table.append([
                f"{v:.2f}", f"{discount:.0%}",
                f"{a['hit_rate_broadcast']:.0%}",
                f"{a['hit_rate_coherent']:.0%}",
                f"{a['effective_cost_mult_broadcast']:.3f}",
                f"{a['effective_cost_mult_coherent']:.3f}",
                f"{a['amplification']:.2f}x",
            ])
            rows.append(BenchRow(
                name=f"promptcache/V={v}/disc={discount}",
                us_per_call=0.0,
                derived=f"amplification={a['amplification']:.2f}x"))
    md = ("### SS8.4 - prompt-caching amplification (analytic model)\n\n"
          + md_table(["V", "discount", "hit (broadcast)", "hit (coherent)",
                      "eff. cost x (broadcast)", "eff. cost x (coherent)",
                      "amplification"], table))
    write_results("prompt_cache_amplification", rows, md)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
