"""Paper Table 4: artifact-size scaling, Scenario A volatility (SS8.6).

Key claim: the savings *ratio* is invariant to artifact size (94.8-95.0%
across a 16x size range) - determined by workflow shape, not magnitude.

One ``compare_grid`` call over all sizes; the jit cache makes repeats
free (artifact size is a static token multiplier in the tick).

Timing note: one fused program runs every cell, so ``us_per_call`` is
the grid-average per-episode time repeated on each row - per-cell
attribution does not exist post-fusion.
"""

from __future__ import annotations

from benchmarks.common import (BenchRow, bench_points, bench_scenario,
                               fmt_k, fmt_pct, md_table, timed,
                               write_results)
from repro.sim import (SCALING_ARTIFACT_TOKENS, artifact_size_scenario,
                       compare_grid)

PAPER = {4096: 95.0, 8192: 95.0, 32768: 94.8, 65536: 94.8}


def run() -> list[BenchRow]:
    sizes = bench_points(SCALING_ARTIFACT_TOKENS)
    scns = [bench_scenario(artifact_size_scenario(t)) for t in sizes]
    cmps, us = timed(compare_grid, scns, warmup=1, iters=1)
    n_episodes = sum(s.n_runs * 2 for s in scns)
    rows, table = [], []
    savings = []
    for tokens, cmp_ in zip(sizes, cmps):
        absolute = (cmp_.broadcast.total_tokens_mean
                    - cmp_.coherent.total_tokens_mean)
        table.append([
            tokens, fmt_k(cmp_.broadcast.total_tokens_mean),
            fmt_k(cmp_.coherent.total_tokens_mean),
            fmt_pct(cmp_.savings_mean, cmp_.savings_std),
            fmt_k(absolute), f"{PAPER[tokens]:.1f}%",
        ])
        savings.append(cmp_.savings_mean)
        rows.append(BenchRow(
            name=f"table4/d={tokens}",
            us_per_call=us / n_episodes,
            derived=(f"savings={cmp_.savings_mean * 100:.1f}%"
                     f" paper={PAPER[tokens]}%")))
    spread = (max(savings) - min(savings)) * 100
    md = ("### Table 4 - artifact-size scaling, Scenario A (V = 0.05)\n\n"
          + md_table(["d_i tokens", "T_broadcast", "T_coherent (lazy)",
                      "Savings", "Absolute savings", "paper"], table)
          + f"\nSavings spread across 16x size range: {spread:.2f} pp "
          "(paper: 0.2 pp - ratio is size-invariant).\n")
    write_results("table4_artifact_size", rows, md,
                  extra={"savings_spread_pp": spread})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
