"""End-to-end driver: serve a small model to a batch of coherent agents.

    PYTHONPATH=src python examples/multi_agent_coherent_serving.py

Four agents collaborate over three shared artifacts against a reduced
qwen3-family backbone.  The coherence layer (MESI over artifacts) gates
which context re-prefills actually happen; at the end the system runs a
REAL batched prefill + a few decode steps through the model for every
agent, proving the serving path end-to-end.  Compares broadcast vs lazy
vs lazy+volatility-sorted-suffix in both tokens and prefill FLOPs.
"""

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ARCHS, n_active_params, smoke_config
from repro.models import transformer as tf
from repro.runtime.coherent_serving import (CoherentServingSystem,
                                            run_workload)

ARCH = "qwen3-1.7b"
ARTIFACT_TOKENS = 48
VOLATILITIES = [0.4, 0.1, 0.02]   # skewed, like real workflows
STEPS = 30


def build_system(strategy: str, sorted_: bool) -> CoherentServingSystem:
    cfg = smoke_config(ARCH)
    artifacts = {
        "shared_plan": [3] * ARTIFACT_TOKENS,       # volatile
        "research_notes": [5] * ARTIFACT_TOKENS,    # occasional edits
        "style_guide": [7] * ARTIFACT_TOKENS,       # near-read-only
    }
    return CoherentServingSystem(
        cfg, n_agents=4, artifacts=artifacts, strategy=strategy,
        volatility_sorted=sorted_,
        n_active_params=n_active_params(ARCHS[ARCH]))


def main() -> None:
    print(f"backbone: {ARCH} (reduced config, real weights on CPU)")
    results = {}
    for name, strategy, sorted_ in [
            ("lazy", "lazy", False),
            ("lazy+sorted-suffix", "lazy", True),
            ("eager", "eager", False)]:
        system = build_system(strategy, sorted_)
        stats = run_workload(system, STEPS, VOLATILITIES, seed=20260307)
        results[name] = (system, stats)
        print(f"\n[{name}]")
        print(f"  prefill tokens {stats.prefill_tokens:8,} "
              f"vs broadcast {stats.broadcast_tokens:10,} "
              f"-> {stats.token_savings:.1%} saved")
        print(f"  prefill FLOPs {stats.prefill_flops:.3e} "
              f"vs broadcast {stats.broadcast_flops:.3e} "
              f"-> {stats.flops_savings:.1%} saved")
        print(f"  fetches={stats.fetches} hits={stats.cache_hits}")

    # --- run the REAL model for every agent of the lazy system -------
    system, _ = results["lazy"]
    cfg = system.cfg
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    print("\nbatched serving through the backbone:")
    for i, agent in enumerate(system.agents):
        logits = system.materialize_prefill(params, i, max_len=128)
        # greedy-decode 4 tokens to show the full serve path
        ctx_tokens = []
        for a in agent.layout:
            ctx_tokens += [int(t) % cfg.vocab_size
                           for t in system.store.get(a)]
        ctx_tokens = ctx_tokens[:96] or [1]
        cache = tf.init_cache(cfg, 1, 128)
        lg, cache = models.prefill(
            params, cfg, jnp.asarray(ctx_tokens, jnp.int32)[None], cache)
        out = []
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        for _ in range(4):
            lg, cache = models.decode_step(params, cfg, tok, cache)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(int(tok[0, 0]))
        print(f"  agent-{i}: context={len(ctx_tokens)} tokens, "
              f"layout={agent.layout}, decoded={out}")
    print("\ndone - every agent served from coherence-gated context.")


if __name__ == "__main__":
    main()
