"""Delta-coherence quickstart: two adapter agents exchanging chunk
deltas through a chunked broker.

An *editor* agent keeps revising one section (chunk span) of a shared
document artifact; a *reviewer* agent re-reads it after every revision.
The broker comes from the topology-neutral ``service.connect(...)``
entry with the chunk-granular content plane on (``chunk_tokens=``):

  * every artifact is a content-addressed chunk array
    (``repro.content.ChunkStore``), so a write's dirty set is
    *measured* by digest diff, not declared;
  * the reviewer's re-reads are coherence misses (the editor's commits
    invalidate its MESI entry) but ship only the chunks whose authority
    version moved past the reviewer's chunk vector -
    ``ReadResult.delta`` - which the client patches onto its local
    mirror (``repro.content.apply_delta``) and checks byte-for-byte
    against the authority copy;
  * the editor drives the broker through the framework-neutral
    ``CoherentTool`` adapter, the reviewer through a CrewAI-style sync
    tool on a ``ServicePortal`` - two different framework veneers over
    one delta-coherent broker.

At the end the broker's captured trace - including the measured dirty
masks - replays through the byte-exact content oracle
(``verify_broker``: chunked scan + Pallas chunk-diff kernel +
real-payload chunk store + whole-artifact baseline), asserting the live
wire ledger bit-for-bit.

Run:  PYTHONPATH=src python examples/delta_coherence_demo.py [--smoke]
"""

from __future__ import annotations

import argparse
import asyncio

from repro.content import BYTES_PER_TOKEN, apply_delta
from repro.service import (CoherenceBroker, CoherenceConfig,
                           CoherentClient, CoherentTool, ServicePortal,
                           connect, crewai_tool, verify_broker)

DOC = "design-doc"
ARTIFACT_TOKENS = 2048
CHUNK_TOKENS = 256          # 8 chunks per artifact


def section(doc: list, idx: int, fill: int) -> list:
    """Rewrite one chunk-sized section of the document."""
    out = list(doc)
    lo = idx * CHUNK_TOKENS
    out[lo:lo + CHUNK_TOKENS] = [fill] * CHUNK_TOKENS
    return out


async def edit_review_rounds(broker: CoherenceBroker,
                             n_rounds: int) -> dict:
    editor = CoherentTool(CoherentClient(broker, 0, name="editor"))
    reviewer = CoherentClient(broker, 1, name="reviewer")

    first = await reviewer.read(DOC)      # cold fill: every chunk ships
    assert len(first.delta) == ARTIFACT_TOKENS // CHUNK_TOKENS
    mirror = first.content
    shipped = [first.delta_bytes]

    for r in range(n_rounds):
        doc = list((await editor.acall("read", DOC)).content)
        await editor.acall("write", DOC,
                           section(doc, r % 8, 1000 + r))
        res = await reviewer.read(DOC)
        # the broker shipped only the edited section(s)
        dirty = [i for i, _ in res.delta]
        mirror = apply_delta(mirror, res.delta, CHUNK_TOKENS)
        assert mirror == res.content, "patched mirror diverged!"
        shipped.append(res.delta_bytes)
        print(f"  round {r}: reviewer re-fetched chunks {dirty} "
              f"({res.delta_bytes} B vs "
              f"{(ARTIFACT_TOKENS + 12) * BYTES_PER_TOKEN} B "
              f"whole-artifact)")
    return {"shipped": shipped}


def sync_reviewer_pass(portal: ServicePortal) -> None:
    """A CrewAI-style sync tool sees the same delta-coherent state."""
    tool = crewai_tool(portal.client(2, name="sync-reviewer"))
    out = tool.run(operation="read", artifact=DOC)
    print(f"  sync adapter read: {out[:72]}...")


async def main(n_rounds: int) -> None:
    config = CoherenceConfig.make(
        3, (DOC,), artifact_tokens=ARTIFACT_TOKENS,
        strategy="lazy", chunk_tokens=CHUNK_TOKENS)
    async with connect(config) as broker:
        print(f"editor/reviewer exchanging {CHUNK_TOKENS}-token chunk "
              f"deltas over {DOC!r} ({ARTIFACT_TOKENS} tokens, "
              f"{ARTIFACT_TOKENS // CHUNK_TOKENS} chunks):")
        await edit_review_rounds(broker, n_rounds)

        stats = broker.stats()
        wire = stats["wire"]
        full = wire["full_bytes"]
        delta = wire["delta_bytes"]
        print(f"\nbytes-on-wire: delta {delta:,} vs whole-artifact "
              f"lazy {full:,} ({wire['bytes_savings_vs_full']:.1%} "
              f"saved; {wire['unique_chunks']} unique chunks stored)")
        assert delta < full

        report = verify_broker(broker, name="delta-demo")
        print(f"oracle replay: {report.trace.n_actions} live actions "
              f"bit-exact through {', '.join(report.implementations)} "
              f"+ byte-exact content legs")

    # the sync-bridge adapter against a fresh chunked broker
    with ServicePortal(config) as portal:
        sync_reviewer_pass(portal)
    print("done.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="2 rounds (CI smoke)")
    args = ap.parse_args()
    asyncio.run(main(2 if args.smoke else 6))
