"""Coherent-service quickstart: a planner/worker team on the broker.

A planner rewrites a shared plan; workers read the plan and publish
results - through three different framework adapter styles over ONE
broker, to show the adapters are a veneer over the same coherence
layer:

  * the planner writes via the framework-neutral ``CoherentTool``;
  * workers read/write via a LangGraph-style async node;
  * a reviewer polls via a CrewAI-style sync tool on a
    ``ServicePortal`` background loop.

The team runs against the *sharded* authority plane - two directory
shards, two L1 hosts - through the topology-neutral
``service.connect(...)`` entry: nothing in the team code names the
topology, and the token ledger is bit-identical to the single-broker
run (oracle-enforced).  At the end the captured decision trace is
replayed bit-for-bit through the four-way differential oracle
(protocol / vectorized ACS / Pallas kernel / model checker), plus the
cross-shard and L1/L2 conformance legs - the live service and the
verified simulator are the same machine.

Run:  PYTHONPATH=src python examples/coherent_service_demo.py [--smoke]
"""

from __future__ import annotations

import argparse
import asyncio

from repro.service import (CoherentClient, CoherentTool, connect,
                           crewai_tool, langgraph_node, verify_broker)

ARTIFACTS = ("plan", "result-a", "result-b")


async def team_round(broker, round_idx: int) -> None:
    planner = CoherentTool(CoherentClient(broker, 0, name="planner"))
    workers = [
        langgraph_node(CoherentClient(broker, 1, name="worker-a"),
                       reads=("plan",)),
        langgraph_node(CoherentClient(broker, 2, name="worker-b"),
                       reads=("plan",)),
    ]
    # planner revises the plan every third round, else re-reads it
    if round_idx % 3 == 0:
        await planner.acall("write", "plan",
                            f"plan revision {round_idx}")
    else:
        await planner.acall("read", "plan")
    # workers run concurrently: read the plan, publish their result
    await asyncio.gather(*(
        worker({"artifact_updates":
                {f"result-{tag}": f"result {round_idx} from {tag}"}})
        for worker, tag in zip(workers, "ab")))


async def run_team(broker, rounds: int) -> None:
    for i in range(rounds):
        await team_round(broker, i)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (CI example-smoke)")
    ap.add_argument("--shards", type=int, default=2,
                    help="authority shards (deployment knob only: the "
                    "ledger is identical for any value)")
    ap.add_argument("--hosts", type=int, default=2,
                    help="L1 placement domains")
    args = ap.parse_args(argv)
    rounds = 4 if args.smoke else args.rounds

    # topology-neutral entry: the team below never learns whether it
    # talks to one broker or a sharded plane with host L1s.
    artifact_tokens = 128
    portal = connect(n_agents=4, artifacts=ARTIFACTS,
                     artifact_tokens=artifact_tokens, strategy="lazy",
                     shards=args.shards, hosts=args.hosts, sync=True)

    # async team via asyncio; then a sync reviewer via the portal,
    # against the SAME authority plane.
    with portal:
        portal.call(run_team(portal.broker, rounds))
        reviewer = crewai_tool(portal.client(3, name="reviewer"))
        print(reviewer.run("read", "plan"))
        print(reviewer.run("read", "result-a"))
        print(reviewer.run("read", "result-a"), "(second read: coherent)")

        broker = portal.broker
        stats = broker.stats()
        decision, ledger = stats["decision"], stats["ledger"]
        n, m = 4, len(ARTIFACTS)
        broadcast = (decision["n_batches"] * n * m
                     * (artifact_tokens + 12))
        savings = 1.0 - ledger["total_tokens"] / max(broadcast, 1)
        print(f"\n{decision['n_actions']} actions in "
              f"{decision['n_batches']} micro-batches "
              f"(mean batch {decision['mean_batch']:.1f}); "
              f"{ledger['total_tokens']} tokens vs {broadcast} "
              f"broadcast = {savings:.1%} saved; "
              f"cache-hit rate {ledger['cache_hit_rate']:.1%}")
        if "l1" in stats:
            topo, l1 = stats["topology"], stats["l1"]
            print(f"authority plane: {topo['n_shards']} shards "
                  f"(artifacts per shard {topo['shard_artifacts']}), "
                  f"{topo['n_hosts']} L1 hosts; "
                  f"{l1['l1_fills']} fills served host-locally vs "
                  f"{l1['l2_fills']} from L2 "
                  f"(L1 fill rate {l1['l1_fill_rate']:.1%})")

        report = verify_broker(broker, name="service:demo")
        print(f"oracle replay: bit-exact across "
              f"{', '.join(report.implementations)}")
        return {"stats": stats, "savings": savings,
                "implementations": report.implementations}


if __name__ == "__main__":
    main()
