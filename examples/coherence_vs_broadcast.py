"""Strategy explorer: sweep volatility and strategies, print the cliff.

    PYTHONPATH=src python examples/coherence_vs_broadcast.py
"""

from repro.core import acs, theorem
from repro.sim import SCENARIOS, cliff_scenario, compare


def bar(frac: float, width: int = 40) -> str:
    n = int(max(0.0, min(1.0, frac)) * width)
    return "#" * n + "." * (width - n)


def main() -> None:
    print("strategy comparison, Scenario B (V = 0.10):")
    for name, code in [("eager", acs.EAGER), ("lazy", acs.LAZY),
                       ("ttl", acs.TTL),
                       ("access_count", acs.ACCESS_COUNT)]:
        c = compare(SCENARIOS["B"], code)
        print(f"  {name:13s} |{bar(c.savings_mean)}| "
              f"{c.savings_mean:6.1%} +- {c.savings_std:.1%}")

    print("\nthe volatility cliff that never comes "
          "(n=4, S=40; bound collapses at V*=0.9):")
    print(f"  {'V':>5} {'theorem LB':>11} {'observed':>9}")
    for v in (0.05, 0.25, 0.50, 0.75, 0.90, 1.00):
        c = compare(cliff_scenario(v))
        lb = theorem.savings_lower_bound_uniform(4, 40, v)
        print(f"  {v:5.2f} {lb:10.0%}  {c.savings_mean:8.1%}  "
              f"|{bar(c.savings_mean)}|")
    print("\nlazy deferred-fetch collapse keeps savings ~80% even at "
          "V = 1.0 (paper SS8.3).")


if __name__ == "__main__":
    main()
