"""Quickstart: the paper in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Model-checks the CCS protocol (SWMR + bounded staleness + the
   broken-invalidation counterexample).
2. Runs Scenario B (V = 0.10) broadcast vs lazy coherence and compares
   against the Token Coherence Theorem's lower bound.
3. Shows the protocol objects the framework integrates with.
"""

import jax

from repro.core import acs, model_check, theorem
from repro.core.protocol import (AgentRuntime, ArtifactStore,
                                 CoordinatorService, EventBus)
from repro.sim import SCENARIOS, compare


def main() -> None:
    print("=" * 68)
    print("1) Formal verification (TLA+-equivalent state enumeration)")
    r = model_check.check(model_check.CheckConfig())
    print(f"   {r.states_explored:,} states, {r.transitions:,} "
          f"transitions: SWMR + BoundedStaleness + MonotonicVersion "
          f"hold = {r.ok}, deadlocks = {r.deadlocks}")
    cex = model_check.find_swmr_counterexample()
    print(f"   removing invalidation -> SWMR violated via "
          f"{cex.violation['trace']}")

    print("=" * 68)
    print("2) Token savings, Scenario B (n=4, S=40, V=0.10, 10 runs)")
    c = compare(SCENARIOS["B"])
    lb = theorem.savings_lower_bound_uniform(4, 40, 0.10)
    print(f"   broadcast: {c.broadcast.total_tokens_mean:12,.0f} tokens")
    print(f"   lazy MESI: {c.coherent.total_tokens_mean:12,.0f} tokens")
    print(f"   savings:   {c.savings_mean:.1%} +- {c.savings_std:.1%}  "
          f"(theorem lower bound {lb:.0%}, paper reports 92.3%)")

    print("=" * 68)
    print("3) The protocol, message by message")
    bus = EventBus()
    store = ArtifactStore()
    coord = CoordinatorService(bus, store)
    coord.register_artifact("plan", list(range(100)))
    alice = AgentRuntime("alice", coord, bus)
    bob = AgentRuntime("bob", coord, bus)
    alice.read("plan")
    bob.read("plan")
    print(f"   after reads:  alice={alice.state_of('plan').name} "
          f"bob={bob.state_of('plan').name} "
          f"(fetch tokens={coord.ledger.fetch_tokens})")
    alice.write("plan", list(range(100, 200)))
    print(f"   after alice writes: alice={alice.state_of('plan').name} "
          f"bob={bob.state_of('plan').name} (invalidated, zero tokens "
          f"moved)")
    bob.read("plan")
    print(f"   bob re-reads: fetch tokens={coord.ledger.fetch_tokens}, "
          f"hits={coord.ledger.n_hits} - only the invalidated copy "
          f"re-fetched")


if __name__ == "__main__":
    main()
