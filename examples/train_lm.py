"""Train a small LM for a few hundred steps with the full substrate:
sharded synthetic data pipeline, AdamW + cosine schedule, async atomic
checkpoints, crash + auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import tempfile

from repro.configs import smoke_config
from repro.data import DataConfig
from repro.runtime.train_loop import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_")
    loop = TrainLoopConfig(total_steps=args.steps, checkpoint_every=50)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                      global_batch=8)

    print(f"training {cfg.name}: {args.steps} steps, ckpts -> {ckpt_dir}")
    # deliberately crash mid-run to demonstrate fault tolerance
    crash_step = args.steps // 2 + 5
    try:
        run_training(cfg, loop, ckpt_dir, data_cfg=data,
                     crash_at_step=crash_step)
    except RuntimeError as e:
        print(f"  !! {e} - restarting from the latest checkpoint")
    report = run_training(cfg, loop, ckpt_dir, data_cfg=data)
    print(f"resumed from step {report.resumed_from}; "
          f"ran {report.steps_run} more steps")
    k = max(len(report.losses) // 8, 1)
    for i in range(0, len(report.losses), k):
        print(f"  step {report.resumed_from + i:4d}  "
              f"loss {report.losses[i]:.4f}")
    print(f"final loss {report.losses[-1]:.4f} "
          f"(start-of-run {report.losses[0]:.4f})")
    assert report.losses[-1] < report.losses[0], "loss should improve"


if __name__ == "__main__":
    main()
