"""Oracle-replayable action traces of the live coherence service.

Every micro-batch the broker commits is one *serialized authority
pass* - exactly the shape of one simulator tick.  Recording the batch
stream as a ``(n_batches, n_agents)`` action matrix therefore yields a
trace in the four-way differential oracle's native format
(``repro.sim.oracle.Trace``): batches map to steps, and within a batch
agents are processed ascending, which is both the broker's and the
kernel's serialization order.

``verify_broker`` closes the live-service <-> conformance loop: the
captured trace is replayed through the message-level protocol, the
vectorized ACS, the Pallas MESI kernel and (for lazy) the model
checker's transition relation, then the agreed-upon ledger / MESI
states / versions are compared **bit-for-bit** against what the live
broker actually charged and holds.  Any scheduling bug, lost update or
double-charge in the async layer shows up as a ConformanceError.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np

from repro.core import acs


@dataclasses.dataclass
class StepRecord:
    """One committed micro-batch (one serialized authority pass)."""

    agents: tuple        # acting agent ids, ascending
    arts: tuple          # artifact index per acting agent
    writes: tuple        # bool per acting agent
    miss: tuple          # bool per acting agent (coherence fill)
    version: tuple       # served version per acting agent
    latency_s: tuple     # decision latency per acting agent
    #: measured dirty chunk indices per acting agent (content plane;
    #: empty tuples for reads / whole-artifact brokers)
    chunks: tuple = ()
    #: authority shard that committed this batch (-1 = unsharded
    #: broker).  Steps from different shards interleave in *global
    #: commit order* - the one serializable order the oracle replays.
    shard: int = -1
    #: v4 telemetry stamps: decision-kernel wall time for this batch
    #: and the cut batch size (requests decided together).  Defaults
    #: are what v3-and-older traces load with; ``batch_size`` falls
    #: back to ``len(agents)`` when unstamped (-1), so offline latency
    #: reconstruction works on any trace vintage.
    decide_s: float = 0.0
    batch_size: int = -1

    @property
    def size(self) -> int:
        """Batch size, robust to v3 traces (unstamped -> len(agents))."""
        return self.batch_size if self.batch_size >= 0 else len(self.agents)


@dataclasses.dataclass
class ServiceTrace:
    """Append-only audit log of every decision the broker made."""

    n_agents: int
    n_artifacts: int
    artifact_tokens: int
    strategy: str
    access_k: int
    max_stale_steps: int
    chunk_tokens: int = 0
    #: authority-plane topology: shard count and per-artifact shard id
    #: (empty tuple = unsharded).  Replays ignore them - the global
    #: commit order is already serializable - but the cross-shard
    #: conformance leg (``sim.oracle.check_sharded_trace``) uses them
    #: to re-derive every shard's local history.
    n_shards: int = 1
    artifact_shards: tuple = ()
    steps: list = dataclasses.field(default_factory=list)

    @classmethod
    def for_broker(cls, config) -> "ServiceTrace":
        return cls(n_agents=config.n_agents,
                   n_artifacts=len(config.artifacts),
                   artifact_tokens=config.artifact_tokens,
                   strategy=config.strategy,
                   access_k=config.access_k,
                   max_stale_steps=config.max_stale_steps,
                   chunk_tokens=getattr(config, "chunk_tokens", 0))

    # -------------------------------------------------------- capture
    def append_step(self, acts, arts, writes, miss, version,
                    latencies: Optional[dict] = None,
                    write_chunks=None, shard: int = -1,
                    decide_s: float = 0.0,
                    batch_size: Optional[int] = None) -> None:
        agents = tuple(int(a) for a in np.flatnonzero(np.asarray(acts)))
        chunks = ()
        if write_chunks is not None:
            chunks = tuple(
                tuple(np.flatnonzero(write_chunks[a]).tolist())
                if writes[a] else () for a in agents)
        self.steps.append(StepRecord(
            agents=agents,
            arts=tuple(int(arts[a]) for a in agents),
            writes=tuple(bool(writes[a]) for a in agents),
            miss=tuple(bool(miss[a]) for a in agents),
            version=tuple(int(version[a]) for a in agents),
            latency_s=tuple(float((latencies or {}).get(a, 0.0))
                            for a in agents),
            chunks=chunks, shard=int(shard),
            decide_s=float(decide_s),
            batch_size=(len(agents) if batch_size is None
                        else int(batch_size))))

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    @property
    def n_actions(self) -> int:
        return sum(len(s.agents) for s in self.steps)

    # ------------------------------------------------- oracle interface
    def acs_config(self) -> acs.ACSConfig:
        return acs.ACSConfig(
            n_agents=self.n_agents, n_artifacts=self.n_artifacts,
            artifact_tokens=self.artifact_tokens,
            n_steps=max(self.n_steps, 1),
            strategy=acs.STRATEGY_CODES[self.strategy],
            access_k=self.access_k,
            max_stale_steps=self.max_stale_steps,
            chunk_tokens=self.chunk_tokens)

    def to_oracle_trace(self):
        """The captured batch stream as a ``sim.oracle.Trace`` (batches
        = steps; agent order within a batch is the serialization
        order both executions share).  Chunked traces carry the
        measured per-write dirty masks, so the byte-exact content leg
        replays the *actual* diffs the live broker served."""
        from repro.content.chunks import n_chunks as _n_chunks
        from repro.sim import oracle
        T = max(self.n_steps, 1)
        acts = np.zeros((T, self.n_agents), bool)
        arts = np.zeros((T, self.n_agents), np.int32)
        writes = np.zeros((T, self.n_agents), bool)
        write_chunks = None
        if self.chunk_tokens > 0:
            C = _n_chunks(self.artifact_tokens, self.chunk_tokens)
            write_chunks = np.zeros((T, self.n_agents, C), bool)
        for s, rec in enumerate(self.steps):
            chunks = rec.chunks or ((),) * len(rec.agents)
            for a, d, w, ch in zip(rec.agents, rec.arts, rec.writes,
                                   chunks):
                acts[s, a] = True
                arts[s, a] = d
                writes[s, a] = w
                if write_chunks is not None and w:
                    write_chunks[s, a, list(ch)] = True
        return oracle.Trace(acts=acts, arts=arts, writes=writes,
                            write_chunks=write_chunks)

    # ----------------------------------------------- offline telemetry
    def latency_report(self) -> dict:
        """Reconstruct the service latency/decide histograms from the
        trace alone (no live broker needed).  v4 traces carry per-step
        decision wall time and batch size; v3-and-older traces yield
        zeros for ``decide_*`` and ``len(agents)`` batch sizes."""
        lat = np.asarray([x for s in self.steps for x in s.latency_s],
                         float)
        if lat.size == 0:
            lat = np.zeros(1)
        sizes = [s.size for s in self.steps]
        decide = [s.decide_s for s in self.steps]
        return {
            "n_steps": self.n_steps,
            "n_actions": self.n_actions,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "mean_batch": (sum(sizes) / max(len(sizes), 1)),
            "max_batch": max(sizes, default=0),
            "decide_s_total": float(sum(decide)),
            "decide_s_max": float(max(decide, default=0.0)),
        }

    # --------------------------------------------------- serialization
    def to_json(self) -> str:
        payload = dataclasses.asdict(self)
        # v2: chunk_tokens + step chunks; v3: shard topology + step
        # shard; v4: per-step decide_s + batch_size telemetry stamps
        payload["schema_version"] = 4
        return json.dumps(payload, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "ServiceTrace":
        payload = json.loads(text)
        payload.pop("schema_version", None)
        payload.setdefault("chunk_tokens", 0)   # v1 traces
        payload.setdefault("n_shards", 1)       # v1/v2 traces
        payload["artifact_shards"] = tuple(
            payload.get("artifact_shards", ()))

        def record(s: dict) -> StepRecord:
            chunks = tuple(tuple(c) for c in s.pop("chunks", ()))
            shard = int(s.pop("shard", -1))
            decide_s = float(s.pop("decide_s", 0.0))    # v3 traces
            batch_size = int(s.pop("batch_size", -1))   # v3 traces
            return StepRecord(chunks=chunks, shard=shard,
                              decide_s=decide_s, batch_size=batch_size,
                              **{k: tuple(v) for k, v in s.items()})

        steps = [record(s) for s in payload.pop("steps")]
        return cls(steps=steps, **payload)


# ---------------------------------------------------------------------------
# The live-service <-> conformance loop.


def replay_trace(trace: ServiceTrace, name: str = "service"):
    """Replay a captured service trace through the four-way oracle.

    Returns the agreed-upon ``DiffReport``; raises ``ConformanceError``
    if any two implementations disagree on the trace."""
    from repro.sim import oracle
    return oracle.check_trace(trace.acs_config(),
                              trace.to_oracle_trace(), name=name)


def verify_broker(broker, name: str = "service"):
    """Replay the broker's own captured trace through the oracle and
    assert the *live* ledger, MESI directory and versions match the
    replay bit-for-bit.  The acceptance surface for the async layer:
    batching, interleaving and dispatch may reorder concurrent
    requests, but the serialized history the broker committed must be
    exactly executable - and exactly charged - under all four
    reference implementations.

    Sharded brokers (``service.sharding.ShardedCoherenceBroker``)
    dispatch to :func:`verify_sharded_broker`, which adds the
    cross-shard and L1/L2 conformance legs."""
    from repro.sim import oracle
    if getattr(broker, "is_sharded", False):
        return verify_sharded_broker(broker, name=name)
    if not broker.config.capture_trace:
        raise ValueError(
            "broker was started with capture_trace=False (unbounded "
            "deployments); oracle verification needs the audit trace")
    if broker.n_batches != broker.trace.n_steps:
        raise ValueError(
            f"trace has {broker.trace.n_steps} steps but the broker "
            f"committed {broker.n_batches} batches - partial capture "
            f"cannot be verified")
    report = replay_trace(broker.trace, name=name)
    led = broker.ledger
    for field in dataclasses.fields(oracle.Ledger):
        live = int(getattr(led, field.name))
        replayed = int(getattr(report.ledger, field.name))
        if live != replayed:
            raise oracle.ConformanceError(
                f"live broker ledger.{field.name} = {live} but oracle "
                f"replay charged {replayed}")
    if not np.array_equal(broker.directory_state, report.state):
        raise oracle.ConformanceError(
            f"live MESI directory diverged from replay:\n"
            f"live:\n{broker.directory_state}\nreplay:\n{report.state}")
    if not np.array_equal(broker.versions, report.version):
        raise oracle.ConformanceError(
            f"live versions diverged from replay: {broker.versions} "
            f"vs {report.version}")
    sync = np.asarray(broker.decider.arrays.last_sync, np.int32)
    if not np.array_equal(sync, report.last_sync):
        raise oracle.ConformanceError(
            f"live last_sync diverged from replay:\n{sync}\n"
            f"vs\n{report.last_sync}")
    if broker.chunks is not None:
        verify_broker_content(broker, name=name)
    return report


def verify_sharded_broker(broker, name: str = "service-sharded"):
    """Conformance closure for the sharded authority plane.

    Four legs, all bit-exact:

    1. **Global serializability** + **cross-shard decomposition** -
       the interleaved per-shard batch stream replays through
       ``sim.oracle.check_sharded_trace``: the four-way harness treats
       it as ONE serializable history, and every shard's projected
       sub-trace independently re-derives that shard's directory
       columns and its share of the ledger.
    2. **Live-state comparison** - the *summed* per-shard ledgers and
       the *assembled* directory/version/last_sync views must equal
       the global replay exactly (sharding changed nothing
       observable).
    3. **Content plane** (chunked brokers) - summed wire bytes and
       assembled chunk arrays vs the byte-exact replay, plus every
       shard's chunk index reassembling to its canonical artifacts.
    4. **L1/L2** - every valid host-L1 entry is within the
       version-lag bound and byte-identical to its shard's authority
       copy, and L1+L2 fill attribution conserves the read-miss count
       (the L1 plane never changed what the decision plane charged).
    """
    from repro.sim import oracle
    if not broker.config.service.capture_trace:
        raise ValueError(
            "broker was started with capture_trace=False (unbounded "
            "deployments); oracle verification needs the audit trace")
    trace = broker.trace
    if broker.n_batches != trace.n_steps:
        raise ValueError(
            f"trace has {trace.n_steps} steps but the sharded broker "
            f"committed {broker.n_batches} batches - partial capture "
            f"cannot be verified")
    report = oracle.check_sharded_trace(
        trace.acs_config(), trace.to_oracle_trace(),
        trace.artifact_shards, name=name)
    led = broker.ledger
    for field in dataclasses.fields(oracle.Ledger):
        live = int(getattr(led, field.name))
        replayed = int(getattr(report.ledger, field.name))
        if live != replayed:
            raise oracle.ConformanceError(
                f"summed shard ledger.{field.name} = {live} but oracle "
                f"replay charged {replayed}")
    for label, live, want in (
            ("directory_state", broker.directory_state, report.state),
            ("versions", broker.versions, report.version),
            ("last_sync", broker.last_sync, report.last_sync)):
        if not np.array_equal(np.asarray(live), want):
            raise oracle.ConformanceError(
                f"assembled sharded {label} diverged from replay:\n"
                f"{np.asarray(live)}\nvs\n{want}")
    if broker.chunked:
        _verify_sharded_content(broker, report, name=name)
    # ---- L1/L2 leg
    broker.check_l1()
    read_misses = sum(
        sum(1 for w, miss in zip(s.writes, s.miss) if miss and not w)
        for s in trace.steps)
    attributed = (broker.l1_wire["l1_fills"]
                  + broker.l1_wire["l2_fills"])
    if attributed != read_misses:
        raise oracle.ConformanceError(
            f"L1/L2 fill attribution lost fills: {attributed} "
            f"attributed vs {read_misses} read misses in the trace")
    return report


def _verify_sharded_content(broker, report, name: str):
    """Byte-exact content leg of sharded verification (chunk ledgers,
    chunk arrays, and per-shard store reassembly)."""
    from repro.content.chunks import reassemble, split_chunks
    from repro.sim import oracle
    trace = broker.trace
    creport = oracle.check_content_trace(
        trace.acs_config(), trace.to_oracle_trace(),
        name=f"{name}:content")
    wire = broker.wire
    for field in dataclasses.fields(oracle.ByteLedger):
        live = int(wire[field.name])
        replayed = int(getattr(creport.ledger, field.name))
        if live != replayed:
            raise oracle.ConformanceError(
                f"summed shard wire.{field.name} = {live} but oracle "
                f"replay charged {replayed}")
    cv = np.zeros_like(np.asarray(creport.chunk_version))
    cs = np.zeros_like(np.asarray(creport.chunk_sync))
    cd = np.zeros_like(np.asarray(creport.chunk_dirty))
    for shard, sub in enumerate(broker.brokers):
        arrays = sub.decider.arrays
        for local, d in enumerate(
                broker.config.shard_artifact_indices()[shard]):
            cv[d] = np.asarray(arrays.chunk_version, np.int32)[local]
            cs[:, d] = np.asarray(arrays.chunk_sync, np.int32)[:, local]
            cd[d] = np.asarray(arrays.chunk_dirty, np.int32)[local]
    for label, live, want in (
            ("chunk_version", cv, creport.chunk_version),
            ("chunk_sync", cs, creport.chunk_sync),
            ("chunk_dirty", cd, creport.chunk_dirty)):
        if not np.array_equal(live, want):
            raise oracle.ConformanceError(
                f"assembled sharded {label} diverged from replay:\n"
                f"{live}\nvs\n{want}")
    for sub in broker.brokers:
        for artifact in sub.names:
            canonical = tuple(sub.store.get(artifact))
            if sub.chunks.reassembled(artifact) != canonical:
                raise oracle.ConformanceError(
                    f"chunk index of {artifact!r} does not reassemble "
                    f"to the canonical artifact on its shard")
            if reassemble(split_chunks(
                    canonical, sub.config.chunk_tokens)) != canonical:
                raise oracle.ConformanceError(
                    f"chunk round-trip broke for {artifact!r}")
    return creport


def verify_broker_content(broker, name: str = "service"):
    """Byte-exact content-plane leg of broker verification: the
    captured trace (with its *measured* per-write dirty masks) replays
    through the chunked scan + Pallas + real-payload-store oracle legs
    (``oracle.check_content_trace``), and the live broker's wire-byte
    ledger, chunk state, and content-addressed store must match the
    replay bit-for-bit - including every artifact's chunk index
    reassembling to the canonical whole-artifact copy."""
    from repro.content.chunks import reassemble, split_chunks
    from repro.sim import oracle
    report = oracle.check_content_trace(
        broker.trace.acs_config(), broker.trace.to_oracle_trace(),
        name=f"{name}:content")
    for field in dataclasses.fields(oracle.ByteLedger):
        live = int(broker.wire[field.name])
        replayed = int(getattr(report.ledger, field.name))
        if live != replayed:
            raise oracle.ConformanceError(
                f"live broker wire.{field.name} = {live} but oracle "
                f"replay charged {replayed}")
    arrays = broker.decider.arrays
    for label, live, want in (
            ("chunk_version", arrays.chunk_version,
             report.chunk_version),
            ("chunk_sync", arrays.chunk_sync, report.chunk_sync),
            ("chunk_dirty", arrays.chunk_dirty, report.chunk_dirty)):
        live = np.asarray(live, np.int32)
        if not np.array_equal(live, want):
            raise oracle.ConformanceError(
                f"live {label} diverged from replay:\n{live}\nvs\n"
                f"{want}")
    for d, artifact in enumerate(broker.names):
        canonical = tuple(broker.store.get(artifact))
        rebuilt = broker.chunks.reassembled(artifact)
        if rebuilt != canonical:
            raise oracle.ConformanceError(
                f"chunk index of {artifact!r} does not reassemble to "
                f"the canonical artifact")
        if reassemble(split_chunks(canonical,
                                   broker.config.chunk_tokens)
                      ) != canonical:
            raise oracle.ConformanceError(
                f"chunk round-trip broke for {artifact!r}")
    return report
