"""Framework adapters: the paper's "thin adapter layer" (contribution 5).

The reference CCS implementation "integrates with LangGraph, CrewAI and
AutoGen via thin adapter layers" - thin because the coherence decision
lives entirely in the broker; an adapter only reshapes read/write calls
into the host framework's tool calling convention.  None of these
frameworks are (or may be) installed here, so each shim is duck-typed
to the framework's documented surface and works standalone:

  * :class:`CoherentTool` - framework-neutral callable + an
    OpenAI-style function schema (``.spec``), the shape both CrewAI
    and AutoGen ultimately consume;
  * :func:`langgraph_node` - an async ``state -> partial-state`` node
    function (LangGraph nodes are exactly that signature);
  * :func:`crewai_tool` - an object exposing ``name`` /
    ``description`` / ``run(...)`` (CrewAI's ``BaseTool`` protocol);
  * :func:`autogen_functions` - ``(schemas, function_map)`` matching
    AutoGen's ``llm_config["functions"]`` + ``register_function``
    pattern.

Sync frameworks get a ``SyncCoherentClient`` (via
``client.ServicePortal``); async frameworks can pass a plain
``CoherentClient``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from repro.service.client import CoherentClient, SyncCoherentClient

AnyClient = Union[CoherentClient, SyncCoherentClient]

TOOL_NAME = "shared_artifact"
TOOL_DESCRIPTION = (
    "Read or write a shared artifact through the coherence broker. "
    "Reads are free when your cached copy is still coherent; writes "
    "serialize through the authority and invalidate peer copies.")

#: OpenAI-style JSON-schema for the tool call, the least common
#: denominator the three frameworks all accept.
TOOL_PARAMETERS = {
    "type": "object",
    "properties": {
        "operation": {"type": "string", "enum": ["read", "write"]},
        "artifact": {"type": "string",
                     "description": "artifact id, e.g. 'plan'"},
        "content": {
            "type": "string",
            "description": "new artifact content (write only)"},
    },
    "required": ["operation", "artifact"],
}


def encode_content(content: Union[str, Sequence[int]],
                   artifact_tokens: int) -> list:
    """Fixed-slot token encoding: int sequences pass through; strings
    become their UTF-8 bytes.  Either is padded/truncated to the
    broker's fixed ``artifact_tokens`` slot (the broker accounts whole
    slots, like the simulator)."""
    toks = (list(content.encode("utf-8")) if isinstance(content, str)
            else [int(t) for t in content])
    toks = toks[:artifact_tokens]
    return toks + [0] * (artifact_tokens - len(toks))


def _is_async(client: AnyClient) -> bool:
    return isinstance(client, CoherentClient)


@dataclasses.dataclass
class ToolResult:
    """Framework-neutral result envelope."""

    operation: str
    artifact: str
    version: int
    hit: Optional[bool]      # None for writes
    content: Optional[tuple]  # None for writes

    def as_text(self) -> str:
        """LLM-facing rendering (what a tool call returns to the model)."""
        if self.operation == "write":
            return (f"wrote {self.artifact!r}; committed version "
                    f"{self.version}")
        src = "coherent cache" if self.hit else "authority fetch"
        return (f"{self.artifact!r} v{self.version} ({src}): "
                f"{list(self.content[:16])}...")


class CoherentTool:
    """Framework-neutral coherent-artifact tool.

    Call synchronously with a :class:`SyncCoherentClient`, or
    ``await tool.acall(...)`` with an async :class:`CoherentClient`.
    """

    name = TOOL_NAME
    description = TOOL_DESCRIPTION

    def __init__(self, client: AnyClient) -> None:
        self.client = client
        self._tokens = client_broker(client).config.artifact_tokens

    @property
    def spec(self) -> dict:
        """OpenAI-style function-call schema."""
        return {"name": self.name, "description": self.description,
                "parameters": TOOL_PARAMETERS}

    # ------------------------------------------------------------ sync
    def __call__(self, operation: str, artifact: str,
                 content: Union[str, Sequence[int], None] = None
                 ) -> ToolResult:
        if _is_async(self.client):
            raise TypeError(
                "CoherentTool over an async CoherentClient must be "
                "awaited via .acall(); hand it a "
                "ServicePortal.client(...) for sync frameworks")
        if operation == "read":
            r = self.client.read(artifact)
            return ToolResult("read", artifact, r.version, r.hit,
                              r.content)
        if operation == "write":
            toks = (encode_content(content, self._tokens)
                    if content is not None else None)
            w = self.client.write(artifact, toks)
            return ToolResult("write", artifact, w.version, None, None)
        raise ValueError(f"operation must be read|write, got "
                         f"{operation!r}")

    # ----------------------------------------------------------- async
    async def acall(self, operation: str, artifact: str,
                    content: Union[str, Sequence[int], None] = None
                    ) -> ToolResult:
        if operation == "read":
            r = await _areader(self.client)(artifact)
            return ToolResult("read", artifact, r.version, r.hit,
                              r.content)
        if operation == "write":
            toks = (encode_content(content, self._tokens)
                    if content is not None else None)
            w = await _awriter(self.client)(artifact, toks)
            return ToolResult("write", artifact, w.version, None, None)
        raise ValueError(f"operation must be read|write, got "
                         f"{operation!r}")


def client_broker(client: AnyClient):
    return (client.broker if _is_async(client)
            else client.portal.broker)


def _guard_sync_on_portal_loop(client) -> None:
    """A sync (portal) client called from a coroutine that runs ON the
    portal's own loop would block that loop while waiting for itself -
    a guaranteed deadlock.  Fail fast with the fix instead."""
    import asyncio
    try:
        running = asyncio.get_running_loop()
    except RuntimeError:
        return
    if running is client.portal._loop:
        raise TypeError(
            "sync portal client awaited on the portal's own event loop "
            "- this deadlocks.  Inside portal-loop coroutines use an "
            "async CoherentClient(portal.broker, ...) instead")


def _areader(client):
    if _is_async(client):
        return client.read

    async def read(artifact):
        _guard_sync_on_portal_loop(client)
        return client.read(artifact)
    return read


def _awriter(client):
    if _is_async(client):
        return client.write

    async def write(artifact, content):
        _guard_sync_on_portal_loop(client)
        return client.write(artifact, content)
    return write


# ---------------------------------------------------------------------------
# LangGraph-style adapter.


def langgraph_node(client: AnyClient, reads: Sequence[str] = (),
                   name: str = "coherent_artifacts"):
    """A LangGraph-style node: ``async (state: dict) -> dict`` update.

    Writes every entry of ``state['artifact_updates']`` (a
    ``{artifact: content}`` dict) through the broker, then reads
    ``reads`` (or ``state['artifact_reads']``) into
    ``state['artifacts']``.  Wire it into a graph exactly like any
    other node - the coherence layer decides whether each read costs
    tokens."""

    async def node(state: dict) -> dict:
        tool = CoherentTool(client)
        versions = {}
        for artifact, content in (state.get("artifact_updates")
                                  or {}).items():
            res = await tool.acall("write", artifact, content)
            versions[artifact] = res.version
        artifacts = {}
        hits = {}
        for artifact in (reads or state.get("artifact_reads") or ()):
            res = await tool.acall("read", artifact)
            artifacts[artifact] = res.content
            versions[artifact] = res.version
            hits[artifact] = res.hit
        return {"artifacts": artifacts, "artifact_versions": versions,
                "artifact_hits": hits}

    node.__name__ = name
    return node


# ---------------------------------------------------------------------------
# CrewAI-style adapter.


class CrewAIToolShim:
    """Duck-typed CrewAI ``BaseTool``: ``name``, ``description``,
    ``run(**kwargs)`` (and the ``_run`` alias newer versions call)."""

    def __init__(self, client: SyncCoherentClient) -> None:
        self._tool = CoherentTool(client)
        self.name = TOOL_NAME
        self.description = TOOL_DESCRIPTION
        self.args_schema = TOOL_PARAMETERS

    def run(self, operation: str, artifact: str,
            content: Union[str, Sequence[int], None] = None) -> str:
        return self._tool(operation, artifact, content).as_text()

    _run = run


def crewai_tool(client: SyncCoherentClient) -> CrewAIToolShim:
    """CrewAI-style tool over a sync (portal) client."""
    if _is_async(client):
        raise TypeError("CrewAI runs synchronous tools - pass a "
                        "ServicePortal.client(...) instead")
    return CrewAIToolShim(client)


# ---------------------------------------------------------------------------
# AutoGen-style adapter.


def autogen_functions(client: AnyClient):
    """AutoGen-style registration pair: ``(schemas, function_map)``.

    ``schemas`` plugs into ``llm_config["functions"]``; ``function_map``
    into ``UserProxyAgent.register_function``.  With an async client the
    mapped callables are coroutine functions (AutoGen supports async
    function maps); with a portal client they are plain callables."""
    tool = CoherentTool(client)
    schemas = [
        {"name": "read_artifact",
         "description": "Read a shared artifact (coherence-cached).",
         "parameters": {
             "type": "object",
             "properties": {"artifact": {"type": "string"}},
             "required": ["artifact"]}},
        {"name": "write_artifact",
         "description": "Commit new content to a shared artifact.",
         "parameters": {
             "type": "object",
             "properties": {"artifact": {"type": "string"},
                            "content": {"type": "string"}},
             "required": ["artifact", "content"]}},
    ]
    if _is_async(client):
        async def read_artifact(artifact: str) -> str:
            return (await tool.acall("read", artifact)).as_text()

        async def write_artifact(artifact: str, content: str) -> str:
            return (await tool.acall("write", artifact,
                                     content)).as_text()
    else:
        def read_artifact(artifact: str) -> str:
            return tool("read", artifact).as_text()

        def write_artifact(artifact: str, content: str) -> str:
            return tool("write", artifact, content).as_text()
    return schemas, {"read_artifact": read_artifact,
                     "write_artifact": write_artifact}
