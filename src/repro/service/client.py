"""Per-agent clients of the coherence broker.

``CoherentClient`` is the async-native client (one per agent slot).
``ServicePortal`` hosts a broker on a background-thread event loop and
hands out ``SyncCoherentClient``s, so *synchronous* frameworks (the
CrewAI-style adapter, plain scripts, REPLs) can call the async broker
without owning an event loop - the portal is what makes the paper's
"thin adapter layer" thin.
"""

from __future__ import annotations

import asyncio
import threading
from typing import Optional, Sequence

from repro.content.chunks import apply_delta
from repro.service.broker import (BrokerConfig, CoherenceBroker,
                                  ReadResult, WriteResult)


class DeltaMismatch(AssertionError):
    """A delta-patched mirror diverged from the authority copy."""


def _chunk_tokens(config) -> int:
    """Chunk granularity of a flat BrokerConfig or a layered
    CoherenceConfig (clients serve both broker flavors)."""
    core = getattr(config, "core", None)
    return core.chunk_tokens if core is not None else config.chunk_tokens


class CoherentClient:
    """One agent's handle on the broker (async).

    Against a *chunked* broker the client keeps a local mirror per
    artifact and patches it with each read's delta payload
    (``repro.content.apply_delta``) - the client-side half of delta
    coherence.  Every patched mirror is checked byte-for-byte against
    the authority copy the response carries; a mismatch raises
    :class:`DeltaMismatch` (it would mean the broker shipped an
    incomplete stale-chunk set).
    """

    def __init__(self, broker: CoherenceBroker, agent_id: int,
                 name: Optional[str] = None) -> None:
        self.broker = broker
        self.agent_id = int(agent_id)
        self.name = name or f"agent-{agent_id}"
        self.n_reads = 0
        self.n_writes = 0
        self.n_hits = 0
        self._mirror: dict = {}
        self.delta_bytes_received = 0

    def _patch_mirror(self, artifact: str, res: ReadResult) -> None:
        if res.delta is None:
            return
        ct = _chunk_tokens(self.broker.config)
        base = self._mirror.get(artifact)
        if base is None:
            # first contact: adopt the full copy (the broker charged a
            # cold full-artifact delta for it anyway)
            self._mirror[artifact] = res.content
        else:
            self._mirror[artifact] = apply_delta(base, res.delta, ct)
        if res.delta_bytes > 0:
            self.delta_bytes_received += res.delta_bytes
        if self._mirror[artifact] != res.content:
            raise DeltaMismatch(
                f"agent {self.agent_id}: delta-patched mirror of "
                f"{artifact!r} diverged from the authority copy")

    async def read(self, artifact: str) -> ReadResult:
        res = await self.broker.read(self.agent_id, artifact)
        self.n_reads += 1
        self.n_hits += int(res.hit)
        self._patch_mirror(artifact, res)
        return res

    async def write(self, artifact: str,
                    content: Optional[Sequence[int]] = None
                    ) -> WriteResult:
        res = await self.broker.write(self.agent_id, artifact, content)
        self.n_writes += 1
        if content is not None:
            # the writer holds what it just committed
            self._mirror[artifact] = tuple(int(t) for t in content)
        return res

    @property
    def hit_rate(self) -> float:
        return self.n_hits / max(self.n_reads, 1)


def make_clients(broker: CoherenceBroker) -> list:
    """One client per agent slot of the broker."""
    return [CoherentClient(broker, a)
            for a in range(broker.config.n_agents)]


# ---------------------------------------------------------------------------
# Sync bridge for frameworks that do not run an event loop.


class ServicePortal:
    """Owns an event loop on a daemon thread and runs a broker on it.

    Synchronous code (framework tool callbacks, scripts) submits
    coroutines with :meth:`call`; concurrency still happens - requests
    from many threads coalesce into the broker's micro-batches on the
    portal loop.  Use as a context manager::

        with ServicePortal(config) as portal:
            client = portal.client(0)
            client.read("plan")
    """

    _CALL_TIMEOUT_S = 60.0

    def __init__(self, config: BrokerConfig,
                 contents: Optional[dict] = None) -> None:
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, name="coherence-broker",
            daemon=True)
        self._thread.start()
        self.broker = self.call(self._make_broker(config, contents))

    @staticmethod
    async def _make_broker(config, contents):
        # topology-neutral: a layered config with shards/hosts gets the
        # sharded authority plane, anything else the single broker
        from repro.service.connect import resolve_broker
        return await resolve_broker(config, contents).start()

    # ---------------------------------------------------------------
    def call(self, coro):
        """Run a coroutine on the portal loop, blocking for the result."""
        fut = asyncio.run_coroutine_threadsafe(coro, self._loop)
        return fut.result(timeout=self._CALL_TIMEOUT_S)

    def client(self, agent_id: int,
               name: Optional[str] = None) -> "SyncCoherentClient":
        return SyncCoherentClient(self, agent_id, name=name)

    def close(self) -> None:
        if self._loop.is_closed():
            return
        self.call(self.broker.stop())
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=self._CALL_TIMEOUT_S)
        self._loop.close()

    def __enter__(self) -> "ServicePortal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SyncCoherentClient:
    """Blocking per-agent client backed by a :class:`ServicePortal`."""

    def __init__(self, portal: ServicePortal, agent_id: int,
                 name: Optional[str] = None) -> None:
        self.portal = portal
        self._async = CoherentClient(portal.broker, agent_id, name=name)
        self.agent_id = self._async.agent_id
        self.name = self._async.name

    def read(self, artifact: str) -> ReadResult:
        return self.portal.call(self._async.read(artifact))

    def write(self, artifact: str,
              content: Optional[Sequence[int]] = None) -> WriteResult:
        return self.portal.call(self._async.write(artifact, content))

    @property
    def hit_rate(self) -> float:
        return self._async.hit_rate
