"""Artifact-coherence service: the paper's reference implementation as
a servable async system (contribution 5).

Public surface (stable import paths for examples and docs):

  * :func:`connect` - the **blessed entry point**: a topology-neutral
    factory that resolves the layered ``repro.configs.CoherenceConfig``
    onto the right authority implementation (single broker or sharded
    plane) without callers naming either;
  * :class:`CoherenceBroker` - the asyncio single-writer authority
    with micro-batched coherence decisions;
  * :class:`ShardedCoherenceBroker` / :class:`HostL1Directory` - the
    K-shard authority plane with per-host L1 directories;
  * :class:`BrokerConfig` - legacy flat config, now a thin frozen view
    over ``CoherenceConfig`` (direct construction warns once);
  * :class:`CoherentClient` / :func:`make_clients` /
    :class:`ServicePortal` / :class:`SyncCoherentClient` - per-agent
    clients (async-native, plus a sync bridge for frameworks);
  * :class:`CoherentTool`, :func:`langgraph_node`, :func:`crewai_tool`,
    :func:`autogen_functions` - the thin framework adapter layer;
  * :class:`ServiceTrace` / :func:`replay_trace` /
    :func:`verify_broker` / :func:`verify_sharded_broker` -
    oracle-replayable decision traces (``verify_broker`` dispatches on
    the broker flavor);
  * :func:`drive_workload` / :class:`LoadReport` - the concurrent load
    generator over workload-zoo rate matrices.
"""

from repro.configs.coherence import (CoherenceConfig, CoherenceCore,
                                     ServiceLayer, ShardTopology,
                                     shard_of_artifact)
from repro.service.broker import (BROKER_STRATEGIES, BrokerConfig,
                                  CoherenceBroker, InvariantViolation,
                                  ReadResult, WriteResult)
from repro.service.batching import (BatchDecider, BatchDecision,
                                    resolve_decide_backend)
from repro.service.sharding import (HostL1Directory, L1Entry,
                                    ShardedCoherenceBroker)
from repro.service.connect import connect, resolve_broker
from repro.service.client import (CoherentClient, DeltaMismatch,
                                  ServicePortal, SyncCoherentClient,
                                  make_clients)
from repro.service.adapters import (CoherentTool, ToolResult,
                                    autogen_functions, crewai_tool,
                                    langgraph_node)
from repro.service.trace import (ServiceTrace, StepRecord, replay_trace,
                                 verify_broker, verify_broker_content,
                                 verify_sharded_broker)
from repro.service.loadgen import LoadReport, drive_workload

__all__ = [
    "connect", "resolve_broker",
    "CoherenceConfig", "CoherenceCore", "ServiceLayer", "ShardTopology",
    "shard_of_artifact",
    "BROKER_STRATEGIES", "BrokerConfig", "CoherenceBroker",
    "InvariantViolation", "ReadResult", "WriteResult",
    "BatchDecider", "BatchDecision", "resolve_decide_backend",
    "HostL1Directory", "L1Entry", "ShardedCoherenceBroker",
    "CoherentClient", "DeltaMismatch", "ServicePortal",
    "SyncCoherentClient", "make_clients",
    "CoherentTool", "ToolResult", "autogen_functions", "crewai_tool",
    "langgraph_node",
    "ServiceTrace", "StepRecord", "replay_trace", "verify_broker",
    "verify_broker_content", "verify_sharded_broker",
    "LoadReport", "drive_workload",
]
