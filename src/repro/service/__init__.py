"""Artifact-coherence service: the paper's reference implementation as
a servable async system (contribution 5).

Public surface (stable import paths for examples and docs):

  * :class:`CoherenceBroker` / :class:`BrokerConfig` - the asyncio
    single-writer authority with micro-batched coherence decisions;
  * :class:`CoherentClient` / :func:`make_clients` /
    :class:`ServicePortal` / :class:`SyncCoherentClient` - per-agent
    clients (async-native, plus a sync bridge for frameworks);
  * :class:`CoherentTool`, :func:`langgraph_node`, :func:`crewai_tool`,
    :func:`autogen_functions` - the thin framework adapter layer;
  * :class:`ServiceTrace` / :func:`replay_trace` /
    :func:`verify_broker` - oracle-replayable decision traces;
  * :func:`drive_workload` / :class:`LoadReport` - the concurrent load
    generator over workload-zoo rate matrices.
"""

from repro.service.broker import (BROKER_STRATEGIES, BrokerConfig,
                                  CoherenceBroker, InvariantViolation,
                                  ReadResult, WriteResult)
from repro.service.batching import (BatchDecider, BatchDecision,
                                    resolve_decide_backend)
from repro.service.client import (CoherentClient, DeltaMismatch,
                                  ServicePortal, SyncCoherentClient,
                                  make_clients)
from repro.service.adapters import (CoherentTool, ToolResult,
                                    autogen_functions, crewai_tool,
                                    langgraph_node)
from repro.service.trace import (ServiceTrace, StepRecord, replay_trace,
                                 verify_broker, verify_broker_content)
from repro.service.loadgen import LoadReport, drive_workload

__all__ = [
    "BROKER_STRATEGIES", "BrokerConfig", "CoherenceBroker",
    "InvariantViolation", "ReadResult", "WriteResult",
    "BatchDecider", "BatchDecision", "resolve_decide_backend",
    "CoherentClient", "DeltaMismatch", "ServicePortal",
    "SyncCoherentClient", "make_clients",
    "CoherentTool", "ToolResult", "autogen_functions", "crewai_tool",
    "langgraph_node",
    "ServiceTrace", "StepRecord", "replay_trace", "verify_broker",
    "verify_broker_content",
    "LoadReport", "drive_workload",
]
