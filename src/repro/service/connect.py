"""Topology-neutral client entry: ``repro.service.connect(...)``.

Callers say *what* fleet they are (agents, artifacts, protocol knobs)
and at most *how wide* the authority plane should be (``shards=``,
``hosts=``); the resolver picks the implementation - the single
asyncio broker for a trivial topology, the sharded authority plane
(with per-host L1 directories) otherwise.  Client code is identical
either way::

    from repro import service

    async with service.connect(n_agents=8,
                               artifacts=("plan", "result"),
                               shards=2, hosts=2) as broker:
        await broker.read(agent=0, artifact="plan")

    with service.connect(n_agents=4, artifacts=("plan",),
                         sync=True) as portal:     # thread-loop bridge
        portal.client(0).read("plan")

Shard count, artifact placement and L1 host mapping are deployment
facts, not protocol facts - nothing about coherence semantics leaks
through this boundary (the K=4 ledger is bit-identical to K=1,
oracle-enforced), so callers never branch on the topology.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.configs.coherence import CoherenceConfig
from repro.service.broker import CoherenceBroker
from repro.service.sharding import ShardedCoherenceBroker


def resolve_broker(config: CoherenceConfig,
                   contents: Optional[Dict[str, Sequence[int]]] = None):
    """Pick the authority implementation this topology needs.

    Trivial topology (1 shard, 1 host) -> the plain single-writer
    ``CoherenceBroker`` (byte-identical to the pre-sharding service);
    anything wider -> ``ShardedCoherenceBroker``.  Legacy flat
    ``BrokerConfig``s are lifted into the layered config first."""
    if not hasattr(config, "topology"):      # legacy BrokerConfig
        config = config.coherence_config()
    if config.topology.trivial:
        return CoherenceBroker(config.broker_view(), contents)
    return ShardedCoherenceBroker(config, contents)


def connect(config: Optional[CoherenceConfig] = None, *,
            n_agents: Optional[int] = None,
            artifacts: Optional[Sequence[str]] = None,
            contents: Optional[Dict[str, Sequence[int]]] = None,
            sync: bool = False, **knobs):
    """Build an authority handle without naming its implementation.

    Either pass a prebuilt ``CoherenceConfig`` (or legacy
    ``BrokerConfig``), or flat knobs (``n_agents`` + ``artifacts``
    plus any core / service / topology field, with ``shards`` /
    ``hosts`` aliases) and the layered config is assembled here.

    Returns an *unstarted* broker - use ``async with`` (or ``await
    .start()``).  With ``sync=True`` returns a started
    ``ServicePortal`` (its own event loop on a daemon thread) for
    frameworks that do not run asyncio; use ``with``.
    """
    if config is None:
        if n_agents is None or artifacts is None:
            raise TypeError(
                "connect() needs either a config or both n_agents= "
                "and artifacts=")
        config = CoherenceConfig.make(n_agents, artifacts, **knobs)
    else:
        if knobs or n_agents is not None or artifacts is not None:
            raise TypeError(
                "pass either a prebuilt config or flat knobs, not both")
        if not hasattr(config, "topology"):  # legacy BrokerConfig
            config = config.coherence_config()
    if sync:
        from repro.service.client import ServicePortal
        return ServicePortal(config, contents)
    return resolve_broker(config, contents)
