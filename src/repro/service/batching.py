"""Micro-batching decision layer for the artifact-coherence broker.

The broker never decides one request at a time: in-flight read/write
requests are coalesced into a *micro-batch* (at most one per agent) and
resolved by ONE call into the coherence state machine - the service
analog of the fused sweep engine, which amortizes *compilation* across
a grid the way this layer amortizes *dispatch* across concurrent
clients.

Two interchangeable execution routes, both bit-exact with the
simulator (and therefore with the four-way differential oracle):

  ``scan``    one jitted ``acs.apply_actions`` call - literally the
              simulation's serialized agent pass, compiled once per
              static broker config (module-level jit cache, same
              pattern as ``repro.sim.engine``).  Covers every
              invalidation strategy plus K-staleness enforcement.
  ``pallas``  one ``kernels.mesi_transition.mesi_decision_batch`` call:
              the batched MESI transition kernel over prefix-replicated
              sims, which yields per-request outcomes from the kernel's
              own counters.  Covers the differential strategies
              (lazy / eager / access_count) with ``max_stale_steps=0``;
              staleness diagnostics are scan-route-only, mirroring the
              oracle's Pallas scope note.

``auto`` resolves to the kernel route on a real TPU backend (where the
sim engine also routes ticks through the kernel) and to ``scan``
elsewhere; ``REPRO_SERVICE_DECIDE`` forces either.
"""

from __future__ import annotations

import functools
import os
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acs
from repro.obs import runtime as obs_runtime
from repro.kernels.backend import interpret_default
from repro.kernels.chunk_diff import (chunk_tick_pallas, chunk_tick_ref,
                                      resolve_chunk_route)
from repro.kernels.mesi_transition import mesi_decision_batch

#: strategies the kernel route supports (== oracle DIFFERENTIAL scope).
KERNEL_STRATEGIES = (acs.LAZY, acs.EAGER, acs.ACCESS_COUNT)

#: ACSMetrics content-plane counters forwarded as the wire-byte delta.
_WIRE_FIELDS = ("delta_bytes", "full_bytes", "n_chunks_fetched")


class BatchDecision(NamedTuple):
    """Host-side result of one coalesced decision pass."""

    miss: np.ndarray     # (n,) bool: request triggered a coherence fill
    version: np.ndarray  # (n,) int32: version served at the agent's slot
    ledger_delta: dict   # exact integer counter deltas for this batch
    #: (n, C) bool chunks each fill shipped (content plane; else None)
    fetched_chunks: np.ndarray | None = None
    #: exact byte-ledger deltas (content plane; else None)
    wire_delta: dict | None = None


def _kernel_supported(cfg: acs.ACSConfig) -> bool:
    return (cfg.strategy in KERNEL_STRATEGIES
            and cfg.max_stale_steps == 0)


def resolve_decide_backend(cfg: acs.ACSConfig,
                           backend: str = "auto") -> str:
    """'scan' | 'pallas' for a broker with static config ``cfg``."""
    forced = os.environ.get("REPRO_SERVICE_DECIDE", backend)
    if forced == "scan":
        return "scan"
    if forced == "pallas":
        if not _kernel_supported(cfg):
            raise ValueError(
                "pallas decision route covers lazy/eager/access_count "
                "with max_stale_steps=0; use backend='scan' for "
                f"strategy={acs.STRATEGY_NAMES[cfg.strategy]} "
                f"max_stale_steps={cfg.max_stale_steps}")
        return "pallas"
    if forced != "auto":
        raise ValueError(f"unknown decision backend {forced!r}")
    return ("pallas" if not interpret_default() and _kernel_supported(cfg)
            else "scan")


@functools.lru_cache(maxsize=None)
def _scan_decider(cfg: acs.ACSConfig):
    """One compiled serialized-authority pass per static broker config;
    every micro-batch of the broker's lifetime reuses it.  For chunked
    configs the pass also carries the content plane (the per-agent
    dirty chunk masks become a traced operand)."""

    label = (f"agents={cfg.n_agents} artifacts={cfg.n_artifacts} "
             f"strategy={acs.STRATEGY_NAMES[cfg.strategy]}")

    if acs.content_enabled(cfg):
        def fn(arrays, met, acts, arts, writes, write_chunks):
            # trace-time side effect: fires once per (re)trace, never
            # during compiled execution (engine trace-counter pattern)
            obs_runtime.note_compile("scan", label)
            return acs.apply_actions(cfg, arrays, met, acts, arts,
                                     writes, write_chunks=write_chunks)
    else:
        def fn(arrays, met, acts, arts, writes):
            obs_runtime.note_compile("scan", label)
            return acs.apply_actions(cfg, arrays, met, acts, arts,
                                     writes)

    return jax.jit(fn)


#: ACSMetrics counter fields forwarded into the broker's token ledger.
_LEDGER_FIELDS = ("fetch_tokens", "push_tokens", "signal_tokens",
                  "n_fetches", "n_hits", "n_reads", "n_writes",
                  "n_invalidation_signals")

#: kernel counter slot -> ledger field (mesi_transition layout).
_KERNEL_SLOTS = {"fetch_tokens": 0, "signal_tokens": 1, "push_tokens": 2,
                 "n_fetches": 3, "n_hits": 4,
                 "n_invalidation_signals": 5}


class BatchDecider:
    """Stateful decision engine: owns the directory arrays and applies
    one coalesced micro-batch per call.

    The broker is the *single writer* of this state - only the flush
    task calls :meth:`decide`, which is what makes SWMR hold under true
    asyncio interleaving (enforced with a reentrancy guard, checked by
    the invariant suite after every batch).
    """

    def __init__(self, cfg: acs.ACSConfig, backend: str = "auto",
                 device=None) -> None:
        self.cfg = cfg
        self.backend = resolve_decide_backend(cfg, backend)
        self.arrays = acs.init_arrays(cfg)
        self.metrics = acs.init_metrics()
        #: device this authority's directory lives on.  The sharded
        #: plane pins each shard's decider to its own device of the
        #: sweep mesh (``launch.mesh.shard_devices``), so every shard's
        #: serialized pass runs as its own device program - the
        #: service-plane analog of the sharded sweep grids.
        self.device = device
        if device is not None:
            self.arrays = jax.device_put(self.arrays, device)
            self.metrics = jax.device_put(self.metrics, device)
        self._scan = _scan_decider(cfg) if self.backend == "scan" else None
        self._deciding = False
        self._warmed = False

    # ------------------------------------------------------------------
    def decide(self, acts: np.ndarray, arts: np.ndarray,
               writes: np.ndarray,
               write_chunks: np.ndarray | None = None) -> BatchDecision:
        """Resolve one micro-batch (at most one request per agent).

        ``write_chunks`` (n, C) bool is required for chunked configs:
        the *measured* dirty chunk mask of each write in the batch
        (the broker diffs actual content digests)."""
        if self._deciding:
            raise RuntimeError(
                "re-entrant decide(): the broker's single-writer "
                "discipline was violated")
        if acs.content_enabled(self.cfg) and write_chunks is None:
            raise ValueError("chunked decider needs write_chunks masks")
        self._deciding = True
        t0 = time.perf_counter()
        try:
            if self.backend == "scan":
                return self._decide_scan(acts, arts, writes,
                                         write_chunks)
            return self._decide_pallas(acts, arts, writes, write_chunks)
        finally:
            if not self._warmed:
                # first-call wall time = compile + first dispatch (the
                # portable proxy for Pallas lowering, which happens
                # inside pallas_call where we own no Python body)
                self._warmed = True
                obs_runtime.note_warmup(
                    self.backend, time.perf_counter() - t0,
                    f"agents={self.cfg.n_agents} "
                    f"artifacts={self.cfg.n_artifacts}")
            self._deciding = False

    # ------------------------------------------------------------------
    def _decide_scan(self, acts, arts, writes,
                     write_chunks) -> BatchDecision:
        content = acs.content_enabled(self.cfg)
        before = {f: int(getattr(self.metrics, f))
                  for f in _LEDGER_FIELDS + (_WIRE_FIELDS if content
                                             else ())}
        args = [self.arrays, self.metrics, jnp.asarray(acts, bool),
                jnp.asarray(arts, jnp.int32), jnp.asarray(writes, bool)]
        if content:
            args.append(jnp.asarray(write_chunks, bool))
        self.arrays, self.metrics, out = self._scan(*args)
        delta = {f: int(getattr(self.metrics, f)) - before[f]
                 for f in _LEDGER_FIELDS}
        wire = ({f: int(getattr(self.metrics, f)) - before[f]
                 for f in _WIRE_FIELDS} if content else None)
        return BatchDecision(
            miss=np.asarray(out.miss, bool),
            version=np.asarray(out.version, np.int32),
            ledger_delta=delta,
            fetched_chunks=(np.asarray(out.fetched_chunks, bool)
                            if content else None),
            wire_delta=wire)

    def _decide_pallas(self, acts, arts, writes,
                       write_chunks) -> BatchDecision:
        a = self.arrays
        st, ver, sy, rd, cnt, miss, served = mesi_decision_batch(
            a.state, a.version, a.last_sync, a.reads_since_fetch,
            np.asarray(acts, bool), np.asarray(arts, np.int32),
            np.asarray(writes, bool),
            artifact_tokens=self.cfg.artifact_tokens,
            eager=self.cfg.strategy == acs.EAGER,
            access_k=(self.cfg.access_k
                      if self.cfg.strategy == acs.ACCESS_COUNT else 0),
            signal_tokens=acs.SIGNAL_TOKENS)
        acts_np = np.asarray(acts, bool)
        writes_np = np.asarray(writes, bool)
        cnt_np = np.asarray(cnt, np.int64)
        delta = {f: int(cnt_np[slot])
                 for f, slot in _KERNEL_SLOTS.items()}
        # the kernel tracks token counters only; action counts come from
        # the batch itself (same derivation as oracle.replay_pallas).
        delta["n_reads"] = int((acts_np & ~writes_np).sum())
        delta["n_writes"] = int((acts_np & writes_np).sum())
        # agent_actions is a scan-path diagnostic (staleness clocks);
        # each acting agent performed exactly one action this batch.
        self.arrays = a._replace(
            state=st, version=ver, last_sync=sy, reads_since_fetch=rd,
            agent_actions=a.agent_actions + jnp.asarray(acts_np, jnp.int32))
        self.metrics = self.metrics._replace(**{
            f: getattr(self.metrics, f) + delta[f]
            for f in _LEDGER_FIELDS})
        fetched = wire = None
        if acs.content_enabled(self.cfg):
            # Content plane rides the same serialization order: the
            # chunk tick consumes the per-request miss bits and the
            # measured dirty masks.  REPRO_CHUNK_DIFF=scan forces the
            # pure-jnp reference (bit-identical; oracle-checked).
            tick = (chunk_tick_ref
                    if resolve_chunk_route("pallas") == "scan"
                    else chunk_tick_pallas)
            wact = (acts_np & writes_np).astype(np.int32)
            cv, cs, dirty, fetched_b, ccnt = tick(
                self.arrays.chunk_version[None],
                self.arrays.chunk_sync[None],
                self.arrays.chunk_dirty[None],
                np.asarray(miss, np.int32)[None], wact[None],
                np.asarray(arts, np.int32)[None],
                np.asarray(write_chunks, np.int32)[None],
                artifact_tokens=self.cfg.artifact_tokens,
                chunk_tokens=self.cfg.chunk_tokens,
                signal_tokens=acs.SIGNAL_TOKENS)
            self.arrays = self.arrays._replace(
                chunk_version=cv[0], chunk_sync=cs[0],
                chunk_dirty=dirty[0])
            ccnt_np = np.asarray(ccnt[0], np.int64)
            wire = {"delta_bytes": int(ccnt_np[0]),
                    "full_bytes": int(ccnt_np[1]),
                    "n_chunks_fetched": int(ccnt_np[2])}
            self.metrics = self.metrics._replace(**{
                f: getattr(self.metrics, f) + wire[f]
                for f in _WIRE_FIELDS})
            fetched = np.asarray(fetched_b[0], bool)
        return BatchDecision(miss=np.asarray(miss, bool),
                             version=np.asarray(served, np.int32),
                             ledger_delta=delta,
                             fetched_chunks=fetched, wire_delta=wire)
