"""Sharded authority plane: K directory shards + per-host L1s.

The single-broker authority (``repro.service.broker``) serializes ALL
directory mutation through one flush task.  That is the correctness
anchor - and the scaling bottleneck: every fleet in the building funnels
through one decider.  This module partitions the authority **by
artifact** across K broker shards (``configs.shard_of_artifact``):

  * SWMR survives sharding because exclusivity is *per-artifact* - an
    artifact's entire history (reads, upgrades, commits, invalidations)
    serializes through exactly one shard, so no cross-shard interleaving
    can ever produce two M holders;
  * every shard is a full, unmodified ``CoherenceBroker`` pinned to its
    own device (``launch.mesh.shard_devices``), so each shard's
    micro-batches run through its own ``mesi_decision_batch`` /
    ``apply_actions`` device program;
  * the shards' interleaved batch commits are recorded into ONE global
    ``ServiceTrace`` in event-loop commit order - a serializable order
    the four-way oracle replays, and ``sim.oracle.check_sharded_trace``
    additionally re-derives every shard's local history from it
    (cross-shard conformance leg).

In front of the L2 authority sits a per-host **L1 directory**
(:class:`HostL1Directory`): each host caches the (version, content) it
last saw per artifact, so a same-host agent's fill is served from the
host's copy without a cross-shard hop.  The L1 plane is *attribution
only* - it never changes what the decision plane charges (which is what
keeps the K=4 ledger bit-identical to K=1); it splits each fill's wire
bytes into ``l1_bytes`` (served host-locally) vs ``l2_bytes`` (shipped
from the authority).  Writes drive an explicit L1-invalidation path:
the commit invalidates the artifact's entry on every host, then the
writer's host adopts the committed copy.  The invariant bound
``topology.l1_max_version_lag`` says a *valid* L1 entry may never be
observed more than that many versions behind the authority; a stale
entry surviving past the bound raises ``InvariantViolation``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Sequence

import numpy as np

from repro.content.chunks import BYTES_PER_TOKEN
from repro.core.protocol import TokenLedger
from repro.obs.stats import unified_stats
from repro.obs.telemetry import Telemetry
from repro.service.batching import resolve_decide_backend
from repro.service.broker import (CoherenceBroker, InvariantViolation,
                                  ReadResult, WriteResult)
from repro.service.trace import ServiceTrace


class L1Entry(NamedTuple):
    """One host's cached copy of an artifact (version-exact)."""

    version: int
    content: tuple


class HostL1Directory:
    """Per-host L1 cache of artifact copies in front of the L2 shards.

    Serve rule: an entry is usable for a fill only on an **exact
    version match** with byte-equal content - anything else is an L2
    fill (and refreshes the entry).  The invalidation path keeps valid
    entries within ``max_version_lag`` of the authority; the white-box
    check (:meth:`check`) proves it.
    """

    def __init__(self, host: int, max_version_lag: int = 0) -> None:
        self.host = host
        self.max_version_lag = max_version_lag
        self.entries: Dict[str, L1Entry] = {}
        self.n_invalidations = 0

    def lookup(self, artifact: str) -> Optional[L1Entry]:
        return self.entries.get(artifact)

    def fill(self, artifact: str, version: int, content) -> None:
        self.entries[artifact] = L1Entry(int(version), tuple(content))

    def invalidate(self, artifact: str) -> bool:
        """Drop the entry; True if one was actually held."""
        if self.entries.pop(artifact, None) is not None:
            self.n_invalidations += 1
            return True
        return False

    def check(self, artifact: str, authority_version: int) -> None:
        """Raise if a valid entry sits past the staleness bound - the
        L1-invalidation path failed to keep this host coherent."""
        entry = self.entries.get(artifact)
        if entry is None:
            return
        lag = int(authority_version) - entry.version
        if lag > self.max_version_lag:
            raise InvariantViolation(
                f"L1 staleness bound violated: host {self.host} holds "
                f"{artifact!r} at version {entry.version}, authority is "
                f"at {authority_version} (lag {lag} > bound "
                f"{self.max_version_lag})")


class ShardedCoherenceBroker:
    """K-shard authority plane behind the single-broker client API.

    Use as an async context manager, exactly like ``CoherenceBroker``::

        async with ShardedCoherenceBroker(cfg) as broker:
            await broker.read(agent=0, artifact="plan")

    ``cfg`` is a layered ``repro.configs.CoherenceConfig``; its
    ``topology`` layer fixes the shard count, host count and L1 bound.
    The blessed constructor is ``repro.service.connect(...)``, which
    resolves the topology and picks this class or the plain broker.
    """

    #: lets ``trace.verify_broker`` dispatch to the sharded verifier.
    is_sharded = True

    def __init__(self, config,
                 contents: Optional[Dict[str, Sequence[int]]] = None
                 ) -> None:
        if not hasattr(config, "topology"):
            raise TypeError(
                "ShardedCoherenceBroker needs a layered "
                "repro.configs.CoherenceConfig (BrokerConfig has no "
                "topology layer); build one with CoherenceConfig.make "
                "or repro.service.connect(...)")
        if config.core.max_stale_steps > 0:
            raise ValueError(
                "sharded authority does not serve simulator K-staleness"
                " (per-shard action clocks diverge from the global "
                "clock); bound L1 staleness with l1_max_version_lag")
        from repro.launch.mesh import shard_devices

        self.config = config
        self.names = tuple(config.artifacts)
        self.n_shards = config.topology.n_shards
        self.artifact_shards = config.artifact_shards()
        self._shard_cols = config.shard_artifact_indices()
        self._shard_of_name = {name: self.artifact_shards[d]
                               for d, name in enumerate(self.names)}
        devices = shard_devices(self.n_shards)

        #: the ONE global audit trace, in event-loop commit order
        self.trace = ServiceTrace.for_broker(config.broker_view())
        self.trace.n_shards = self.n_shards
        self.trace.artifact_shards = self.artifact_shards
        self._capture = config.service.capture_trace
        self.n_batches = 0

        #: ONE telemetry plane shared by every shard: sub-brokers stamp
        #: their own ``shard=k`` label into the same registry, so the
        #: fleet-wide MESI counters aggregate without a collector.
        self.telemetry: Optional[Telemetry] = None
        if config.service.telemetry:
            self.telemetry = Telemetry(
                config.n_agents, strategy=config.core.strategy,
                backend=resolve_decide_backend(config.acs_config(),
                                               config.service.backend),
                n_shards=self.n_shards,
                n_hosts=config.topology.n_hosts)

        self.brokers = []
        for shard in range(self.n_shards):
            view = config.shard_view(shard)
            # sub-brokers never capture: the global trace above is the
            # single authoritative history (per-shard histories are
            # re-derived from it by the cross-shard oracle leg)
            view = dataclasses.replace(view, service=dataclasses.replace(
                view.service, capture_trace=False))
            sub_contents = None
            if contents is not None:
                sub_contents = {name: contents[name]
                                for name in view.artifacts
                                if name in contents}
            self.brokers.append(CoherenceBroker(
                view.broker_view(), sub_contents,
                on_commit=functools.partial(self._commit, shard),
                device=devices[shard],
                telemetry=self.telemetry, shard=shard))
        self.brokers = tuple(self.brokers)

        self.l1 = tuple(
            HostL1Directory(h, config.topology.l1_max_version_lag)
            for h in range(config.topology.n_hosts))
        #: fill attribution (never touches the token ledger): how many
        #: fills / wire bytes the L1 plane served host-locally vs what
        #: crossed to the L2 authority shards
        self.l1_wire = {"l1_fills": 0, "l2_fills": 0,
                        "l1_bytes": 0, "l2_bytes": 0}

    # ------------------------------------------------------- lifecycle
    async def start(self) -> "ShardedCoherenceBroker":
        for broker in self.brokers:
            await broker.start()
        return self

    async def stop(self) -> None:
        for broker in self.brokers:
            await broker.stop()
        if self.config.service.check_invariants:
            self.check_l1()

    async def __aenter__(self) -> "ShardedCoherenceBroker":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ------------------------------------------------------ client API
    def shard_of(self, artifact: str) -> int:
        try:
            return self._shard_of_name[artifact]
        except KeyError:
            raise KeyError(
                f"unknown artifact {artifact!r}; registered: "
                f"{list(self.names)}") from None

    def broker_of(self, artifact: str) -> CoherenceBroker:
        return self.brokers[self.shard_of(artifact)]

    def host_of(self, agent: int) -> int:
        return self.config.topology.host_of(agent)

    async def read(self, agent: int, artifact: str) -> ReadResult:
        result = await self.broker_of(artifact).read(agent, artifact)
        if not result.hit:
            self._attribute_fill(agent, artifact, result)
        return result

    async def write(self, agent: int, artifact: str,
                    content: Optional[Sequence[int]] = None
                    ) -> WriteResult:
        result = await self.broker_of(artifact).write(agent, artifact,
                                                      content)
        self._l1_on_commit(agent, artifact, result.version)
        return result

    # -------------------------------------------------------- L1 plane
    def _fill_bytes(self, result: ReadResult) -> int:
        if result.delta is not None:     # content plane: measured delta
            return sum(len(chunk) for _, chunk in result.delta) \
                * BYTES_PER_TOKEN
        return self.config.core.artifact_tokens * BYTES_PER_TOKEN

    def _attribute_fill(self, agent: int, artifact: str,
                        result: ReadResult) -> None:
        """Attribute one coherence fill to the L1 or the L2 plane.

        Future resolution order IS the authority's serialization order
        (batches commit in event-loop order; within a batch futures
        resolve in ascending agent order), so this bookkeeping observes
        commits exactly as the decision plane serialized them."""
        host = self.l1[self.host_of(agent)]
        host.check(artifact, result.version)
        entry = host.lookup(artifact)
        nbytes = self._fill_bytes(result)
        if (entry is not None and entry.version == result.version
                and entry.content == result.content):
            # a same-host peer already holds this exact version: the
            # delta never leaves the host, no cross-shard hop
            self.l1_wire["l1_fills"] += 1
            self.l1_wire["l1_bytes"] += nbytes
            level = "l1"
        else:
            self.l1_wire["l2_fills"] += 1
            self.l1_wire["l2_bytes"] += nbytes
            host.fill(artifact, result.version, result.content)
            level = "l2"
        if self.telemetry is not None:
            self.telemetry.record_l1_fill(host.host, level, nbytes)

    def _l1_on_commit(self, agent: int, artifact: str,
                      version: int) -> None:
        """The explicit L1-invalidation path: a commit invalidates the
        artifact on EVERY host, then the writer's host adopts the
        committed copy (if it is still the authority's current one)."""
        for host in self.l1:
            if host.invalidate(artifact) and self.telemetry is not None:
                self.telemetry.record_l1_invalidation(host.host)
        broker = self.broker_of(artifact)
        local = broker.artifact_index(artifact)
        if int(broker.versions[local]) == int(version):
            self.l1[self.host_of(agent)].fill(
                artifact, version, tuple(broker.store.get(artifact)))

    def check_l1(self) -> None:
        """White-box L1/L2 invariant sweep: every valid entry on every
        host is within the version-lag bound, and lag-0 entries are
        byte-identical to the authority copy."""
        for host in self.l1:
            for artifact, entry in host.entries.items():
                broker = self.broker_of(artifact)
                local = broker.artifact_index(artifact)
                authority = int(broker.versions[local])
                host.check(artifact, authority)
                if (entry.version == authority and entry.content
                        != tuple(broker.store.get(artifact))):
                    raise InvariantViolation(
                        f"L1 content diverged from authority: host "
                        f"{host.host} holds {artifact!r} at version "
                        f"{entry.version} with different bytes")

    # ------------------------------------------------- trace assembly
    def _commit(self, shard: int, sub: CoherenceBroker,
                commit: dict) -> None:
        """Per-shard commit hook: remap the shard-local batch onto the
        global artifact index space and append it (tagged with its
        shard) to the global trace, in event-loop commit order."""
        self.n_batches += 1
        if not self._capture:
            return
        acts = commit["acts"]
        cols = np.asarray(self._shard_cols[shard], np.int32)
        arts = np.zeros_like(commit["arts"])
        arts[acts] = cols[commit["arts"][acts]]
        self.trace.append_step(acts, arts, commit["writes"],
                               commit["miss"], commit["version"],
                               commit["latencies"],
                               write_chunks=commit["write_chunks"],
                               shard=shard,
                               decide_s=commit["busy_s"],
                               batch_size=int(np.asarray(acts).sum()))

    # --------------------------------------------------- assembled views
    def _assemble(self, attr: str, agent_axis: bool) -> np.ndarray:
        """Stitch per-shard directory columns back into the global
        (n_agents, n_artifacts, ...) layout."""
        parts = [np.asarray(getattr(b, attr)) for b in self.brokers]
        ref = parts[0]
        m = len(self.names)
        shape = ((ref.shape[0], m) + ref.shape[2:] if agent_axis
                 else (m,) + ref.shape[1:])
        out = np.zeros(shape, ref.dtype)
        for shard, cols in enumerate(self._shard_cols):
            part = parts[shard]
            for local, d in enumerate(cols):
                if agent_axis:
                    out[:, d] = part[:, local]
                else:
                    out[d] = part[local]
        return out

    @property
    def directory_state(self) -> np.ndarray:
        """(n_agents, n_artifacts) MESI matrix across all shards."""
        return self._assemble("directory_state", agent_axis=True)

    @property
    def versions(self) -> np.ndarray:
        return self._assemble("versions", agent_axis=False)

    @property
    def last_sync(self) -> np.ndarray:
        parts = [np.asarray(b.decider.arrays.last_sync, np.int32)
                 for b in self.brokers]
        n = self.config.n_agents
        out = np.zeros((n, len(self.names)), np.int32)
        for shard, cols in enumerate(self._shard_cols):
            for local, d in enumerate(cols):
                out[:, d] = parts[shard][:, local]
        return out

    @property
    def ledger(self) -> TokenLedger:
        """Summed token ledger - per-artifact charges are independent,
        so the sum over shards IS the global ledger (oracle-checked)."""
        led = TokenLedger()
        for broker in self.brokers:
            led = led.merge(broker.ledger)
        return led

    @property
    def wire(self) -> dict:
        out = {"delta_bytes": 0, "full_bytes": 0, "n_chunks_fetched": 0}
        for broker in self.brokers:
            for key in out:
                out[key] += broker.wire[key]
        return out

    @property
    def chunked(self) -> bool:
        return self.config.core.chunk_tokens > 0

    def decision_busy(self) -> tuple:
        """Per-shard seconds spent inside the decider - the serialized
        per-authority bottleneck.  Under the shard-per-host deployment
        the shards decide concurrently, so the plane's makespan is the
        MAX over shards (the decision-capacity metric of the bench)."""
        return tuple(broker.decide_busy_s for broker in self.brokers)

    # ----------------------------------------------------------- stats
    def stats(self) -> dict:
        """The unified stats mapping (``repro.obs.stats``): canonical
        nested schema plus the legacy flat aliases as a deprecation
        shim (identical schema to the plain broker's)."""
        return unified_stats(self)
