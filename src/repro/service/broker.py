"""Artifact Coherence Broker: an asyncio single-writer authority.

The simulator answers "how many tokens would a fleet spend"; this
module answers "serve the fleet".  Many concurrent agent clients issue
read/write requests against a shared artifact store; the broker is the
serialization point (paper A2/AS1): all directory mutation happens on
ONE flush task, so the three verified invariants (SWMR, monotonic
versioning, K-bounded staleness) hold under true asyncio interleaving
by construction - and are *checked* after every micro-batch, not
assumed.

State machinery is reused, not reimplemented:

  * content plane: ``repro.core.protocol``'s ``ArtifactStore`` +
    ``EventBus`` (``VERSION_UPDATE`` messages on every commit) +
    ``TokenLedger`` accounting;
  * decision plane: ``repro.service.batching`` - coalesced micro-batches
    resolved by the simulator's own serialized authority pass
    (``acs.apply_actions``) or the batched Pallas MESI kernel;
  * audit plane: every decision lands in a ``ServiceTrace``
    (``repro.service.trace``) that replays bit-for-bit through the
    four-way differential oracle, closing the live-service <->
    conformance loop.
"""

from __future__ import annotations

import asyncio
import collections
import contextvars
import dataclasses
import time
import warnings
from typing import Callable, Dict, NamedTuple, Optional, Sequence

import numpy as np

from repro.content.chunks import (BYTES_PER_TOKEN, ChunkStore,
                                  diff_chunks)
from repro.core import acs, invariants
from repro.core.protocol import (ArtifactStore, EventBus, Message,
                                 TokenLedger)
from repro.core.states import MESIState
from repro.obs.stats import unified_stats
from repro.obs.telemetry import BatchObservation, Telemetry
from repro.service.batching import BatchDecider
from repro.service.trace import ServiceTrace

_E = int(MESIState.E)

#: strategies the broker serves.  Broadcast is the *baseline* the bench
#: compares against analytically; TTL epochs are defined in terms of the
#: simulator's logical step clock, which a live service does not have.
BROKER_STRATEGIES = ("lazy", "eager", "access_count")


class InvariantViolation(AssertionError):
    """A verified CCS invariant failed on live broker state."""


#: set while ``CoherenceConfig.broker_view()`` constructs the flat view,
#: so only *direct* legacy construction triggers the deprecation shim.
_VIEW_CONSTRUCTION = contextvars.ContextVar("broker_view_construction",
                                            default=False)
_LEGACY_WARNED = False


def _warn_legacy_broker_config() -> None:
    global _LEGACY_WARNED
    if _LEGACY_WARNED:
        return
    _LEGACY_WARNED = True
    warnings.warn(
        "constructing BrokerConfig directly is deprecated: it is now a "
        "thin frozen view over the layered "
        "repro.configs.CoherenceConfig (core -> service -> shard "
        "topology); build one with CoherenceConfig.make(...) and "
        "connect()/broker_view().  Direct construction keeps working "
        "(ledgers are byte-identical) but loses the topology layer.",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class BrokerConfig:
    """Static single-authority service parameters (baked into the
    compiled decider).

    Since the layered-config redesign this is a *thin frozen view* over
    ``repro.configs.CoherenceConfig``'s core + service layers - the
    blessed constructors are ``CoherenceConfig.broker_view()`` and
    ``repro.service.connect(...)``.  Direct construction is a
    deprecation shim: it warns once per process and keeps working
    byte-identically."""

    n_agents: int
    artifacts: tuple
    artifact_tokens: int = 4096
    strategy: str = "lazy"
    access_k: int = 8
    max_stale_steps: int = 0       # 0 disables K-staleness enforcement
    batch_window: float = 0.0      # extra coalescing wait (s); 0 = one
                                   # event-loop pass
    max_batch: int = 0             # 0 = up to n_agents requests
    backend: str = "auto"          # decision route: auto | scan | pallas
    check_invariants: bool = True
    #: audit-trace capture.  The trace grows one StepRecord per batch,
    #: so indefinitely-running deployments (the TCP frontend) disable
    #: it; bounded load runs keep it on for oracle replay.
    capture_trace: bool = True
    #: ring-buffer size for per-decision latency samples (stats
    #: percentiles); bounds the broker's memory under open-ended load.
    latency_window: int = 1 << 20
    #: chunk-granular content plane (``repro.content``): with
    #: ``chunk_tokens > 0`` the broker content-addresses every
    #: artifact's chunks, a write's dirty set is *measured* by digest
    #: diff, and a read miss ships only the reader's stale chunks
    #: (``ReadResult.delta``).  0 = whole-artifact payloads.
    chunk_tokens: int = 0
    #: telemetry plane (``repro.obs``): MESI perf counters, span
    #: tracing and the metrics-conformance oracle leg.  Off = the
    #: broker keeps only the ledger/trace it always kept.
    telemetry: bool = True

    def __post_init__(self):
        if not _VIEW_CONSTRUCTION.get():
            _warn_legacy_broker_config()
        if self.strategy not in BROKER_STRATEGIES:
            raise ValueError(
                f"broker serves {BROKER_STRATEGIES}, got "
                f"{self.strategy!r} (broadcast is the baseline, not a "
                f"servable strategy; ttl is simulation-clock-only)")
        if len(set(self.artifacts)) != len(self.artifacts):
            raise ValueError("duplicate artifact ids")
        if self.chunk_tokens > 0:
            if acs.STRATEGY_CODES[
                    self.strategy] not in acs.CONTENT_STRATEGIES:
                raise ValueError(
                    f"chunked broker serves "
                    f"{[acs.STRATEGY_NAMES[s] for s in acs.CONTENT_STRATEGIES]}"
                    f" (delta fetch is pull-only); got "
                    f"{self.strategy!r}")
            if self.max_stale_steps > 0:
                # the byte-exact oracle leg (verify_broker_content)
                # covers max_stale_steps=0 only; allowing the combo
                # would build a broker that can never be verified
                raise ValueError(
                    "chunked broker does not support K-staleness "
                    "enforcement (max_stale_steps > 0): the byte-exact "
                    "content oracle covers the pull-only invalidation "
                    "protocol without revalidation; run either "
                    "chunk_tokens=0 or max_stale_steps=0")

    def acs_config(self, n_steps: int = 1) -> acs.ACSConfig:
        return acs.ACSConfig(
            n_agents=self.n_agents, n_artifacts=len(self.artifacts),
            artifact_tokens=self.artifact_tokens, n_steps=n_steps,
            strategy=acs.STRATEGY_CODES[self.strategy],
            access_k=self.access_k,
            max_stale_steps=self.max_stale_steps,
            chunk_tokens=self.chunk_tokens)

    @classmethod
    def _from_layers(cls, coherence) -> "BrokerConfig":
        """The blessed view constructor (``CoherenceConfig.broker_view``
        calls this); suppresses the legacy-construction warning."""
        token = _VIEW_CONSTRUCTION.set(True)
        try:
            return cls(
                n_agents=coherence.n_agents,
                artifacts=tuple(coherence.artifacts),
                artifact_tokens=coherence.core.artifact_tokens,
                strategy=coherence.core.strategy,
                access_k=coherence.core.access_k,
                max_stale_steps=coherence.core.max_stale_steps,
                batch_window=coherence.service.batch_window,
                max_batch=coherence.service.max_batch,
                backend=coherence.service.backend,
                check_invariants=coherence.service.check_invariants,
                capture_trace=coherence.service.capture_trace,
                latency_window=coherence.service.latency_window,
                chunk_tokens=coherence.core.chunk_tokens,
                telemetry=coherence.service.telemetry)
        finally:
            _VIEW_CONSTRUCTION.reset(token)

    def coherence_config(self):
        """Lift this flat view back into the layered config (trivial
        topology)."""
        from repro.configs.coherence import from_broker_fields
        return from_broker_fields(
            self.n_agents, self.artifacts,
            artifact_tokens=self.artifact_tokens, strategy=self.strategy,
            access_k=self.access_k, max_stale_steps=self.max_stale_steps,
            batch_window=self.batch_window, max_batch=self.max_batch,
            backend=self.backend,
            check_invariants=self.check_invariants,
            capture_trace=self.capture_trace,
            latency_window=self.latency_window,
            chunk_tokens=self.chunk_tokens,
            telemetry=self.telemetry)


class ReadResult(NamedTuple):
    content: tuple
    version: int
    hit: bool            # False = coherence fill (tokens were charged)
    latency_s: float
    #: chunked brokers only: the actual delta payload of a miss -
    #: ((chunk_idx, chunk_tokens), ...) covering exactly the reader's
    #: stale chunks (empty tuple on a hit; ``None`` when the content
    #: plane is off).  ``content`` is always the full authority copy;
    #: ``repro.content.apply_delta(prev, delta, chunk_tokens)`` patched
    #: onto any previously-held copy reproduces it byte-for-byte.
    delta: tuple | None = None
    #: wire bytes this read cost under delta coherence (-1 when off)
    delta_bytes: int = -1


class WriteResult(NamedTuple):
    version: int
    latency_s: float
    #: chunked brokers only: chunks this commit actually dirtied
    #: (measured by content-address diff; ``None`` when off)
    dirty_chunks: tuple | None = None


@dataclasses.dataclass
class _Request:
    agent: int
    artifact: int
    is_write: bool
    content: Optional[tuple]
    future: asyncio.Future
    t_submit: float


class CoherenceBroker:
    """The single-writer directory service.

    Use as an async context manager::

        async with CoherenceBroker(cfg) as broker:
            await broker.read(agent=0, artifact="plan")
    """

    def __init__(self, config: BrokerConfig,
                 contents: Optional[Dict[str, Sequence[int]]] = None,
                 *, on_commit: Optional[Callable] = None,
                 device=None, telemetry: Optional[Telemetry] = None,
                 shard: int = 0) -> None:
        if hasattr(config, "broker_view"):   # layered CoherenceConfig
            if not config.topology.trivial:
                raise ValueError(
                    "CoherenceBroker is the single-authority shard; "
                    "non-trivial topologies need "
                    "repro.service.connect(...) / "
                    "ShardedCoherenceBroker")
            config = config.broker_view()
        self.config = config
        self.names = tuple(config.artifacts)
        self._index = {a: d for d, a in enumerate(self.names)}
        self.acs_config = config.acs_config()
        self.decider = BatchDecider(self.acs_config, config.backend,
                                    device=device)
        #: called as ``on_commit(broker, commit)`` after every committed
        #: micro-batch (the sharded authority plane uses this to build
        #: the globally-sequenced trace)
        self._on_commit = on_commit
        #: decision-plane busy time: seconds spent inside the decider
        #: (the serialized per-authority bottleneck the shard-capacity
        #: metric is built on)
        self.decide_busy_s = 0.0
        #: shard label this authority stamps on its metrics (the
        #: sharded plane passes its shard id; standalone brokers are
        #: shard 0 - the same label the conformance replay uses)
        self.shard = int(shard)
        #: the telemetry plane handle (None = disabled).  A sharded
        #: deployment hands ONE shared ``Telemetry`` to every
        #: sub-broker; a standalone broker builds its own.
        self.telemetry: Optional[Telemetry] = telemetry
        if self.telemetry is None and config.telemetry:
            self.telemetry = Telemetry(
                config.n_agents, strategy=config.strategy,
                backend=self.decider.backend)
        self.bus = EventBus()
        self.store = ArtifactStore()
        for name in self.names:
            content = (contents or {}).get(
                name, list(range(config.artifact_tokens)))
            if len(content) != config.artifact_tokens:
                raise ValueError(
                    f"artifact {name!r} content length {len(content)} != "
                    f"artifact_tokens {config.artifact_tokens} (the "
                    f"broker's accounting is fixed-slot, like the "
                    f"simulator's)")
            self.store.put(name, list(content))
        self.chunks: Optional[ChunkStore] = None
        if config.chunk_tokens > 0:
            self.chunks = ChunkStore(self.store, config.chunk_tokens)
            for name in self.names:
                self.chunks.register(name)
        #: bytes-on-wire ledger (content plane; all zero when off)
        self.wire = {"delta_bytes": 0, "full_bytes": 0,
                     "n_chunks_fetched": 0}
        self.ledger = TokenLedger()
        self.trace = ServiceTrace.for_broker(config)
        self.latencies = collections.deque(maxlen=config.latency_window)
        self.n_batches = 0
        self._pending: list = []
        self._wake = asyncio.Event()
        self._flusher_task: Optional[asyncio.Task] = None
        self._closed = False

    # ------------------------------------------------------- lifecycle
    async def start(self) -> "CoherenceBroker":
        if self._flusher_task is None:
            self._flusher_task = asyncio.get_running_loop().create_task(
                self._flusher())
        return self

    async def stop(self) -> None:
        self._closed = True
        self._wake.set()
        if self._flusher_task is not None:
            await self._flusher_task
            self._flusher_task = None

    async def __aenter__(self) -> "CoherenceBroker":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ------------------------------------------------------ client API
    def artifact_index(self, artifact: str) -> int:
        try:
            return self._index[artifact]
        except KeyError:
            raise KeyError(
                f"unknown artifact {artifact!r}; registered: "
                f"{list(self.names)}") from None

    async def read(self, agent: int, artifact: str) -> ReadResult:
        """Consume an artifact: zero tokens when the agent's coherent
        copy is valid, a full fetch otherwise."""
        return await self._submit(agent, artifact, False, None)

    async def write(self, agent: int, artifact: str,
                    content: Optional[Sequence[int]] = None
                    ) -> WriteResult:
        """Read-modify-write through the authority (upgrade -> commit).
        ``content=None`` commits a same-size revision of the current
        canonical content (pointer-semantics update)."""
        if content is not None:
            content = tuple(content)
            if len(content) != self.config.artifact_tokens:
                raise ValueError(
                    f"write of {len(content)} tokens to fixed "
                    f"{self.config.artifact_tokens}-token artifact slot")
        return await self._submit(agent, artifact, True, content)

    def _submit(self, agent: int, artifact: str, is_write: bool,
                content) -> asyncio.Future:
        if self._closed:
            raise RuntimeError("broker is stopped")
        if not 0 <= agent < self.config.n_agents:
            raise ValueError(f"agent {agent} outside [0, "
                             f"{self.config.n_agents})")
        if self._flusher_task is None:
            raise RuntimeError("broker not started - use "
                               "`async with CoherenceBroker(...)` or "
                               "await broker.start()")
        fut = asyncio.get_running_loop().create_future()
        self._pending.append(_Request(
            agent=agent, artifact=self.artifact_index(artifact),
            is_write=is_write, content=content, future=fut,
            t_submit=time.perf_counter()))
        self._wake.set()
        return fut

    # --------------------------------------------------------- flusher
    async def _flusher(self) -> None:
        while True:
            await self._wake.wait()
            self._wake.clear()
            if self._closed and not self._pending:
                return
            if self.config.batch_window > 0:
                await asyncio.sleep(self.config.batch_window)
            else:
                # one event-loop pass: every already-scheduled client
                # coroutine gets to enqueue before the batch is cut.
                await asyncio.sleep(0)
            while self._pending:
                self._flush_once()
                if self._pending:       # same-agent conflict spillover
                    await asyncio.sleep(0)
            if self._closed:
                return

    def _cut_batch(self) -> list:
        """Drain pending FIFO into a micro-batch: at most one request
        per agent (a batch is one serialized authority pass; a second
        request from the same agent belongs to the next pass)."""
        max_batch = self.config.max_batch or self.config.n_agents
        batch, rest, seen = [], [], set()
        for req in self._pending:
            if req.agent in seen or len(batch) >= max_batch:
                rest.append(req)
            else:
                seen.add(req.agent)
                batch.append(req)
        self._pending = rest
        return batch

    def _flush_once(self) -> None:
        batch = self._cut_batch()
        if not batch:
            return
        try:
            self._decide_and_resolve(batch)
        except Exception as e:       # noqa: BLE001 - fail the batch, not
            for req in batch:        # the event loop
                if not req.future.done():
                    req.future.set_exception(e)

    def _measure_write_masks(self, batch: list) -> Optional[np.ndarray]:
        """(n, C) measured dirty chunk masks for the batch's writes.

        Masks are diffed *sequentially in the authority's agent order*
        against the content each write will actually see at its
        serialization slot (two same-batch writers of one artifact:
        the second diffs against the first's content, exactly as the
        commits apply below)."""
        if self.chunks is None:
            return None
        n = self.config.n_agents
        masks = np.zeros((n, self.chunks.n_chunks_of(self.names[0])),
                         bool)
        pending: Dict[str, list] = {}
        for req in sorted(batch, key=lambda r: r.agent):
            if not req.is_write:
                continue
            name = self.names[req.artifact]
            cur = pending.get(name)
            if cur is None:
                cur = list(self.store.get(name))
            new = (list(req.content) if req.content is not None
                   else cur)
            masks[req.agent] = diff_chunks(cur, new,
                                           self.config.chunk_tokens)
            pending[name] = new
        return masks

    def _decide_and_resolve(self, batch: list) -> None:
        n = self.config.n_agents
        acts = np.zeros(n, bool)
        arts = np.zeros(n, np.int32)
        writes = np.zeros(n, bool)
        for req in batch:
            acts[req.agent] = True
            arts[req.agent] = req.artifact
            writes[req.agent] = req.is_write
        wmasks = self._measure_write_masks(batch)

        tel = self.telemetry
        state_before = (np.asarray(self.decider.arrays.state,
                                   np.int32).copy()
                        if tel is not None else None)
        queue_depth = len(batch) + len(self._pending)
        ver_before = np.asarray(self.decider.arrays.version,
                                np.int64).copy()
        t_decide = time.perf_counter()
        decision = self.decider.decide(acts, arts, writes,
                                       write_chunks=wmasks)
        busy_s = time.perf_counter() - t_decide
        self.decide_busy_s += busy_s
        ver_after = np.asarray(self.decider.arrays.version, np.int64)

        if self.config.check_invariants:
            self._check_invariants(batch, ver_before, ver_after)

        # ledger: exact integer deltas from the decision engine
        for field, delta in decision.ledger_delta.items():
            setattr(self.ledger, field,
                    getattr(self.ledger, field) + delta)
        if decision.wire_delta is not None:
            for field, delta in decision.wire_delta.items():
                self.wire[field] += delta

        # content plane + responses, in the authority's agent order
        # (reads at slot a see commits from slots < a, exactly the
        # order the decision plane serialized)
        now = time.perf_counter()
        latencies = {}
        for req in sorted(batch, key=lambda r: r.agent):
            name = self.names[req.artifact]
            version = int(decision.version[req.agent])
            latency = now - req.t_submit
            latencies[req.agent] = latency
            self.latencies.append(latency)
            if req.is_write:
                content = (list(req.content) if req.content is not None
                           else list(self.store.get(name)))
                dirty = None
                if self.chunks is not None:
                    self.chunks.put(name, content)
                    dirty = tuple(np.flatnonzero(wmasks[req.agent])
                                  .tolist())
                else:
                    self.store.put(name, content)
                self.bus.publish(Message(
                    "VERSION_UPDATE", f"agent-{req.agent}", name,
                    version, timestamp=now))
                req.future.set_result(WriteResult(version, latency,
                                                  dirty_chunks=dirty))
            else:
                delta = None
                delta_bytes = -1
                if self.chunks is not None:
                    fetched = np.flatnonzero(
                        decision.fetched_chunks[req.agent])
                    delta = self.chunks.delta(name, fetched)
                    delta_bytes = 0
                    if decision.miss[req.agent]:
                        delta_bytes = (sum(len(c) for _, c in delta)
                                       + acs.SIGNAL_TOKENS
                                       ) * BYTES_PER_TOKEN
                req.future.set_result(ReadResult(
                    tuple(self.store.get(name)), version,
                    hit=not bool(decision.miss[req.agent]),
                    latency_s=latency, delta=delta,
                    delta_bytes=delta_bytes))
        self.n_batches += 1
        if self.config.capture_trace:
            self.trace.append_step(acts, arts, writes, decision.miss,
                                   decision.version, latencies,
                                   write_chunks=wmasks,
                                   decide_s=busy_s,
                                   batch_size=len(batch))
        if tel is not None:
            tel.record_batch(BatchObservation(
                names=self.names, acts=acts, arts=arts, writes=writes,
                miss=np.asarray(decision.miss, bool),
                version=np.asarray(decision.version, np.int64),
                ledger_delta=decision.ledger_delta,
                state_before=state_before,
                state_after=np.asarray(self.decider.arrays.state,
                                       np.int32),
                ver_after=ver_after,
                wire_delta=decision.wire_delta,
                shard=self.shard, live=True, busy_s=busy_s,
                route=self.decider.backend, queue_depth=queue_depth,
                t_decide=t_decide, t_respond=now,
                t_submits={req.agent: req.t_submit for req in batch},
                latencies=latencies))
        if self._on_commit is not None:
            self._on_commit(self, {
                "acts": acts, "arts": arts, "writes": writes,
                "miss": decision.miss, "version": decision.version,
                "latencies": latencies, "write_chunks": wmasks,
                "busy_s": busy_s})

    # ------------------------------------------------------ invariants
    def _check_invariants(self, batch, ver_before, ver_after) -> None:
        state = np.asarray(self.decider.arrays.state)
        if not invariants.single_writer(state):
            raise InvariantViolation(
                f"SWMR violated: two M holders\n{state}")
        if not invariants.exclusive_means_alone(state):
            raise InvariantViolation(
                f"exclusivity violated\n{state}")
        if (state >= _E).any():
            raise InvariantViolation(
                f"E/M persisted past a committed batch\n{state}")
        if not invariants.monotonic_version(ver_before, ver_after):
            raise InvariantViolation(
                f"version regressed: {ver_before} -> {ver_after}")
        bumps = np.zeros(len(self.names), np.int64)
        for req in batch:
            if req.is_write:
                bumps[req.artifact] += 1
        if not np.array_equal(ver_after - ver_before, bumps):
            raise InvariantViolation(
                f"version bump mismatch: delta {ver_after - ver_before}"
                f" vs writes {bumps}")
        if self.config.max_stale_steps > 0:
            consumed = int(self.decider.metrics.max_consumed_staleness)
            if consumed > self.config.max_stale_steps:
                raise InvariantViolation(
                    f"K-staleness violated: served a hit "
                    f"{consumed} action-steps stale "
                    f"(K={self.config.max_stale_steps})")

    # ----------------------------------------------------------- stats
    @property
    def directory_state(self) -> np.ndarray:
        """(n_agents, n_artifacts) MESI matrix (live view)."""
        return np.asarray(self.decider.arrays.state, np.int32)

    @property
    def versions(self) -> np.ndarray:
        return np.asarray(self.decider.arrays.version, np.int32)

    def stats(self) -> dict:
        """The unified stats mapping (``repro.obs.stats``): canonical
        nested schema plus the legacy flat aliases as a deprecation
        shim."""
        return unified_stats(self)
