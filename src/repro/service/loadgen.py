"""Concurrent load generator driving the broker with workload-zoo rates.

Each agent of a ``repro.sim.workloads.Workload`` becomes one concurrent
async client; actions are sampled per *round* from the workload's rate
matrices (activity Bernoulli, categorical artifact pick, conditional
write Bernoulli) with a seeded numpy generator.

Two drive modes:

  * ``lockstep=True`` - rounds are barriers: every client of a round
    submits concurrently, the round's decisions resolve, then the next
    round starts.  A round is one orchestration step in the paper's
    SS8.1 sense, which makes the broadcast baseline exact
    (``n_rounds * n * m * (|d| + signal)``) and the coherent token
    totals deterministic for a fixed seed - the mode the benchmark and
    the perf gate use.
  * ``lockstep=False`` - open loop: every client runs its own round
    schedule with optional jittered think-time sleeps, so batches cut
    across rounds at the event loop's mercy.  Nothing is deterministic
    except what must be: the invariants and the oracle-replay of
    whatever trace was actually committed.  The concurrency stress
    tests use this mode.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.acs import SIGNAL_TOKENS
from repro.service.broker import CoherenceBroker
from repro.service.client import CoherentClient, make_clients


@dataclasses.dataclass
class LoadReport:
    """What the generated load did and what it cost."""

    n_rounds: int
    n_actions: int
    n_reads: int
    n_writes: int
    wall_s: float
    latencies_s: np.ndarray
    broadcast_tokens: int     # what per-round full rebroadcast would pay
    coherent_tokens: int      # what the broker actually charged
    #: per-authority-shard seconds spent inside the decider (length 1
    #: for the single broker).  The decision plane's makespan is the
    #: MAX entry: shards decide concurrently under the shard-per-host
    #: deployment, so capacity scales with the slowest shard, not the
    #: sum.
    decide_busy_s: tuple = (0.0,)

    @property
    def throughput_dps(self) -> float:
        """Decisions per second, end to end."""
        return self.n_actions / max(self.wall_s, 1e-9)

    @property
    def capacity_dps(self) -> float:
        """Decision capacity: decisions per second of decision-plane
        makespan (max busy time over authority shards).  Unlike
        ``throughput_dps`` this is host-count independent - it measures
        what the authority plane itself can serialize, which is the
        quantity sharding scales."""
        return self.n_actions / max(max(self.decide_busy_s), 1e-9)

    @property
    def savings_vs_broadcast(self) -> float:
        return 1.0 - self.coherent_tokens / max(self.broadcast_tokens, 1)

    def latency_ms(self, pct: float) -> float:
        if self.latencies_s.size == 0:
            return 0.0
        return float(np.percentile(self.latencies_s, pct) * 1e3)


def sample_round(rng: np.random.Generator, workload) -> list:
    """One round of (agent, artifact, is_write) actions from the
    workload's rate matrices."""
    n = workload.acs.n_agents
    p_act = np.asarray(workload.p_act, np.float64)
    pick = np.asarray(workload.pick, np.float64)
    wr = np.asarray(workload.write_rate, np.float64)
    actions = []
    for a in range(n):
        if rng.random() >= p_act[a]:
            continue
        d = int(rng.choice(pick.shape[1], p=pick[a] / pick[a].sum()))
        actions.append((a, d, bool(rng.random() < wr[a, d])))
    return actions


async def drive_workload(broker: CoherenceBroker, workload,
                         n_rounds: int, seed: int = 0, *,
                         lockstep: bool = True,
                         think_time_s: float = 0.0,
                         clients: Optional[list] = None) -> LoadReport:
    """Drive ``broker`` with ``workload``'s rates for ``n_rounds``."""
    clients = clients if clients is not None else make_clients(broker)
    if len(clients) != workload.acs.n_agents:
        raise ValueError(
            f"{len(clients)} clients vs workload n_agents="
            f"{workload.acs.n_agents}")
    names = broker.names
    if len(names) != workload.acs.n_artifacts:
        raise ValueError(
            f"broker has {len(names)} artifacts vs workload "
            f"n_artifacts={workload.acs.n_artifacts}")
    rng = np.random.default_rng(seed)
    schedule = [sample_round(rng, workload) for _ in range(n_rounds)]

    def busy() -> tuple:
        if hasattr(broker, "decision_busy"):    # sharded plane
            return tuple(broker.decision_busy())
        return (broker.decide_busy_s,)

    busy_before = busy()
    tok_before = broker.ledger.total_tokens
    lat: list = []
    n_reads = n_writes = 0

    async def one_action(client: CoherentClient, d: int, is_write: bool,
                         jitter: float) -> None:
        if jitter > 0:
            await asyncio.sleep(jitter)
        if is_write:
            res = await client.write(names[d])
        else:
            res = await client.read(names[d])
        lat.append(res.latency_s)

    t0 = time.perf_counter()
    if lockstep:
        for actions in schedule:
            await asyncio.gather(*(
                one_action(clients[a], d, w, 0.0)
                for a, d, w in actions))
    else:
        async def client_script(a: int) -> None:
            crng = np.random.default_rng((seed, a))
            for actions in schedule:
                for aa, d, w in actions:
                    if aa != a:
                        continue
                    jitter = (float(crng.random()) * think_time_s
                              if think_time_s > 0 else 0.0)
                    await one_action(clients[a], d, w, jitter)

        await asyncio.gather(*(client_script(a)
                               for a in range(len(clients))))
    wall = time.perf_counter() - t0

    for actions in schedule:
        for _, _, w in actions:
            n_writes += int(w)
            n_reads += int(not w)
    n, m = workload.acs.n_agents, workload.acs.n_artifacts
    broadcast = n_rounds * n * m * (workload.acs.artifact_tokens
                                    + SIGNAL_TOKENS)
    return LoadReport(
        n_rounds=n_rounds, n_actions=n_reads + n_writes,
        n_reads=n_reads, n_writes=n_writes, wall_s=wall,
        latencies_s=np.asarray(lat, np.float64),
        broadcast_tokens=broadcast,
        coherent_tokens=broker.ledger.total_tokens - tok_before,
        decide_busy_s=tuple(b - b0 for b, b0
                            in zip(busy(), busy_before)))
