"""Coherence-service launcher: run the broker under load or as a
JSON-lines TCP frontend (stdlib asyncio only - no web framework).

In-process load run (the default)::

    PYTHONPATH=src python -m repro.launch.service \
        --family zipf --clients 32 --rounds 40 --verify

TCP frontend (one JSON object per line, newline-terminated replies)::

    PYTHONPATH=src python -m repro.launch.service --tcp 8788

    request : {"op": "read",  "agent": 0, "artifact": "a0"}
              {"op": "write", "agent": 0, "artifact": "a0",
               "content": [1, 2, ...]}            # optional content
              {"op": "stats"}
              {"op": "metrics"}   # Prometheus text + registry snapshot
    reply   : {"ok": true, "version": 3, "hit": false,
               "content": [...]} | {"ok": false, "error": "..."}

The wire layer is deliberately a veneer: every connection handler just
awaits the same broker coroutines the in-process clients use, so TCP
requests coalesce into the same micro-batches.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import pathlib
from typing import Optional

from repro.service import (CoherenceBroker, CoherenceConfig, connect,
                           drive_workload, verify_broker)
from repro.sim import workloads


def artifact_names(n_artifacts: int) -> tuple:
    return tuple(f"artifact-{d}" for d in range(n_artifacts))


def build_workload(family: str, n_clients: int, n_artifacts: int,
                   artifact_tokens: int, n_rounds: int,
                   volatility: Optional[float] = None,
                   seed: Optional[int] = None):
    """A workload-zoo family sized for the service (``uniform`` is the
    paper's homogeneous SS8.1 scenario: uniform pick, scalar V)."""
    import dataclasses
    if family == "uniform":
        v = 0.10 if volatility is None else volatility
        w = workloads.zipf(
            n_agents=n_clients, n_artifacts=n_artifacts, skew=0.0,
            volatility=v, artifact_tokens=artifact_tokens,
            n_steps=n_rounds)
        return dataclasses.replace(
            w, name=f"uniform V={v:.2f}", family="uniform",
            seed=w.seed if seed is None else seed,
            description="paper SS8.1 homogeneous scenario "
                        "(uniform pick, scalar V).")
    if volatility is not None:
        raise ValueError("--volatility only applies to --family uniform")
    kw = {} if seed is None else {"seed": seed}
    return workloads.make(family, n_agents=n_clients,
                          n_artifacts=n_artifacts,
                          artifact_tokens=artifact_tokens,
                          n_steps=n_rounds, **kw)


# ---------------------------------------------------------------------------
# TCP frontend.


async def handle_connection(broker: CoherenceBroker,
                            reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
    try:
        while True:
            line = await reader.readline()
            if not line:
                break
            try:
                req = json.loads(line)
                op = req.get("op")
                if op == "read":
                    r = await broker.read(int(req["agent"]),
                                          req["artifact"])
                    reply = {"ok": True, "version": r.version,
                             "hit": r.hit, "content": list(r.content)}
                elif op == "write":
                    w = await broker.write(int(req["agent"]),
                                           req["artifact"],
                                           req.get("content"))
                    reply = {"ok": True, "version": w.version}
                elif op == "stats":
                    reply = {"ok": True, "stats": broker.stats()}
                elif op == "metrics":
                    tel = getattr(broker, "telemetry", None)
                    if tel is None:
                        reply = {"ok": False,
                                 "error": "telemetry disabled "
                                          "(telemetry=False)"}
                    else:
                        reply = {"ok": True,
                                 "prometheus": tel.prometheus(),
                                 "snapshot": tel.snapshot()}
                else:
                    reply = {"ok": False,
                             "error": f"unknown op {op!r}"}
            except Exception as e:  # noqa: BLE001 - wire errors go to
                reply = {"ok": False,  # the client, not the server log
                         "error": f"{type(e).__name__}: {e}"}
            writer.write(json.dumps(reply).encode() + b"\n")
            await writer.drain()
    finally:
        writer.close()


async def serve_tcp(broker: CoherenceBroker, host: str = "127.0.0.1",
                    port: int = 8788) -> asyncio.base_events.Server:
    """Start the JSON-lines frontend; caller owns the server object."""
    # a write request carries artifact_tokens JSON ints on one line;
    # asyncio's default 64 KiB readline limit would drop the connection
    # instead of answering, so size the limit to the artifact slot.
    tokens = getattr(broker.config, "artifact_tokens", None)
    if tokens is None:          # layered config (sharded plane)
        tokens = broker.config.core.artifact_tokens
    limit = max(1 << 16, tokens * 16 + (1 << 12))
    return await asyncio.start_server(
        lambda r, w: handle_connection(broker, r, w), host, port,
        limit=limit)


# ---------------------------------------------------------------------------
# CLI.


async def run_load(args) -> dict:
    w = build_workload(args.family, args.clients, args.artifacts,
                       args.artifact_tokens, args.rounds,
                       volatility=args.volatility, seed=args.seed)
    cfg = CoherenceConfig.make(
        args.clients, artifact_names(args.artifacts),
        artifact_tokens=args.artifact_tokens, strategy=args.strategy,
        backend=args.backend, shards=args.shards, hosts=args.hosts,
        telemetry=not args.no_telemetry)
    async with connect(cfg) as broker:
        rep = await drive_workload(broker, w, args.rounds,
                                   seed=args.seed,
                                   lockstep=not args.open_loop,
                                   think_time_s=args.think_time)
        stats = broker.stats()
        summary = {
            "family": w.family, "workload": w.name,
            "strategy": args.strategy, "backend": stats["backend"],
            "clients": args.clients, "rounds": rep.n_rounds,
            "actions": rep.n_actions,
            "batches": stats["decision"]["n_batches"],
            "mean_batch": round(stats["decision"]["mean_batch"], 2),
            "throughput_dps": round(rep.throughput_dps, 1),
            "capacity_dps": round(rep.capacity_dps, 1),
            "p50_ms": round(rep.latency_ms(50), 3),
            "p99_ms": round(rep.latency_ms(99), 3),
            "coherent_tokens": rep.coherent_tokens,
            "broadcast_tokens": rep.broadcast_tokens,
            "savings_vs_broadcast": round(rep.savings_vs_broadcast, 4),
            "cache_hit_rate": round(stats["ledger"]["cache_hit_rate"],
                                    4),
        }
        if args.shards > 1 or args.hosts > 1:
            topo = stats["topology"]
            l1 = stats["l1"]
            summary.update({
                "shards": topo["n_shards"], "hosts": topo["n_hosts"],
                "shard_artifacts": list(topo["shard_artifacts"]),
                "l1_fills": l1["l1_fills"],
                "l2_fills": l1["l2_fills"],
                "l1_fill_rate": round(l1["l1_fill_rate"], 4),
            })
        if args.trace_out:
            pathlib.Path(args.trace_out).write_text(
                broker.trace.to_json())
            summary["trace_out"] = args.trace_out
        if args.verify:
            report = verify_broker(broker, name=f"service:{w.family}")
            summary["oracle"] = {
                "bit_exact": True,
                "implementations": list(report.implementations),
                "n_actions": report.trace.n_actions,
            }
        if args.verify_metrics:
            from repro.obs import check_metrics_conformance
            summary["metrics_conformance"] = check_metrics_conformance(
                broker, name=f"metrics:{w.family}")
        return summary


async def run_tcp(args) -> None:
    # an open-ended frontend must not grow an unbounded audit trace;
    # use the load-generator mode for oracle-replayable captures.
    cfg = CoherenceConfig.make(
        args.clients, artifact_names(args.artifacts),
        artifact_tokens=args.artifact_tokens, strategy=args.strategy,
        backend=args.backend, capture_trace=False,
        shards=args.shards, hosts=args.hosts)
    async with connect(cfg) as broker:
        server = await serve_tcp(broker, args.host, args.tcp)
        addr = server.sockets[0].getsockname()
        print(f"coherence broker on {addr[0]}:{addr[1]} "
              f"({args.clients} agent slots, {args.artifacts} artifacts,"
              f" strategy={args.strategy})")
        async with server:
            await server.serve_forever()


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--family", default="uniform",
                    choices=("uniform",) + tuple(workloads.FAMILIES),
                    help="load-generator workload family")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--artifacts", type=int, default=6)
    ap.add_argument("--artifact-tokens", type=int, default=4096)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--strategy", default="lazy",
                    choices=("lazy", "eager", "access_count"))
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "scan", "pallas"),
                    help="decision route (see repro.service.batching)")
    ap.add_argument("--shards", type=int, default=1,
                    help="authority-plane shard count (K directory "
                    "shards, hash-of-artifact routed; 1 = the single "
                    "broker)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="L1 placement domains (per-host L1 "
                    "directories in front of the shards; 1 = no L1 "
                    "plane)")
    ap.add_argument("--volatility", type=float, default=None,
                    help="write probability for --family uniform")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--open-loop", action="store_true",
                    help="clients free-run with think-time jitter "
                    "instead of lockstep rounds")
    ap.add_argument("--think-time", type=float, default=0.0,
                    help="max per-action think-time sleep (s), "
                    "open-loop mode")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the captured ServiceTrace JSON here")
    ap.add_argument("--verify", action="store_true",
                    help="replay the captured trace through the "
                    "four-way differential oracle before exiting")
    ap.add_argument("--verify-metrics", action="store_true",
                    help="replay the captured trace through a fresh "
                    "telemetry plane and assert every replayable "
                    "counter bit-identical to the live registry "
                    "(repro.obs.conformance)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="run with the telemetry plane disabled (the "
                    "overhead baseline)")
    ap.add_argument("--tcp", type=int, default=None, metavar="PORT",
                    help="serve the JSON-lines TCP frontend instead of "
                    "running the load generator")
    ap.add_argument("--host", default="127.0.0.1")
    return ap


def main(argv=None) -> dict:
    args = build_parser().parse_args(argv)
    if args.tcp is not None:
        asyncio.run(run_tcp(args))
        return {}
    summary = asyncio.run(run_load(args))
    print(json.dumps(summary, indent=2, default=float))
    return summary


if __name__ == "__main__":
    main()
