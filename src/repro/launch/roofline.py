"""Roofline-term extraction from compiled XLA artifacts.

Terms (per EXPERIMENTS.md SSRoofline, TPU v5e constants):
    compute    = HLO_FLOPs / (chips x 197e12 FLOP/s)      [bf16 MXU]
    memory     = HLO_bytes / (chips x 819e9 B/s)          [HBM]
    collective = collective_bytes / (chips x 50e9 B/s)    [ICI per link]

``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed.
Collective bytes are NOT in cost_analysis: we parse the *partitioned*
HLO text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (shapes in the
partitioned module are already per-device).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (we charge one link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

#: ops whose operands ride the interconnect
_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_TOKEN_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across jax versions:
    newer jax returns a dict, older returns list[dict]."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int = 0
    by_op: dict = dataclasses.field(default_factory=dict)
    n_ops: int = 0

    def combine(self, other: "CollectiveStats", scale: float = 1.0
                ) -> "CollectiveStats":
        by_op = dict(self.by_op)
        for k, v in other.by_op.items():
            by_op[k] = by_op.get(k, 0) + int(v * scale)
        return CollectiveStats(
            total_bytes=self.total_bytes
            + int(other.total_bytes * scale),
            by_op=by_op,
            n_ops=self.n_ops + other.n_ops)


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Sum per-device payload bytes of every collective op instance.

    HLO line format: ``%name = <result-shape> all-reduce(...)`` - the
    result shape(s) sit between '=' and the op name (shapes in the
    partitioned module are per-device).  For all-reduce the result
    equals the operand; for all-gather the result is the gathered
    buffer (a conservative upper bound on link traffic); reduce-scatter
    results are the scattered shard (ring traffic ~= (n-1)/n of the
    unscattered operand - we record the result shape and note the
    approximation).

    NOTE: collectives inside a scanned superblock appear once in the
    HLO; the dry-run extrapolates by trip count (see
    ``extrapolate_body``)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "=" not in line or "-done(" in line:
            continue  # async pairs: count the -start only
        rhs = line.split("=", 1)[1]
        m = _OP_TOKEN_RE.search(rhs)
        if not m:
            continue
        op = m.group(1)
        head = rhs[: m.start()]  # result shape(s) precede the op name
        nbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(head))
        stats.total_bytes += nbytes
        stats.n_ops += 1
        stats.by_op[op] = stats.by_op.get(op, 0) + nbytes
    return stats


def extrapolate_body(c1: CollectiveStats, c2: CollectiveStats,
                     n_super: int) -> CollectiveStats:
    """Scan-body correction: compile the model at 1 and 2 superblocks;
    (c2 - c1) is one body's collectives, so the full model is
    c1 + body * (n_super - 1)."""
    body = CollectiveStats(
        total_bytes=max(0, c2.total_bytes - c1.total_bytes),
        by_op={k: max(0, c2.by_op.get(k, 0) - c1.by_op.get(k, 0))
               for k in set(c1.by_op) | set(c2.by_op)},
        n_ops=max(0, c2.n_ops - c1.n_ops))
    return c1.combine(body, scale=float(n_super - 1))


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    analytic_gflops: float         # whole step, all chips (primary)
    analytic_hbm_gbytes_dev: float
    collective_gbytes: float       # per-device, HLO-derived
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_gflops: float            # 6*N_active*D (2*N for inference)
    useful_ratio: float            # model / analytic total
    roofline_fraction: float       # bound_time share vs sum of terms
    hlo_raw: dict                  # raw cost_analysis (see caveat)
    bytes_per_device: dict
    collective_by_op: dict
    flops_by_part: dict
    bytes_by_part: dict
    note: str = ""

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def build_report(*, arch: str, shape: str, mesh_name: str, n_chips: int,
                 analytic, cost: dict, mem: dict, coll: CollectiveStats,
                 model_flops: float, note: str = "") -> RooflineReport:
    """analytic: launch.analytic.CostBreakdown (primary compute/memory
    terms - XLA cost_analysis counts while-bodies once, see analytic.py);
    cost: raw compiled.cost_analysis() recorded for transparency;
    coll: HLO-parsed collective payloads (superblock-extrapolated)."""
    compute_s = analytic.flops_total / n_chips / PEAK_FLOPS
    memory_s = analytic.hbm_bytes_per_chip / HBM_BW
    collective_s = coll.total_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        analytic_gflops=analytic.flops_total / 1e9,
        analytic_hbm_gbytes_dev=analytic.hbm_bytes_per_chip / 1e9,
        collective_gbytes=coll.total_bytes / 1e9,
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_gflops=model_flops / 1e9,
        useful_ratio=(model_flops / analytic.flops_total
                      if analytic.flops_total else 0.0),
        roofline_fraction=(bound / max(sum(terms.values()), 1e-30)),
        hlo_raw={k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        bytes_per_device=mem, collective_by_op=coll.by_op,
        flops_by_part=analytic.flops_by_part,
        bytes_by_part=analytic.bytes_by_part,
        note=note)


def model_flops_for(cfg, shape_cfg, n_params_active: int) -> float:
    """MODEL_FLOPS: 6*N*D for training (fwd+bwd), 2*N*D for inference
    fwd; D = processed tokens for the step being lowered."""
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        if cfg.family == "audio":
            tokens = shape_cfg.global_batch * (
                shape_cfg.seq_len + max(128, shape_cfg.seq_len // 4))
        return 6.0 * n_params_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape_cfg.global_batch
