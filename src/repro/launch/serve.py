"""Coherent multi-agent serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --agents 4 --artifacts 3 --steps 40 --volatility 0.1 \
        --strategy lazy

Runs the coherence-gated serving system (reduced backbone on CPU) under
the paper's SS8.1 workload and reports token + prefill-FLOPs savings vs
the rebroadcast baseline.
"""

from __future__ import annotations

import argparse

import jax

from repro import models
from repro.configs import ARCHS, n_active_params, smoke_config
from repro.runtime.coherent_serving import (CoherentServingSystem,
                                            run_workload)


def build_artifacts(m: int, tokens: int) -> dict:
    return {f"artifact-{i}": list(range(1, tokens + 1)) for i in range(m)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--artifacts", type=int, default=3)
    ap.add_argument("--artifact-tokens", type=int, default=64)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--volatility", type=float, default=0.10)
    ap.add_argument("--strategy", default="lazy",
                    choices=["lazy", "eager", "access_count"])
    ap.add_argument("--volatility-sorted", action="store_true",
                    help="beyond-paper prefix layout optimization")
    ap.add_argument("--materialize", action="store_true",
                    help="run a real prefill through the backbone")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    n_active = n_active_params(ARCHS[args.arch])
    system = CoherentServingSystem(
        cfg, args.agents,
        build_artifacts(args.artifacts, args.artifact_tokens),
        strategy=args.strategy,
        volatility_sorted=args.volatility_sorted,
        n_active_params=n_active)
    stats = run_workload(system, args.steps, args.volatility)
    print(f"strategy={args.strategy} sorted={args.volatility_sorted}")
    print(f"  prefill tokens:   {stats.prefill_tokens:,} vs broadcast "
          f"{stats.broadcast_tokens:,} -> "
          f"savings {stats.token_savings:.1%}")
    print(f"  prefill FLOPs:    {stats.prefill_flops:.3e} vs broadcast "
          f"{stats.broadcast_flops:.3e} -> "
          f"savings {stats.flops_savings:.1%}  "
          f"(@{n_active / 1e9:.2f}B active params)")
    print(f"  fetches={stats.fetches} cache_hits={stats.cache_hits}")
    if args.materialize:
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        logits = system.materialize_prefill(params, 0)
        print(f"  materialized prefill logits: {logits.shape} "
              f"(finite={bool(jax.numpy.isfinite(logits).all())})")


if __name__ == "__main__":
    main()
