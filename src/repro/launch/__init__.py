"""Launchers: mesh builders, multi-pod dry-run, train/serve drivers,
and the artifact-coherence service entry point (``repro.launch.service``
- in-process load runs or the JSON-lines TCP frontend)."""
