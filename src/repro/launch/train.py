"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

``--smoke`` swaps in the reduced same-family config (CPU-runnable);
without it the full config is used (requires a real TPU slice - on this
container use the dry-run instead).  The loop auto-resumes from the
newest checkpoint in --ckpt-dir, so re-running after a crash continues
where it left off (fault tolerance demo: --crash-at N).
"""

from __future__ import annotations

import argparse

from repro.configs import ARCHS, get, smoke_config
from repro.data import DataConfig
from repro.runtime.train_loop import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a crash at this step (FT demo)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get(args.arch)
    loop = TrainLoopConfig(total_steps=args.steps,
                           checkpoint_every=args.ckpt_every)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch)
    report = run_training(cfg, loop, args.ckpt_dir, data_cfg=data,
                          crash_at_step=args.crash_at)
    print(f"arch={cfg.name} steps_run={report.steps_run} "
          f"resumed_from={report.resumed_from} "
          f"first_loss={report.losses[0]:.4f} "
          f"last_loss={report.losses[-1]:.4f} "
          f"checkpoints={report.checkpoints}")


if __name__ == "__main__":
    main()
