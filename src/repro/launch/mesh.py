"""Production mesh builders.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set
``--xla_force_host_platform_device_count`` before any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
    an outer data-parallel dim whose collectives ride the inter-pod DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
