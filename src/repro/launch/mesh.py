"""Production mesh builders.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set
``--xla_force_host_platform_device_count`` before any jax init).

CPU hook: to exercise the device-sharded sweep path
(``repro.sim.engine``) without accelerators, force a multi-device host
topology *before* the first jax import::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_sharded_sweep.py -q

CI's ``sharded`` job does exactly this, so every PR runs the
``shard_map`` grid runners on 8 (virtual) devices.
"""

from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
    an outer data-parallel dim whose collectives ride the inter-pod DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def shard_devices(n_shards: int, axis_name: str = "shards") -> tuple:
    """Round-robin device assignment for K authority-broker shards.

    Reuses the sweep-mesh machinery: a 1-D mesh over min(K, local
    devices) and a length-K tuple assigning each shard its device, so
    every shard's micro-batch decision (``mesi_decision_batch`` /
    ``apply_actions``) runs as its own device program.  On a
    single-device host every shard maps to device 0 - byte-for-byte
    the unpinned behavior (CI forces 8 host devices to exercise the
    real placement; see the module docstring).
    """
    n = max(1, min(int(n_shards), len(jax.devices())))
    mesh = make_sweep_mesh(n, axis_name)
    devices = list(mesh.devices.flat)
    return tuple(devices[s % len(devices)] for s in range(int(n_shards)))


def make_sweep_mesh(n_devices: Optional[int] = None,
                    axis_name: str = "runs"):
    """1-D mesh for the device-sharded fleet sweep engine.

    The sweep grids of ``repro.sim.engine`` are embarrassingly parallel
    along their batch axes, so the engine shards them over a single
    mesh axis - ``"runs"`` normally, ``"workloads"`` when the run axis
    does not divide (see ``engine.shard_plan``).  ``n_devices`` defaults
    to every local device; pass fewer to sweep on a sub-mesh.
    """
    n = len(jax.devices()) if n_devices is None else n_devices
    return jax.make_mesh((n,), (axis_name,))
