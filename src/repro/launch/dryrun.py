import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init).  Do not move them.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds ShapeDtypeStruct stand-ins (no allocation) for params,
     optimizer state, batch/caches via jax.eval_shape;
  2. jits the appropriate step (train_step for train shapes, prefill /
     serve_step for inference shapes) with the production shardings;
  3. ``.lower().compile()`` against the 16x16 single-pod mesh and the
     2x16x16 multi-pod mesh;
  4. records memory_analysis (proves it fits), cost_analysis
     (FLOPs/bytes) and the collective schedule parsed from the
     partitioned HLO -> benchmarks/results/dryrun.json, which SSRoofline
     and SSPerf read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k --mesh single
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, SHAPES, get, input_specs,
                           n_active_params, n_params_analytic, shapes_for)
from repro.launch import analytic as an
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime import steps as step_factories

RESULTS = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun.json"


def _mem_fields(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[f] = int(getattr(ma, f, 0))
    out["total_bytes_per_device"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"] - out["alias_size_in_bytes"])
    return out


def _moment_dtype(cfg) -> str:
    return ("bfloat16" if n_params_analytic(cfg) > 6e10 else "float32")


def cell_options(cfg, shape_cfg, mesh) -> step_factories.StepOptions:
    """Production memory policy per cell (recorded in the results):

    * FSDP when TP-sharded weights alone exceed ~8 GB/chip (jamba-398b,
      llama-3.2-vision-90b);
    * gradient-accumulation microbatches sized so remat boundary
    activations (B_loc x S x d x 2 x L) stay under ~4 GB/chip.
    """
    tp = mesh.shape.get("model", 1)
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    w_per_chip = n_params_analytic(cfg) * 2 / tp
    fsdp = w_per_chip > 8e9
    n_micro = 1
    if shape_cfg.kind == "train":
        from repro.configs.registry import _dec_len
        b_loc = max(shape_cfg.global_batch // dp, 1)
        boundary = (b_loc * _dec_len(cfg, shape_cfg.seq_len)
                    * cfg.d_model * 2 * cfg.n_layers)
        while boundary / n_micro > 4e9 and n_micro < b_loc:
            n_micro *= 2
    return step_factories.StepOptions(fsdp=fsdp,
                                      n_microbatches=n_micro)


def _adapt_moe_dispatch(cfg, mesh):
    """Production MoE dispatch: one slice per DP shard (SSPerf iteration
    1: removes the dispatch-buffer partial-sum across the data axis)."""
    if cfg.moe is None or cfg.moe.dispatch_slices != 1:
        return cfg
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, dispatch_slices=dp, dispatch_axes=axes))


def _lower_and_compile(cfg, shape_cfg, mesh, options=None):
    """One lower+compile of the appropriate step; returns compiled."""
    cfg = _adapt_moe_dispatch(cfg, mesh)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: tf.init_params(cfg, k), key)
    specs = input_specs(cfg, shape_cfg)
    options = options or cell_options(cfg, shape_cfg, mesh)
    with mesh:
        if shape_cfg.kind == "train":
            opt_cfg = adamw.AdamWConfig(moment_dtype=_moment_dtype(cfg))
            opt_shape = jax.eval_shape(
                lambda: adamw.init_state(opt_cfg, params_shape))
            fn, in_sh, _ = step_factories.make_train_step(
                cfg, opt_cfg, mesh, params_shape, specs, options)
            mb_specs = step_factories.microbatch_shape(
                specs, options.n_microbatches)
            lowered = fn.lower(
                _shard_struct(params_shape, in_sh[0]),
                _shard_struct(opt_shape, in_sh[1]),
                _shard_struct(mb_specs, in_sh[2]))
        elif shape_cfg.kind == "prefill":
            ctx_len = 0
            if cfg.family == "vlm":
                ctx_len = cfg.vision.n_image_tokens
            if cfg.family == "audio":
                ctx_len = shape_cfg.seq_len
            cache_shape = jax.eval_shape(lambda: tf.init_cache(
                cfg, shape_cfg.global_batch,
                specs["tokens"].shape[1], ctx_len=ctx_len))
            fn, in_sh, _ = step_factories.make_prefill_step(
                cfg, mesh, params_shape, specs, cache_shape, options)
            lowered = fn.lower(
                _shard_struct(params_shape, in_sh[0]),
                _shard_struct(specs, in_sh[1]),
                _shard_struct(cache_shape, in_sh[2]))
        else:  # decode
            cache_shape = specs["cache"]
            fn, in_sh, _ = step_factories.make_decode_step(
                cfg, mesh, params_shape, cache_shape, options)
            lowered = fn.lower(
                _shard_struct(params_shape, in_sh[0]),
                _shard_struct({"token": specs["token"]},
                              {"token": in_sh[1]})["token"],
                _shard_struct(cache_shape, in_sh[2]))
        return lowered.compile()


def _reduced_cfg(cfg, n_blocks: int):
    """Config with n_blocks superblocks (for scan-body extrapolation)."""
    specs = tf.layer_specs(cfg)
    prefix, period = tf.split_pattern(specs)
    over = dict(n_layers=prefix + n_blocks * period)
    if cfg.encoder_layers:
        over["encoder_layers"] = n_blocks
    return dataclasses.replace(cfg, **over)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True,
               extrapolate_collectives: bool = True) -> dict:
    cfg = get(arch)
    shape_cfg = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = 512 if multi_pod else 256
    t0 = time.time()

    options = cell_options(cfg, shape_cfg, mesh)
    compiled = _lower_and_compile(cfg, shape_cfg, mesh, options)
    cost = rf.cost_analysis_dict(compiled)
    mem = _mem_fields(compiled)
    coll_raw = rf.collective_bytes_from_hlo(compiled.as_text())

    # Scan-body collective correction: compile 1- and 2-superblock
    # variants; the delta is one body's collectives (roofline.py).
    note = ""
    coll = coll_raw
    specs = tf.layer_specs(cfg)
    prefix, period = tf.split_pattern(specs)
    n_super = (cfg.n_layers - prefix) // period
    if extrapolate_collectives and n_super > 2:
        c1 = rf.collective_bytes_from_hlo(_lower_and_compile(
            _reduced_cfg(cfg, 1), shape_cfg, mesh).as_text())
        c2 = rf.collective_bytes_from_hlo(_lower_and_compile(
            _reduced_cfg(cfg, 2), shape_cfg, mesh).as_text())
        coll = rf.extrapolate_body(c1, c2, n_super)
        note = (f"collectives extrapolated from 1/2-superblock "
                f"compiles x{n_super}")

    n_active = n_active_params(cfg)
    analytic = an.analytic_cost(
        cfg, shape_cfg, n_chips, tp=mesh.shape["model"],
        moment_bytes=2 if _moment_dtype(cfg) == "bfloat16" else 4)
    report = rf.build_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_chips=n_chips, analytic=analytic, cost=cost, mem=mem,
        coll=coll,
        model_flops=rf.model_flops_for(cfg, shape_cfg, n_active),
        note=note)
    result = report.to_dict()
    result.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        n_params=n_params_analytic(cfg),
        n_params_active=n_active,
        collective_raw_gbytes=coll_raw.total_bytes / 1e9,
        options={"fsdp": options.fsdp,
                 "n_microbatches": options.n_microbatches},
    )
    if verbose:
        print(f"  memory_analysis: {json.dumps(mem)}")
        print(f"  cost_analysis(raw, see caveat): "
              f"flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {coll.n_ops} ops, "
              f"{coll.total_bytes / 1e9:.3f} GB/device "
              f"{json.dumps(coll.by_op)}")
        print(f"  roofline: compute={report.compute_s:.4f}s "
              f"memory={report.memory_s:.4f}s "
              f"collective={report.collective_s:.4f}s "
              f"-> {report.dominant}-bound "
              f"(useful_ratio={report.useful_ratio:.2f})")
    return result


def _shard_struct(shape_tree, shard_tree):
    """Attach shardings to ShapeDtypeStructs (still no allocation)."""
    def one(s, sh):
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return jax.tree.map(one, shape_tree, shard_tree)


def load_results() -> dict:
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return {}


def save_results(results: dict) -> None:
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    RESULTS.write_text(json.dumps(results, indent=1, default=float))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default all)")
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run needs the 512 placeholder devices; run as a script so "
        "the XLA_FLAGS line executes before jax init")

    archs = [args.arch] if args.arch else list(ARCHS)
    results = load_results()
    failures = []
    for arch in archs:
        cfg = get(arch)
        shape_list = ([SHAPES[args.shape]] if args.shape
                      else shapes_for(cfg))
        for shape_cfg in shape_list:
            meshes = {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                cell = f"{arch}|{shape_cfg.name}|{mesh_name}"
                if cell in results and \
                        results[cell].get("status") == "ok" \
                        and not args.force:
                    print(f"[cached] {cell}")
                    continue
                print(f"[lower+compile] {cell}", flush=True)
                try:
                    results[cell] = lower_cell(arch, shape_cfg.name,
                                               multi)
                except Exception as e:
                    traceback.print_exc()
                    results[cell] = {"status": "failed",
                                     "error": f"{type(e).__name__}: {e}"}
                    failures.append(cell)
                save_results(results)
    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    print(f"\ndry-run summary: {n_ok} ok, {len(failures)} failed")
    if failures:
        for f in failures:
            print(f"  FAILED {f}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
