"""Analytic FLOPs / HBM-traffic model for the roofline compute & memory
terms.

Why analytic rather than ``compiled.cost_analysis()``: XLA's
HloCostAnalysis counts a while-loop body ONCE regardless of trip count,
so any scan-over-layers/time program under-reports FLOPs by ~the layer
count (verified on gemma-2b: reported 4.06e15 vs expected 1.58e16 -
exactly the body-counted-once signature).  Analytic model-FLOPs is also
the standard MFU accounting (PaLM App. B / MaxText): exact for matmuls,
explicit about attention quadratic terms, MoE active params, and
recurrent state updates.  Raw cost_analysis numbers are still recorded
in dryrun.json for transparency.

All formulas count multiply-accumulate as 2 FLOPs.  Train multiplier is
3x fwd (fwd + 2x bwd) for parameter matmuls and 4x for the
chunk-checkpointed components (attention scores, mamba/rwkv scans),
whose forward is recomputed during backward.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2


@dataclasses.dataclass
class CostBreakdown:
    flops_total: float            # whole step, all chips
    hbm_bytes_per_chip: float
    flops_by_part: dict
    bytes_by_part: dict


def _layer_matmul_params(cfg: ModelConfig, i: int) -> float:
    """Matmul parameters touched per token at layer i (active only)."""
    d = cfg.d_model
    hd = cfg.kv_head_dim()
    kind = cfg.layer_kind(i)
    if cfg.is_cross_layer(i):
        # q/o every text token; k/v are amortized over the context and
        # counted separately in cross-context flops
        mixer = d * cfg.n_heads * hd * 2
    elif cfg.mla is not None:
        m = cfg.mla
        mixer = (d * cfg.n_heads * (m.qk_nope_head_dim
                                    + m.qk_rope_head_dim)
                 + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                 + m.kv_lora_rank * cfg.n_heads
                 * (m.qk_nope_head_dim + m.v_head_dim)
                 + cfg.n_heads * m.v_head_dim * d)
    elif kind == "attn":
        mixer = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
            + cfg.n_heads * hd * d
        if cfg.encoder_layers:   # whisper decoder adds cross-attn
            mixer += d * hd * (cfg.n_heads + 0) + cfg.n_heads * hd * d
    elif kind == "mamba":
        mb = cfg.mamba
        di = mb.expand * d
        dtr = mb.dt_rank or max(1, -(-d // 16))
        mixer = (d * 2 * di + mb.d_conv * di
                 + di * (dtr + 2 * mb.d_state) + dtr * di + di * d)
    elif kind == "rwkv":
        r = cfg.rwkv
        mixer = 5 * d * d + d * 5 * r.mix_lora + 5 * r.mix_lora * d \
            + d * r.decay_lora + r.decay_lora * d
    else:
        mixer = 0

    if cfg.is_moe_layer(i):
        m = cfg.moe
        ffn = d * m.n_experts \
            + (m.top_k + m.n_shared) * 3 * d * m.d_expert
    elif cfg.rwkv is not None:
        ffn = 2 * d * cfg.d_ff + d * d    # channel mix
    elif cfg.family == "audio":
        ffn = 2 * d * cfg.d_ff
    else:
        dff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_d_ff:
            dff = cfg.moe.dense_d_ff
        ffn = 3 * d * dff
    return float(mixer + ffn)


def _recurrent_flops_per_token(cfg: ModelConfig, i: int) -> float:
    """State-update FLOPs per token (chunk-checkpointed -> 4x in train)."""
    kind = cfg.layer_kind(i)
    if kind == "mamba" and not cfg.is_cross_layer(i):
        di = cfg.mamba.expand * cfg.d_model
        return 9.0 * di * cfg.mamba.d_state
    if kind == "rwkv":
        return 6.0 * cfg.d_model * cfg.rwkv.head_size
    return 0.0


def _attn_layers(cfg: ModelConfig) -> list[int]:
    return [i for i in range(cfg.n_layers)
            if cfg.layer_kind(i) == "attn"
            and not cfg.is_cross_layer(i)
            and cfg.mla is None]


def _mla_layers(cfg: ModelConfig) -> list[int]:
    if cfg.mla is None:
        return []
    return [i for i in range(cfg.n_layers)
            if not cfg.is_cross_layer(i)]


def _cross_layers(cfg: ModelConfig) -> list[int]:
    return [i for i in range(cfg.n_layers) if cfg.is_cross_layer(i)]


def _score_dims(cfg: ModelConfig) -> float:
    """hq * hd for the score matmuls (MLA uses its own head dims)."""
    if cfg.mla is not None:
        m = cfg.mla
        return cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim
                              + m.v_head_dim) / 2.0
    return cfg.n_heads * cfg.kv_head_dim()


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig,
                  n_chips: int, tp: int = 16,
                  moment_bytes: int = 4) -> CostBreakdown:
    from repro.configs.registry import _ctx_len, _dec_len, \
        n_params_analytic

    d = cfg.d_model
    b = shape.global_batch
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    dec_len = _dec_len(cfg, shape.seq_len)
    ctx_len = _ctx_len(cfg, shape.seq_len)
    if cfg.family == "vlm":
        ctx_len = cfg.vision.n_image_tokens

    # tokens processed this step
    if decode:
        tokens = float(b)                 # one new token per sequence
        kv_depth = float(shape.seq_len)   # attended history
    else:
        tokens = float(b * dec_len)
        kv_depth = dec_len / 2.0          # causal average

    mm = {"param_matmuls": 0.0, "attn_scores": 0.0, "recurrent": 0.0,
          "cross_context": 0.0, "lm_head": 0.0, "encoder": 0.0}

    # per-layer parameter matmuls + recurrences
    for i in range(cfg.n_layers):
        mm["param_matmuls"] += 2 * tokens * _layer_matmul_params(cfg, i)
        mm["recurrent"] += tokens * _recurrent_flops_per_token(cfg, i)

    # attention score+output flops: 4 * hq*hd * kv_depth per token
    n_full_attn = len(_attn_layers(cfg)) + len(_mla_layers(cfg))
    mm["attn_scores"] += 4 * tokens * kv_depth * _score_dims(cfg) \
        * n_full_attn

    # cross-attention: kv projection over the context (once per step)
    # + scores text x context
    ncross = len(_cross_layers(cfg)) + (
        cfg.n_layers if cfg.encoder_layers else 0)
    if ncross and ctx_len:
        hd = cfg.kv_head_dim()
        mm["cross_context"] += ncross * (
            2 * b * ctx_len * (d * 2 * cfg.n_kv_heads * hd)  # k,v proj
            + 4 * tokens * ctx_len * cfg.n_heads * hd)       # scores
        # decode reuses cached context k/v: drop the projection term
        if decode:
            mm["cross_context"] -= ncross * 2 * b * ctx_len * (
                d * 2 * cfg.n_kv_heads * hd)

    # encoder (whisper): bidirectional self-attn + mlp over enc frames
    if cfg.encoder_layers and not decode:
        enc_tokens = float(b * shape.seq_len)
        hd = cfg.kv_head_dim()
        per_layer = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
            + cfg.n_heads * hd * d + 2 * d * cfg.d_ff
        mm["encoder"] += 2 * enc_tokens * per_layer \
            * cfg.encoder_layers
        mm["encoder"] += 4 * enc_tokens * shape.seq_len \
            * cfg.n_heads * hd * cfg.encoder_layers

    # lm head (+ tied embed matmul)
    mm["lm_head"] += 2 * tokens * d * cfg.vocab_size

    # training multipliers: 3x matmuls, 4x checkpointed components
    if train:
        for k in ("param_matmuls", "lm_head", "cross_context",
                  "encoder"):
            mm[k] *= 3
        for k in ("attn_scores", "recurrent"):
            mm[k] *= 4
    flops_total = sum(mm.values())

    # ----------------------------- HBM ------------------------------
    n_params = n_params_analytic(cfg)
    w_local = n_params * BF16 / tp        # params sharded over 'model'
    by = {}
    if train:
        # fwd read + bwd read + updated write
        by["weights"] = 3 * w_local
        # grads: write in bwd, read in optimizer
        by["grads"] = 2 * w_local
        # moments: read+write mu and nu (ZeRO shards over data too)
        dp = n_chips // tp
        by["optimizer"] = 4 * (n_params * moment_bytes) / (tp * dp)
        # activations: ~12 intermediate tensors per layer + boundaries
        tok_local = tokens / (n_chips / tp)
        by["activations"] = 12 * tok_local * d * BF16 * cfg.n_layers
        by["logits"] = 3 * tok_local * cfg.vocab_size / tp * 4
    elif decode:
        by["weights"] = w_local
        # stream the whole KV cache once per decoded token
        kv_bytes = _kv_cache_bytes(cfg, b, shape.seq_len, ctx_len)
        by["kv_cache"] = kv_bytes / n_chips
        by["activations"] = 2 * (b / max(n_chips / tp, 1)) * d * BF16 \
            * cfg.n_layers
    else:  # prefill
        by["weights"] = w_local
        tok_local = tokens / (n_chips / tp)
        by["activations"] = 12 * tok_local * d * BF16 * cfg.n_layers
        by["kv_cache"] = _kv_cache_bytes(
            cfg, b, dec_len, ctx_len) / n_chips
    hbm = sum(by.values())
    return CostBreakdown(flops_total=flops_total,
                         hbm_bytes_per_chip=hbm,
                         flops_by_part=mm, bytes_by_part=by)


def _kv_cache_bytes(cfg: ModelConfig, b: int, depth: int,
                    ctx_len: int) -> float:
    hd = cfg.kv_head_dim()
    total = 0.0
    for i in range(cfg.n_layers):
        if cfg.is_cross_layer(i):
            total += 2 * b * ctx_len * cfg.n_kv_heads * hd * BF16
        elif cfg.mla is not None:
            m = cfg.mla
            total += b * depth * (m.kv_lora_rank
                                  + m.qk_rope_head_dim) * BF16
        elif cfg.layer_kind(i) == "attn":
            total += 2 * b * depth * cfg.n_kv_heads * hd * BF16
            if cfg.encoder_layers:
                total += 2 * b * ctx_len * cfg.n_kv_heads * hd * BF16
        elif cfg.layer_kind(i) == "mamba":
            mb = cfg.mamba
            di = mb.expand * cfg.d_model
            total += b * di * (mb.d_conv - 1 + mb.d_state) * 4
        elif cfg.layer_kind(i) == "rwkv":
            r = cfg.rwkv
            n_h = cfg.d_model // r.head_size
            total += b * (n_h * r.head_size ** 2 * 4
                          + 2 * cfg.d_model * BF16)
    return total
