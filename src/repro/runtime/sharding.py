"""Sharding rules: param/optimizer/batch/cache PartitionSpecs.

Parallelism mapping on the production mesh (pod, data, model):
  * DP   - batch over ('pod', 'data'); gradients psum'd there by XLA.
  * TP   - 'model' axis: attention head projections, FFN hidden dim,
           vocab rows, Mamba inner channels, RWKV head channels.
  * EP   - MoE expert dim over 'model' (experts >= shards for olmoe /
           deepseek; jamba 16e = 1 expert per shard).
  * ZeRO - optimizer moments additionally sharded over 'data' on the
           dim the param is replicated on (opt-in, see zero_spec).

Rules pattern-match flattened param paths, so they apply equally to raw
params, stacked scan params (leading layer dim -> prepended None), and
optimizer moments (same tree shape).
"""

from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: (regex on path, spec builder taking (shape, extra_leading_dims))
#: specs below are for the *unstacked* rank; leading layer/superblock
#: dims are padded with None automatically.
_RULES: list[tuple[str, tuple]] = [
    # embeddings / lm head: shard vocab rows
    (r"(^|/)(embed|lm_head)$", ("model", None)),
    # attention projections
    (r"/(wq|wk|wv)$", (None, "model")),
    (r"/w_dq$", (None, "model")),
    (r"/(w_uk|w_uv)$", (None, "model")),
    (r"/w_dkv$", (None, None)),          # latent rank is small: replicate
    (r"/(wo|w_o)$", ("model", None)),
    # GLU / dense MLPs
    (r"/(w_gate|w_up|w_in)$", (None, "model")),
    (r"/(w_down|w_out)$", ("model", None)),
    (r"/(b_gate|b_up|b_in)$", ("model",)),
    # MoE: expert-parallel over the expert dim; router replicated
    (r"/ffn/router$", (None, None)),
    (r"/(expert_gate|expert_up|expert_down)$",
     ("model", None, None)),
    (r"/(shared_gate|shared_up)$", (None, "model")),
    (r"/shared_down$", ("model", None)),
    # Mamba: shard the expanded inner dim
    (r"/conv_w$", (None, "model")),
    (r"/conv_b$", ("model",)),
    (r"/w_x_dbc$", ("model", None)),
    (r"/w_dt$", (None, "model")),
    (r"/dt_bias$", ("model",)),
    (r"/a_log$", ("model", None)),
    (r"/d_skip$", ("model",)),
    # RWKV time/channel mix
    (r"/(w_r|w_k|w_v|w_g)$", (None, "model")),
    (r"/(mix_lora_a|mix_lora_b|decay_lora_a|decay_lora_b)$", None),
    (r"/bonus$", ("model", None)),       # heads dim
    # everything small (norms, biases, gates, scalar params): replicate
]


def spec_for(path: str, ndim: int, base_rank: Optional[int] = None) -> P:
    for pattern, spec in _RULES:
        if re.search(pattern, path):
            if spec is None:
                return P()
            pad = ndim - len(spec)
            if pad < 0:   # scalar or unexpectedly low rank: replicate
                return P()
            return P(*((None,) * pad + tuple(spec)))
    return P()


def _flatten_with_paths(tree, prefix=""):
    # PartitionSpec subclasses tuple on some jax versions; it is always
    # a leaf here, never a container to recurse into.
    if isinstance(tree, P):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten_with_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten_with_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def param_specs(params_shape) -> dict:
    """Pytree of PartitionSpec matching a params (shape) tree."""
    flat = dict(_flatten_with_paths(params_shape))
    specs = {p: spec_for(p, len(v.shape)) for p, v in flat.items()}
    return _unflatten_like(params_shape, specs)


def zero_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """ZeRO-1: additionally shard the largest replicated dim over
    'data' when divisible (applied to optimizer moments only)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, (s, dim) in enumerate(zip(parts, shape)):
        if s is None and dim % mesh.shape["data"] == 0 and dim > best_size:
            best, best_size = i, dim
    if best is None:
        return spec
    parts[best] = "data"
    return P(*parts)


def fsdp_param_specs(params_shape, mesh: Mesh) -> dict:
    """FSDP / ZeRO-3: params sharded over 'data' on top of TP.  XLA SPMD
    all-gathers each layer's weights at use - the standard memory/
    bandwidth trade for models whose TP-sharded weights exceed HBM
    (jamba-398b: 49.8 GB/chip with TP-16 alone -> 3.1 GB with FSDP)."""
    flat = dict(_flatten_with_paths(params_shape))
    specs = {p: zero_spec(spec_for(p, len(v.shape)), v.shape, mesh)
             for p, v in flat.items()}
    return _unflatten_like(params_shape, specs)


def opt_state_specs(params_shape, mesh: Mesh, zero: bool = True):
    """Specs for AdamWState(mu, nu) trees (+ step scalar)."""
    flat = dict(_flatten_with_paths(params_shape))
    specs = {}
    for p, v in flat.items():
        base = spec_for(p, len(v.shape))
        specs[p] = zero_spec(base, v.shape, mesh) if zero else base
    return _unflatten_like(params_shape, specs)


def _unflatten_like(tree, flat: dict, prefix=""):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}/{k}")
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        seq = [_unflatten_like(v, flat, f"{prefix}/{i}")
               for i, v in enumerate(tree)]
        return type(tree)(seq) if isinstance(tree, tuple) else seq
    return flat[prefix]


# --------------------------- batch / cache ---------------------------

def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_specs(batch_shape, mesh: Mesh, batch_dim: int = 0) -> dict:
    """Shard the batch dim of every input over DP axes.  ``batch_dim``
    is 1 for microbatch-pre-split inputs (nm, B/nm, ...): the scan dim
    stays unsharded."""
    dp = dp_axes(mesh)

    def one(x):
        if not hasattr(x, "shape") or len(x.shape) <= batch_dim:
            return P()
        b = x.shape[batch_dim]
        usable = []
        prod = 1
        for a in dp:
            if b % (prod * mesh.shape[a]) == 0:
                usable.append(a)
                prod *= mesh.shape[a]
        parts = [None] * len(x.shape)
        if usable:
            parts[batch_dim] = tuple(usable)
        return P(*parts)

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape, cfg, mesh: Mesh) -> dict:
    """Decode-cache sharding.

    KV tensors are (n_super, B, T, H, D) (or latent (n_super, B, T, R)).
    Policy: batch over DP axes when divisible; otherwise (long-context,
    batch 1) shard the TIME dim of attention caches over all axes -
    XLA SPMD partitions the softmax contraction with an all-reduce,
    which the SSPerf loop later replaces with an explicit shard_map
    flash-decode.  Head dims shard over 'model' when divisible.
    States without a time dim (mamba/rwkv) shard their channel dim."""
    dp = dp_axes(mesh)
    model = mesh.shape.get("model", 1)

    def one(path, x):
        shape = x.shape
        nd = len(shape)
        if nd == 0:
            return P()
        # leading dims: (n_super, B, ...) or (B,) for `length`
        if nd == 1:
            return P(None)
        parts = [None] * nd
        b_idx = 1 if nd >= 2 else 0
        b = shape[b_idx]
        usable, prod = [], 1
        for a in dp:
            if b % (prod * mesh.shape[a]) == 0:
                usable.append(a)
                prod *= mesh.shape[a]
        if usable:
            parts[b_idx] = tuple(usable)
        leaf = path.rsplit("/", 1)[-1]
        if leaf in ("k", "v", "xk", "xv", "enc_k", "enc_v") and nd >= 5:
            # (L, B, T, H, D)
            if shape[3] % model == 0:
                parts[3] = "model"
            elif shape[2] % model == 0:
                parts[2] = "model"
            if not usable and shape[2] % model and dp:
                pass
        elif leaf in ("ckv", "kpe") and nd >= 4:
            # (L, B, T, R): latent stream - shard time over model
            if shape[2] % model == 0:
                parts[2] = "model"
        elif leaf in ("conv", "ssm") and nd >= 3:
            if shape[2] % model == 0:
                parts[2] = "model"     # d_inner channels
        elif leaf in ("wkv",) and nd >= 3:
            if shape[2] % model == 0:
                parts[2] = "model"     # heads
        elif leaf in ("tm", "cm") and nd >= 3:
            if shape[2] % model == 0:
                parts[2] = "model"
        return P(*parts)

    flat = dict(_flatten_with_paths(cache_shape))
    specs = {p: one(p, v) for p, v in flat.items()}
    return _unflatten_like(cache_shape, specs)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
