"""Coherence-gated multi-agent LLM serving - the paper's technique as a
first-class runtime feature.

The TPU-native translation of "token cost" (DESIGN.md SS3): injecting an
artifact into an agent's context costs a *prefill pass* over its tokens;
a coherent cached copy costs nothing.  Each agent's context is laid out
as

    [ artifact_0 | artifact_1 | ... | artifact_{m-1} | dialogue ]

with prefix-cache semantics: re-fetching artifact i invalidates the KV
suffix from artifact i's offset, so the re-prefill cost is every token
from that offset to the end of the resident context.  The MESI layer
(repro.core.protocol) decides *when* a fetch is needed; this module
converts those decisions into real prefill compute on a zoo backbone
and accounts both tokens and FLOPs.

Beyond the paper: ``volatility_sorted=True`` enables the
*volatility-sorted suffix* layout policy: whenever an invalidation
forces a KV-suffix recompute anyway, the artifacts inside that (already
dead) suffix are re-ordered by ascending observed write count.  The
re-order is free at that moment, avoids the thrash of naive
move-to-back under multiple hot artifacts, and converges the layout to
ascending volatility so future invalidations land on the shortest
possible suffix - an optimization structurally unavailable to
flat-broadcast systems and absent from the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.protocol import (AgentRuntime, ArtifactStore,
                                 CoordinatorService, EventBus)
from repro.models import transformer as tf


@dataclasses.dataclass
class ServingStats:
    prefill_tokens: int = 0          # tokens actually re-prefilled
    broadcast_tokens: int = 0        # what naive rebroadcast would pay
    prefill_flops: float = 0.0
    broadcast_flops: float = 0.0
    fetches: int = 0
    cache_hits: int = 0

    @property
    def token_savings(self) -> float:
        return 1.0 - self.prefill_tokens / max(self.broadcast_tokens, 1)

    @property
    def flops_savings(self) -> float:
        return 1.0 - self.prefill_flops / max(self.broadcast_flops, 1.0)


class CoherentAgent:
    """One serving agent: protocol client + KV prefix cache.

    ``layout`` is the placement order of resident artifacts in the
    context (prefix-cache order); first-time fetches always append at
    the end (nothing after them to recompute)."""

    def __init__(self, agent_id: str, coordinator, bus,
                 artifact_order: list[str], strategy: str) -> None:
        self.runtime = AgentRuntime(agent_id, coordinator, bus,
                                    strategy=strategy)
        self.layout: list[str] = []          # resident placement order
        self.resident: dict[str, int] = {}   # artifact -> token length

    def offset_of(self, artifact_id: str) -> int:
        off = 0
        for a in self.layout:
            if a == artifact_id:
                return off
            off += self.resident.get(a, 0)
        return off

    def resident_total(self) -> int:
        return sum(self.resident.get(a, 0) for a in self.layout)


class CoherentServingSystem:
    """n agents x m artifacts served against one backbone."""

    def __init__(self, cfg: ModelConfig, n_agents: int,
                 artifacts: dict[str, list[int]],
                 strategy: str = "lazy",
                 volatility_sorted: bool = False,
                 n_active_params: Optional[int] = None) -> None:
        self.cfg = cfg
        self.strategy = strategy
        self.volatility_sorted = volatility_sorted
        self.n_active = n_active_params or 1
        self.bus = EventBus()
        self.store = ArtifactStore()
        self.coordinator = CoordinatorService(self.bus, self.store,
                                              strategy=strategy)
        order = list(artifacts)
        for aid, content in artifacts.items():
            self.coordinator.register_artifact(aid, content)
        self.agents = [
            CoherentAgent(f"agent-{i}", self.coordinator, self.bus,
                          order, strategy)
            for i in range(n_agents)]
        self.write_counts = {a: 0 for a in artifacts}
        self.stats = ServingStats()

    # ------------------------- accounting -----------------------------
    def _prefill_cost(self, n_tokens: int) -> float:
        return 2.0 * self.n_active * n_tokens

    def _sort_suffix(self, agent: CoherentAgent,
                     artifact_id: str) -> None:
        """Re-order the dead KV suffix (from artifact_id onward) by
        ascending coordinator-observed write count - free, because that
        region is being re-prefilled regardless."""
        idx = agent.layout.index(artifact_id)
        suffix = sorted(agent.layout[idx:],
                        key=lambda a: self.write_counts[a])
        agent.layout = agent.layout[:idx] + suffix

    # --------------------------- operations ---------------------------
    def agent_read(self, agent_idx: int, artifact_id: str) -> None:
        """Agent consumes an artifact: coherence check -> maybe fetch ->
        maybe KV suffix re-prefill."""
        agent = self.agents[agent_idx]
        before = self.coordinator.ledger.n_fetches
        content = agent.runtime.read(artifact_id)
        fetched = self.coordinator.ledger.n_fetches > before

        # broadcast baseline would re-inject EVERY artifact each access
        total_ctx = sum(len(self.store.get(a))
                        for a in self.write_counts)
        self.stats.broadcast_tokens += total_ctx
        self.stats.broadcast_flops += self._prefill_cost(total_ctx)

        if fetched:
            self.stats.fetches += 1
            if artifact_id in agent.resident:
                # invalidated re-fetch: the KV suffix from its old
                # offset is dead either way; re-ordering inside it is
                # free, so sort that region by ascending write count.
                offset = agent.offset_of(artifact_id)
                recompute = agent.resident_total() - offset
                if self.volatility_sorted:
                    self._sort_suffix(agent, artifact_id)
            else:
                # first placement: append at the end - nothing after it
                agent.layout.append(artifact_id)
                recompute = len(content)
            agent.resident[artifact_id] = len(content)
            self.stats.prefill_tokens += recompute
            self.stats.prefill_flops += self._prefill_cost(recompute)
        else:
            self.stats.cache_hits += 1

    def agent_write(self, agent_idx: int, artifact_id: str,
                    new_content: list[int]) -> None:
        agent = self.agents[agent_idx]
        agent.runtime.write(artifact_id, new_content)
        self.write_counts[artifact_id] += 1
        # The writer's own KV for this artifact region is now stale:
        # it pays the suffix re-prefill immediately (peers pay lazily
        # on their next read via the coherence protocol).
        if artifact_id in agent.resident:
            offset = agent.offset_of(artifact_id)
            recompute = agent.resident_total() - offset
            if self.volatility_sorted:
                self._sort_suffix(agent, artifact_id)
        else:
            agent.layout.append(artifact_id)
            recompute = len(new_content)
        agent.resident[artifact_id] = len(new_content)
        self.stats.prefill_tokens += recompute
        self.stats.prefill_flops += self._prefill_cost(recompute)

    # ----------------------- real model prefill -----------------------
    def materialize_prefill(self, params, agent_idx: int,
                            max_len: int = 256):
        """Run an actual (smoke-scale) prefill of the agent's current
        context through the backbone - proves the accounting maps to
        real compute and returns the logits."""
        agent = self.agents[agent_idx]
        tokens = []
        for a in agent.layout:
            tokens.extend(int(t) % self.cfg.vocab_size
                          for t in self.store.get(a))
        tokens = tokens[:max_len] or [1]
        tok = jnp.asarray(tokens, jnp.int32)[None, :]
        cache = tf.init_cache(self.cfg, 1, max_len)
        logits, cache = tf.prefill(params, self.cfg, tok, cache)
        return logits


def run_workload(system: CoherentServingSystem, n_steps: int,
                 volatility, seed: int = 0,
                 p_act: float = 0.75) -> ServingStats:
    """Drive the serving system with the paper's SS8.1 workload.

    ``volatility`` may be a scalar (uniform V) or a per-artifact list -
    real deployments have skewed write rates (a plan document vs a
    scratchpad), which is where layout policies matter."""
    rng = np.random.default_rng(seed)
    artifact_ids = list(system.write_counts)
    if isinstance(volatility, (int, float)):
        v_of = {a: float(volatility) for a in artifact_ids}
    else:
        v_of = dict(zip(artifact_ids, volatility))
    n = len(system.agents)
    for _ in range(n_steps):
        for a in range(n):
            if rng.random() > p_act:
                continue
            aid = artifact_ids[rng.integers(len(artifact_ids))]
            if rng.random() < v_of[aid]:
                old = list(system.store.get(aid))
                system.agent_write(a, aid, old)  # same-size revision
                system.agent_read(a, aid)
            else:
                system.agent_read(a, aid)
    return system.stats
