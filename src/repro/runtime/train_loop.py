"""Fault-tolerant training loop.

Production behaviors, all exercised by tests on CPU:
  * auto-resume from the newest complete checkpoint (crash/restart);
  * periodic async checkpoints (host IO overlaps device compute);
  * straggler deadline: a step exceeding ``straggler_factor`` x the
    median step time is logged and counted (on a real multi-host
    deployment this feeds the coordinator's slow-host eviction; here it
    drives the same accounting so the policy is testable);
  * crash injection hook for fault-tolerance tests;
  * elastic restart: checkpoints are global-shape (see
    repro.checkpoint), so a run can resume on a different mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLMStream
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime import steps as step_factories


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 20260305


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_step: int
    losses: list
    resumed_from: Optional[int]
    straggler_events: int
    checkpoints: list


def run_training(cfg: ModelConfig, loop: TrainLoopConfig,
                 ckpt_dir, data_cfg: Optional[DataConfig] = None,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 crash_at_step: Optional[int] = None,
                 step_fn: Optional[Callable] = None) -> TrainReport:
    """Run (or resume) training; returns a report for tests/examples."""
    data_cfg = data_cfg or DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
        seed=loop.seed)
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-3, warmup_steps=5, total_steps=loop.total_steps)
    mgr = CheckpointManager(ckpt_dir)
    stream = SyntheticLMStream(data_cfg)

    key = jax.random.PRNGKey(loop.seed)
    params = tf.init_params(cfg, key)
    opt_state = adamw.init_state(opt_cfg, params)
    start_step = 0
    resumed_from = None
    latest = mgr.latest_step()
    if latest is not None:
        _, tree = mgr.restore(latest)
        params = jax.tree.map(
            lambda ref, x: jax.numpy.asarray(x, ref.dtype), params,
            tree["params"])
        opt_state = adamw.AdamWState(
            step=jax.numpy.asarray(tree["opt"]["step"]),
            mu=tree["opt"]["mu"], nu=tree["opt"]["nu"], error=None)
        start_step = latest
        resumed_from = latest

    if step_fn is None:
        step_fn = step_factories.value_and_grad_step(cfg)

    losses = []
    step_times = []
    stragglers = 0
    saved = []
    for step in range(start_step, loop.total_steps):
        if crash_at_step is not None and step == crash_at_step:
            raise RuntimeError(f"injected crash at step {step}")
        t0 = time.perf_counter()
        batch = stream.batch_at(step)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if step_times and dt > loop.straggler_factor * float(
                np.median(step_times)):
            stragglers += 1
        step_times.append(dt)
        losses.append(loss)
        if (step + 1) % loop.checkpoint_every == 0 \
                or step + 1 == loop.total_steps:
            mgr.save_async(step + 1, {
                "params": params,
                "opt": {"step": opt_state.step, "mu": opt_state.mu,
                        "nu": opt_state.nu}},
                meta={"arch": cfg.name, "loss": loss})
            saved.append(step + 1)
    mgr.wait()
    return TrainReport(
        steps_run=loop.total_steps - start_step,
        final_step=loop.total_steps, losses=losses,
        resumed_from=resumed_from, straggler_events=stragglers,
        checkpoints=saved)
