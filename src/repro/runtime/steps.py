"""Jitted step factories: train / prefill / decode, mesh-aware.

``make_*`` return (jitted_fn, in_shardings, out_shardings) so callers
(train loop, serving loop, dry-run) share one source of truth for the
distribution strategy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime import sharding as shd


@dataclasses.dataclass(frozen=True)
class StepOptions:
    zero: bool = True                 # ZeRO-1 moment sharding
    compress_grads: bool = False      # bf16 AR payload + error feedback
    donate: bool = True
    n_microbatches: int = 1           # gradient accumulation (memory)
    fsdp: bool = False                # params over 'data' too (ZeRO-3)


def loss_fn(params, cfg: ModelConfig, batch):
    return tf.forward_train(params, cfg, batch)


def microbatch_shape(batch_shape, n_micro: int):
    """(B, ...) specs -> (n_micro, B/n_micro, ...) specs (host-side
    pre-split layout; dim 0 is the scan dim and stays unsharded)."""
    if n_micro <= 1:
        return batch_shape

    def one(x):
        b = x.shape[0]
        assert b % n_micro == 0
        return jax.ShapeDtypeStruct(
            (n_micro, b // n_micro) + tuple(x.shape[1:]), x.dtype)

    return jax.tree.map(one, batch_shape)


def microbatch_split(batch, n_micro: int):
    """Host-side batch pre-split matching microbatch_shape."""
    if n_micro <= 1:
        return batch
    return jax.tree.map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                            + tuple(x.shape[1:])), batch)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    mesh: Mesh, params_shape, batch_shape,
                    options: StepOptions = StepOptions()):
    """Returns (fn, in_shardings, out_shardings).

    fn(params, opt_state, batch) -> (params, opt_state, metrics).
    Gradient psum over DP axes is inserted by XLA SPMD (params are
    replicated over DP, sharded over TP); ZeRO-1 shards moments over
    'data' on top.
    """
    p_specs = (shd.fsdp_param_specs(params_shape, mesh) if options.fsdp
               else shd.param_specs(params_shape))
    o_specs = shd.opt_state_specs(params_shape, mesh, zero=options.zero)
    nm = options.n_microbatches
    b_specs = shd.batch_specs(microbatch_shape(batch_shape, nm), mesh,
                              batch_dim=0 if nm <= 1 else 1)

    def grad_of(params, batch):
        if options.n_microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, cfg, batch)
        # Gradient accumulation: the batch arrives PRE-SPLIT as
        # (n_micro, B/n_micro, ...) with the microbatch dim unsharded
        # (see microbatch_shape) - reshaping a dp-sharded batch inside
        # the step would force an SPMD reshard/replication.  Grads
        # accumulate in fp32.
        nm = options.n_microbatches
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, cfg, mb)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) / nm, g_acc, g)
            return (loss_acc + loss / nm, g_acc), None

        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_g), batch)
        return loss, grads

    def step(params, opt_state, batch):
        loss, grads = grad_of(params, batch)
        if options.compress_grads and opt_state.error is not None:
            grads, new_err = adamw.compress_grads(grads, opt_state.error)
            opt_state = opt_state._replace(error=new_err)
        params, opt_state, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    def opt_tree_specs():
        return adamw.AdamWState(
            step=P(), mu=o_specs, nu=o_specs,
            error=(p_specs if options.compress_grads else None))

    in_sh = (shd.to_named(p_specs, mesh),
             shd.to_named(opt_tree_specs(), mesh),
             shd.to_named(b_specs, mesh))
    out_sh = (in_sh[0], in_sh[1], None)
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1) if options.donate else ())
    return fn, in_sh, out_sh


def value_and_grad_step(cfg: ModelConfig):
    """Un-sharded train step for CPU smoke use."""
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        params, opt_state, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, params_shape,
                      batch_shape, cache_shape,
                      options: StepOptions = StepOptions()):
    p_specs = (shd.fsdp_param_specs(params_shape, mesh) if options.fsdp
               else shd.param_specs(params_shape))
    b_specs = shd.batch_specs(batch_shape, mesh)
    c_specs = shd.cache_specs(cache_shape, cfg, mesh)

    def step(params, batch, cache):
        tokens = batch["tokens"]
        ctx = batch.get("vision_embeds", batch.get("frames"))
        logits, cache = tf.prefill(params, cfg, tokens, cache,
                                   context=ctx)
        return logits, cache

    in_sh = (shd.to_named(p_specs, mesh), shd.to_named(b_specs, mesh),
             shd.to_named(c_specs, mesh))
    out_sh = (None, in_sh[2])
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(2,))
    return fn, in_sh, out_sh


def make_decode_step(cfg: ModelConfig, mesh: Mesh, params_shape,
                     cache_shape,
                     options: StepOptions = StepOptions()):
    """serve_step: one new token against the KV cache (the decode_* and
    long_* shapes lower THIS, not train_step)."""
    p_specs = (shd.fsdp_param_specs(params_shape, mesh) if options.fsdp
               else shd.param_specs(params_shape))
    c_specs = shd.cache_specs(cache_shape, cfg, mesh)
    tok_spec = shd.batch_specs(
        {"token": jax.ShapeDtypeStruct(
            (cache_shape["length"].shape[0], 1), jnp.int32)}, mesh)

    def step(params, token, cache):
        return tf.decode_step(params, cfg, token, cache)

    in_sh = (shd.to_named(p_specs, mesh),
             shd.to_named(tok_spec["token"], mesh),
             shd.to_named(c_specs, mesh))
    out_sh = (None, in_sh[2])
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(2,))
    return fn, in_sh, out_sh
