"""Distributed runtime: sharding rules, step factories, loops, coherent
multi-agent serving."""

from repro.runtime import sharding, steps
from repro.runtime.train_loop import (TrainLoopConfig, TrainReport,
                                      run_training)
from repro.runtime.coherent_serving import (CoherentServingSystem,
                                            ServingStats, run_workload)

__all__ = ["sharding", "steps", "TrainLoopConfig", "TrainReport",
           "run_training", "CoherentServingSystem", "ServingStats",
           "run_workload"]
