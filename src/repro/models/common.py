"""Shared model building blocks (pure JAX, flax-free pytree params).

Every block is a pair of functions: ``<block>_init(key, ...) -> params``
and ``<block>_apply(params, x, ...) -> y``.  Params are plain nested
dicts so pjit sharding rules can pattern-match on path names.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


def dtype_of(name: str):
    return DTYPES[name]


# ------------------------------ init ---------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * 0.02).astype(dtype)


# ------------------------------ norms --------------------------------

def norm_init(d: int, kind: str, dtype, use_bias: bool = False):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm" and use_bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
    else:  # layernorm
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y.astype(x.dtype) * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y


# ------------------------------ rope ---------------------------------

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float = 10000.0):
    """positions: (...,) int -> (cos, sin) of shape (..., head_dim/2)."""
    freqs = 1.0 / (theta ** (
        jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope_apply(x: jax.Array, cos: jax.Array, sin: jax.Array):
    """x: (..., L, H, D) or (..., L, D); cos/sin: (..., L, D/2)
    broadcastable after head-dim insertion."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    if x.ndim == cos.ndim + 1:     # (..., L, H, D) vs (..., L, D/2)
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    return jnp.concatenate([rot1, rot2], axis=-1).astype(x.dtype)


# --------------------------- activations ------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu,
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


# ------------------------------ MLP ----------------------------------

def glu_mlp_init(key, d_model: int, d_ff: int, dtype,
                 use_bias: bool = False):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_gate": dense_init(k1, d_model, d_ff, dtype),
         "w_up": dense_init(k2, d_model, d_ff, dtype),
         "w_down": dense_init(k3, d_ff, d_model, dtype)}
    if use_bias:
        p["b_gate"] = jnp.zeros((d_ff,), dtype)
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def glu_mlp_apply(p, x, act: str = "silu"):
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    if "b_gate" in p:
        g = g + p["b_gate"]
        u = u + p["b_up"]
    y = act_fn(act)(g) * u
    y = y @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


def mlp_init(key, d_model: int, d_ff: int, dtype, use_bias: bool = True):
    """Plain 2-layer MLP (whisper-style)."""
    k1, k2 = jax.random.split(key)
    p = {"w_in": dense_init(k1, d_model, d_ff, dtype),
         "w_out": dense_init(k2, d_ff, d_model, dtype)}
    if use_bias:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(p, x, act: str = "gelu"):
    y = x @ p["w_in"]
    if "b_in" in p:
        y = y + p["b_in"]
    y = act_fn(act)(y)
    y = y @ p["w_out"]
    if "b_out" in p:
        y = y + p["b_out"]
    return y


# ------------------------------ loss ----------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy in fp32.  logits (..., V), labels
    (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# --------------------------- param stacking ---------------------------

def stack_layers(key, n: int, init_fn):
    """Initialize n structurally-identical layers and stack each leaf on
    a leading layer axis - the scan-over-layers representation that keeps
    the HLO size depth-independent."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def params_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(params))


def params_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
