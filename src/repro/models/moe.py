"""Mixture-of-Experts FFN with fixed-capacity scatter dispatch.

Expert-parallel-friendly formulation: tokens are scattered into a
(E, capacity, d) buffer, the expert GLU runs as a single batched einsum
over the expert dim (shardable over the mesh 'model' axis = EP), and
results are gathered back with the router gate weights.  Dropped tokens
(capacity overflow) pass through the residual, standard Switch/GShard
semantics.  FLOPs scale with *active* parameters (top-k), which is what
MODEL_FLOPS = 6*N_active*D accounting expects.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import act_fn, dense_init


def moe_init(key, cfg: ModelConfig, dtype):
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, m.n_experts, dtype, scale=0.1),
        "expert_gate": _experts(ks[1], m.n_experts, d, m.d_expert,
                                dtype),
        "expert_up": _experts(ks[2], m.n_experts, d, m.d_expert, dtype),
        "expert_down": _experts(ks[3], m.n_experts, m.d_expert, d,
                                dtype, transpose=True),
    }
    if m.n_shared:
        p["shared_gate"] = dense_init(ks[4], d, m.n_shared * m.d_expert,
                                      dtype)
        k5, k6 = jax.random.split(ks[4])
        p["shared_up"] = dense_init(k5, d, m.n_shared * m.d_expert, dtype)
        p["shared_down"] = dense_init(k6, m.n_shared * m.d_expert, d,
                                      dtype)
    return p


def _experts(key, e, d_in, d_out, dtype, transpose=False):
    import math
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32)
            * std).astype(dtype)


def _dispatch_one_slice(p, m: MoEConfig, xt, act: str, capacity: int):
    """Route one dispatch slice of tokens (T_loc, d) -> (y, probs, sel).

    All gathers/scatters here stay within the slice, so when slices are
    laid out one-per-data-shard the dispatch needs NO cross-shard
    communication; only the expert einsum (E sharded over 'model') and
    the final combine all-reduce touch the interconnect."""
    t, d = xt.shape
    logits = (xt @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    # keep the combine chain in bf16: the (T*k, d) gathered-token tensor
    # rides the expert->token all-to-all, and an fp32 gate cotangent
    # doubles that payload (SSPerf iter 9: 13.2 -> ~6.6 GB/device).
    gate_vals = gate_vals.astype(xt.dtype)

    # position of each (token, slot) within its expert's buffer
    sel = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)  # (T,k,E)
    sel_flat = sel.reshape(t * m.top_k, m.n_experts)
    pos = jnp.cumsum(sel_flat, axis=0) - sel_flat            # (T*k, E)
    pos_in_e = jnp.sum(pos * sel_flat, axis=-1)              # (T*k,)
    expert_of = idx.reshape(-1)                              # (T*k,)
    keep = pos_in_e < capacity

    # scatter tokens into (E*C, d) - slice-local
    slot = expert_of * capacity + jnp.minimum(pos_in_e, capacity - 1)
    token_of = jnp.repeat(jnp.arange(t), m.top_k)
    buf = jnp.zeros((m.n_experts * capacity, d), xt.dtype)
    buf = buf.at[slot].add(
        jnp.where(keep[:, None], xt[token_of], 0))
    buf = buf.reshape(m.n_experts, capacity, d)

    # batched expert GLU (EP-shardable einsum over the expert dim)
    g = jnp.einsum("ecd,edf->ecf", buf, p["expert_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["expert_up"])
    h = act_fn(act)(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["expert_down"])
    out = out.reshape(m.n_experts * capacity, d)

    # gather back with gate weights (combine: all-reduce over 'model')
    contrib = out[slot] * jnp.where(
        keep, gate_vals.reshape(-1), 0.0)[:, None].astype(out.dtype)
    y = jnp.zeros_like(xt).at[token_of].add(contrib)
    return y, probs, sel


def moe_apply(p, cfg: ModelConfig, x, act: str = "silu"):
    """x: (B, S, d) -> (y, aux_loss).

    With ``dispatch_slices == n`` the flat token stream is viewed as
    (n, T/n, d) - matching the DP sharding of the batch - and routing is
    vmapped per slice with per-slice capacity.  This removes the
    (E, C, d) dispatch-buffer partial-sum across the data axis that
    dominates MoE collectives under plain SPMD scatter (measured:
    43.7 GB/device/step all-reduce on olmoe-1b-7b train_4k)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    n_slices = max(1, m.dispatch_slices)
    if t % n_slices:
        n_slices = 1
    t_loc = t // n_slices
    capacity = int(m.capacity_factor * m.top_k * t_loc / m.n_experts)
    capacity = max(capacity, m.top_k)

    if n_slices == 1:
        y, probs, sel = _dispatch_one_slice(p, m, xt, act, capacity)
    else:
        xs = xt.reshape(n_slices, t_loc, d)
        if m.dispatch_axes:
            xs = jax.lax.with_sharding_constraint(
                xs, jax.sharding.PartitionSpec(
                    tuple(m.dispatch_axes), None, None))
        y, probs, sel = jax.vmap(
            lambda xt_loc: _dispatch_one_slice(p, m, xt_loc, act,
                                               capacity))(xs)
        y = y.reshape(t, d)
        probs = probs.reshape(t, m.n_experts)
        sel = sel.reshape(t, m.top_k, m.n_experts)

    if m.n_shared:
        sg = xt @ p["shared_gate"]
        su = xt @ p["shared_up"]
        y = y + (act_fn(act)(sg) * su) @ p["shared_down"]

    # Switch-style load-balance aux loss
    me = probs.mean(axis=0)                                  # (E,)
    ce = (sel.sum(axis=1) > 0).astype(jnp.float32).mean(axis=0)
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
    return y.reshape(b, s, d), aux
