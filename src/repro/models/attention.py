"""Attention blocks: GQA/MQA self-attention (train + cached decode),
qk-norm, cross-attention, and DeepSeek-style MLA.

All math runs through jnp einsums (the XLA path used for the dry-run and
CPU tests); on TPU the prefill/train path can be routed through the
``repro.kernels.flash_attention`` Pallas kernel and decode through
``decode_attention`` via the ``use_pallas`` flag.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.common import (dense_init, norm_apply, norm_init,
                                 rope_angles, rope_apply)

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Per-layer-stack KV cache: (L, B, Hkv, Lmax, D)."""
    k: jax.Array
    v: jax.Array
    length: jax.Array  # (B,) current valid length (shared across layers)


# --------------------------- GQA attention ---------------------------

def gqa_init(key, cfg: ModelConfig, dtype):
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.kv_head_dim()
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "wq": dense_init(k1, d, hq * hd, dtype),
        "wk": dense_init(k2, d, hkv * hd, dtype),
        "wv": dense_init(k3, d, hkv * hd, dtype),
        "wo": dense_init(k4, hq * hd, d, dtype),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm", dtype)
        p["k_norm"] = norm_init(hd, "rmsnorm", dtype)
    if cfg.use_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x):
    b, s, _ = x.shape
    hd = cfg.kv_head_dim()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.use_qk_norm:
        q = norm_apply(p["q_norm"], q)
        k = norm_apply(p["k_norm"], k)
    return q, k, v


#: query-chunk size for memory-efficient attention; chunking engages
#: whenever S*T would materialize more than CHUNK_Q^2 logits per head.
CHUNK_Q = 512


def _sdpa_block(q, k, v, *, causal: bool, q_offset,
                kv_len: Optional[jax.Array]):
    """One query block: q (B,S,Hq,D) vs full k/v (B,T,Hkv,D).

    K/V stay in their storage dtype; the MXU accumulates in fp32 via
    preferred_element_type, so no fp32 copy of the (possibly 32k-deep)
    cache is ever materialized (SSPerf: -2.1 GB/layer temps on
    command-r decode_32k)."""
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    kpos = jnp.arange(t)[None, None, None, None, :]
    if causal:
        qo = jnp.asarray(q_offset)
        if qo.ndim == 1:  # per-batch offsets (cached prefill)
            qo = qo[:, None, None, None, None]
        qpos = qo + jnp.arange(s)[None, None, None, :, None]
        logits = jnp.where(kpos <= qpos, logits, NEG_INF)
    if kv_len is not None:
        valid = kpos < kv_len[:, None, None, None, None]
        logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, hq, d).astype(q.dtype)


def _sdpa(q, k, v, *, causal: bool, q_offset=0,
          kv_len: Optional[jax.Array] = None):
    """Memory-efficient SDPA: full-logit path for short q, query-chunked
    scan (checkpointed: logits are recomputed in backward, never stored)
    for long sequences.  K/V stay resident; the (S x T) logit tensor is
    only ever materialized one q-chunk at a time - the XLA analogue of
    the Pallas flash kernel's VMEM tiling, used by the dry-run path.
    """
    b, s, hq, d = q.shape
    if s <= CHUNK_Q:
        return _sdpa_block(q, k, v, causal=causal, q_offset=q_offset,
                           kv_len=kv_len)
    chunk = CHUNK_Q
    assert s % chunk == 0, "pad seq to a multiple of the q-chunk"
    nc = s // chunk
    qs = q.reshape(b, nc, chunk, hq, d).swapaxes(0, 1)

    def body(carry, inp):
        qc, idx = inp
        out = _sdpa_block(qc, k, v, causal=causal,
                          q_offset=q_offset + idx * chunk, kv_len=kv_len)
        return carry, out

    _, outs = jax.lax.scan(jax.checkpoint(body), 0,
                           (qs, jnp.arange(nc)))
    return outs.swapaxes(0, 1).reshape(b, s, hq, d)


def gqa_apply(p, cfg: ModelConfig, x, positions,
              cache_kv=None, cache_len=None):
    """Self-attention.  Train/prefill: cache_kv None -> full causal.
    Decode: cache_kv = (k,v) with shapes (B, Lmax, Hkv, D); x is the new
    token(s); returns (y, (new_k, new_v))."""
    b, s, _ = x.shape
    hd = cfg.kv_head_dim()
    q, k, v = _project_qkv(p, cfg, x)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = rope_apply(q, cos, sin)
    k = rope_apply(k, cos, sin)

    if cache_kv is None:
        out = _sdpa(q, k, v, causal=True)
        new_cache = (k, v)
    else:
        ck, cv = cache_kv
        # insert the new kv at per-batch position cache_len (decode: s=1)
        ck = _scatter_time(ck, k, cache_len)
        cv = _scatter_time(cv, v, cache_len)
        # s == 1 (decode): the kv_len mask alone is the causal rule;
        # s > 1 (cached prefill): causal with per-batch offsets.
        out = _sdpa(q, ck, cv, causal=s > 1, q_offset=cache_len,
                    kv_len=cache_len + s)
        new_cache = (ck, cv)
    y = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y, new_cache


def _scatter_time(cache, new, lengths):
    """Write ``new`` (B, s, ...) into ``cache`` (B, T, ...) at per-batch
    time offset ``lengths`` (B,)."""
    return jax.vmap(
        lambda c, n, l: jax.lax.dynamic_update_slice_in_dim(c, n, l, 0)
    )(cache, new, lengths)


# -------------------------- cross-attention --------------------------

def cross_attn_init(key, cfg: ModelConfig, dtype, kv_dim: int = 0):
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.kv_head_dim()
    kv_dim = kv_dim or d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, hq * hd, dtype),
        "wk": dense_init(k2, kv_dim, hkv * hd, dtype),
        "wv": dense_init(k3, kv_dim, hkv * hd, dtype),
        "wo": dense_init(k4, hq * hd, d, dtype),
        # llama-3.2-vision style tanh gate, init 0 (identity at start)
        "gate": jnp.zeros((), jnp.float32),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm", dtype)
        p["k_norm"] = norm_init(hd, "rmsnorm", dtype)
    return p


def cross_attn_apply(p, cfg: ModelConfig, x, context,
                     cached_kv=None):
    """x: (B,S,d); context: (B,T,kv_dim) frozen encoder/vision states.
    The projected context kv can be precomputed once per request and
    passed as ``cached_kv`` (the coherence fill for cross-modal
    artifacts)."""
    b, s, _ = x.shape
    hd = cfg.kv_head_dim()
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    if cached_kv is None:
        t = context.shape[1]
        k = (context @ p["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
        v = (context @ p["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
    else:
        k, v = cached_kv
    if cfg.use_qk_norm:
        q = norm_apply(p["q_norm"], q)
        k = norm_apply(p["k_norm"], k)
    out = _sdpa(q, k, v, causal=False)
    y = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    gate = jnp.tanh(p["gate"]).astype(y.dtype)
    return y * gate, (k, v)


# ------------------------------- MLA ---------------------------------

def mla_init(key, cfg: ModelConfig, dtype):
    """DeepSeek-V2 multi-head latent attention.  The KV cache stores only
    the compressed latent c_kv (rank 512) + the shared rope key (64) per
    token - an 8-16x cache shrink, which in coherence terms shrinks the
    *fetch payload* of every artifact re-injection."""
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "w_dq": dense_init(ks[0], d, h * qk_head, dtype),
        "w_dkv": dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim,
                            dtype),
        "kv_norm": norm_init(m.kv_lora_rank, "rmsnorm", dtype),
        "w_uk": dense_init(ks[2], m.kv_lora_rank,
                           h * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[3], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(ks[4], h * m.v_head_dim, d, dtype),
    }
    return p


def mla_apply(p, cfg: ModelConfig, x, positions,
              cache_ckv=None, cache_len=None):
    """Returns (y, (c_kv, k_pe)) where the cache is the compressed
    latent stream."""
    m: MLAConfig = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    q = (x @ p["w_dq"]).reshape(b, s, h,
                                m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = (q[..., : m.qk_nope_head_dim],
                    q[..., m.qk_nope_head_dim:])
    dkv = x @ p["w_dkv"]
    c_kv = norm_apply(p["kv_norm"], dkv[..., : m.kv_lora_rank])
    k_pe = dkv[..., m.kv_lora_rank:]                 # (b, s, rope_dim)

    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_pe = rope_apply(q_pe, cos, sin)
    k_pe = rope_apply(k_pe, cos, sin)                # shared single head

    if cache_ckv is not None:
        old_ckv, old_kpe = cache_ckv
        c_kv_full = _scatter_time(old_ckv, c_kv, cache_len)
        k_pe_full = _scatter_time(old_kpe, k_pe, cache_len)
        causal = s > 1
        kv_len = cache_len + s
        q_base = cache_len
    else:
        c_kv_full, k_pe_full = c_kv, k_pe
        causal = True
        kv_len = None
        q_base = None

    t = c_kv_full.shape[1]
    k_nope = (c_kv_full @ p["w_uk"]).reshape(b, t, h, m.qk_nope_head_dim)
    v = (c_kv_full @ p["w_uv"]).reshape(b, t, h, m.v_head_dim)

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    def block(qn, qp, q_off):
        sc = qn.shape[1]
        logits = (jnp.einsum("bshd,bthd->bhst", qn.astype(jnp.float32),
                             k_nope.astype(jnp.float32))
                  + jnp.einsum("bshd,btd->bhst", qp.astype(jnp.float32),
                               k_pe_full.astype(jnp.float32))) * scale
        kpos = jnp.arange(t)[None, None, None, :]
        if causal:
            qo = jnp.asarray(q_off)
            if qo.ndim == 1:
                qo = qo[:, None, None, None]
            qpos = qo + jnp.arange(sc)[None, None, :, None]
            logits = jnp.where(kpos <= qpos, logits, NEG_INF)
        if kv_len is not None:
            logits = jnp.where(kpos < kv_len[:, None, None, None],
                               logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhst,bthd->bshd", probs,
                          v.astype(jnp.float32))

    base = q_base if q_base is not None else (t - s if causal else 0)
    if s <= CHUNK_Q:
        out = block(q_nope, q_pe, base)
    else:
        assert s % CHUNK_Q == 0
        nc = s // CHUNK_Q
        qn_c = q_nope.reshape(b, nc, CHUNK_Q, h, -1).swapaxes(0, 1)
        qp_c = q_pe.reshape(b, nc, CHUNK_Q, h, -1).swapaxes(0, 1)

        def body(carry, inp):
            qn, qp, idx = inp
            return carry, block(qn, qp, base + idx * CHUNK_Q)

        _, outs = jax.lax.scan(jax.checkpoint(body), 0,
                               (qn_c, qp_c, jnp.arange(nc)))
        out = outs.swapaxes(0, 1).reshape(b, s, h, m.v_head_dim)

    y = out.reshape(b, s, h * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return y, (c_kv_full, k_pe_full)
