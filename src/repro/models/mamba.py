"""Mamba-1 selective SSM block (Jamba's sequence mixer).

Training/prefill uses a chunk-checkpointed sequential scan: the outer
``lax.scan`` walks chunks (each chunk body wrapped in ``jax.checkpoint``
so the backward pass stores only chunk-boundary states - O(S/chunk)
memory instead of O(S)), the inner scan walks steps.  This keeps the HLO
depth-independent and the activation footprint bounded; the SSD-style
chunked-matmul reformulation (intra-chunk work on the MXU) is the
recorded perf-iteration candidate for real hardware.

Decode carries (conv_state, ssm_state) per layer: O(1) per token - the
property that makes the hybrid eligible for the long_500k shape.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig
from repro.models.common import dense_init


class MambaState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, d_inner)
    ssm: jax.Array    # (B, d_inner, d_state)


def _dims(cfg: ModelConfig):
    m: MambaConfig = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or max(1, math.ceil(cfg.d_model / 16))
    return m, d_inner, dt_rank


def mamba_init(key, cfg: ModelConfig, dtype):
    m, d_inner, dt_rank = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "w_in": dense_init(ks[0], d, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, d_inner),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_x_dbc": dense_init(ks[2], d_inner,
                              dt_rank + 2 * m.d_state, dtype),
        "w_dt": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (d_inner,), jnp.float32,
                                        1e-3, 1e-1), 1e-4, None))
        ).astype(jnp.float32),
        # S4D-real init: A = -(1..d_state), log-parameterized
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, m.d_state + 1, dtype=jnp.float32),
            (d_inner, m.d_state))),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(ks[5], d_inner, d, dtype),
    }
    return p


def _conv1d_causal(p, cfg, x, conv_state=None):
    """Depthwise causal conv over time; returns (y, new_state)."""
    m, d_inner, _ = _dims(cfg)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], m.d_conv - 1, d_inner), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)           # (B, T+c-1, D)
    new_state = xp[:, -(m.d_conv - 1):, :]
    # depthwise conv as a sum of shifted scales (d_conv is tiny: 4)
    y = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i]
            for i in range(m.d_conv))
    return jax.nn.silu(y + p["conv_b"]), new_state


def _selective_params(p, cfg, xc):
    """xc: (B, T, d_inner) post-conv -> (dt, B_t, C_t)."""
    m, d_inner, dt_rank = _dims(cfg)
    dbc = xc @ p["w_x_dbc"]
    dt = jax.nn.softplus(
        (dbc[..., :dt_rank] @ p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])                               # (B,T,d_inner)
    b_t = dbc[..., dt_rank:dt_rank + m.d_state].astype(jnp.float32)
    c_t = dbc[..., dt_rank + m.d_state:].astype(jnp.float32)
    return dt, b_t, c_t


def _ssm_step(a, h, dt_t, b_t, c_t, x_t):
    """One recurrence step.  h: (B, D, N); dt/x: (B, D); b/c: (B, N)."""
    da = jnp.exp(dt_t[..., None] * a)                 # (B, D, N)
    dbx = dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
    h = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_t)
    return h, y


def mamba_apply(p, cfg: ModelConfig, x, state: MambaState | None = None):
    """x: (B, T, d_model).  Returns (y, new_state)."""
    m, d_inner, _ = _dims(cfg)
    a = -jnp.exp(p["a_log"])                          # (D, N)
    xz = x @ p["w_in"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]
    conv_state = state.conv if state is not None else None
    xc, new_conv = _conv1d_causal(p, cfg, xs, conv_state)
    dt, b_t, c_t = _selective_params(p, cfg, xc)
    x32 = xc.astype(jnp.float32)

    b_sz, t, _ = x.shape
    h0 = (state.ssm if state is not None
          else jnp.zeros((b_sz, d_inner, m.d_state), jnp.float32))

    if t == 1:  # decode fast path
        h, y = _ssm_step(a, h0, dt[:, 0], b_t[:, 0], c_t[:, 0], x32[:, 0])
        y = y[:, None, :]
    else:
        chunk = min(m.chunk, t)
        assert t % chunk == 0, "seq len must divide mamba chunk"
        nc = t // chunk

        def chunk_body(h, inp):
            dt_c, b_c, c_c, x_c = inp     # (chunk, B, ...)

            def step(h, s_inp):
                dt_s, b_s, c_s, x_s = s_inp
                h, y = _ssm_step(a, h, dt_s, b_s, c_s, x_s)
                return h, y

            h, ys = jax.lax.scan(step, h, (dt_c, b_c, c_c, x_c))
            return h, ys

        # time-major chunks: (nc, chunk, B, ...)
        def tm(arr):
            return arr.swapaxes(0, 1).reshape(nc, chunk, b_sz, -1)

        h, ys = jax.lax.scan(
            jax.checkpoint(chunk_body),
            h0, (tm(dt), tm(b_t), tm(c_t), tm(x32)))
        y = ys.reshape(t, b_sz, d_inner).swapaxes(0, 1)

    y = y + x32 * p["d_skip"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    new_state = MambaState(conv=new_conv,
                           ssm=h.astype(jnp.float32))
    return y, new_state


def mamba_state_init(cfg: ModelConfig, batch: int) -> MambaState:
    m, d_inner, _ = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, m.d_conv - 1, d_inner),
                       jnp.bfloat16 if cfg.dtype == "bfloat16"
                       else jnp.float32),
        ssm=jnp.zeros((batch, d_inner, m.d_state), jnp.float32))
