"""Config-driven model zoo (pure JAX, pytree params)."""

from repro.models.transformer import (init_params, forward_train, prefill,
                                      decode_step, init_cache, encode,
                                      layer_specs, split_pattern)
from repro.models.common import params_count, params_bytes

__all__ = ["init_params", "forward_train", "prefill", "decode_step",
           "init_cache", "encode", "layer_specs", "split_pattern",
           "params_count", "params_bytes"]
