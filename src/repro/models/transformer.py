"""Config-driven model assembly for every assigned architecture.

The layer sequence of each architecture is a *pattern*: an optional
unstacked prefix (e.g. DeepSeek's dense first layer) followed by a
repeating superblock (period 1 for homogeneous stacks, 8 for Jamba's
attn:mamba 1:7 interleave, 5 for llama-vision's cross-attention cadence).
Superblocks are scanned with stacked params, so HLO size is independent
of depth - essential for compiling 100-layer x 512-device programs on
this container.

Modes:
  train    full causal forward -> loss (+ MoE aux)
  prefill  full causal forward -> logits of last token + KV/state cache
  decode   single-token step against the cache

The cache pytree mirrors the superblock structure; entries are
per-mixer: attn {k,v}, MLA {ckv,kpe}, mamba {conv,ssm}, rwkv
{tm,cm,wkv}, cross {k,v} (encoder/vision KV, write-once = a frozen
low-volatility artifact in coherence terms).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (cross_entropy, dtype_of, embed_init,
                                 glu_mlp_init, glu_mlp_apply, mlp_init,
                                 mlp_apply, norm_apply, norm_init,
                                 stack_layers)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str          # attn | mla | mamba | rwkv | cross
    moe: bool
    cross: bool         # additional cross-attn sublayer (whisper dec)


def layer_specs(cfg: ModelConfig) -> list[LayerSpec]:
    specs = []
    for i in range(cfg.n_layers):
        if cfg.is_cross_layer(i):
            mixer = "cross"
        elif cfg.mla is not None:
            mixer = "mla"
        else:
            mixer = cfg.layer_kind(i)
        specs.append(LayerSpec(
            mixer=mixer,
            moe=cfg.is_moe_layer(i),
            cross=(cfg.encoder_layers > 0),
        ))
    return specs


def split_pattern(specs: list[LayerSpec]) -> tuple[int, int]:
    """Return (prefix_len, period) minimizing the *unstacked* HLO size
    (prefix + period), so e.g. DeepSeek's dense first layer becomes a
    1-layer prefix + period-1 stack rather than one giant superblock,
    and Jamba resolves to its natural period-8 interleave."""
    n = len(specs)
    best: tuple[int, int] | None = None
    for prefix in range(0, n):
        rest = specs[prefix:]
        m = len(rest)
        for period in range(1, m + 1):
            if m % period:
                continue
            if all(rest[i] == rest[i % period] for i in range(m)):
                cand = (prefix, period)
                if best is None or (cand[0] + cand[1], cand[1]) < (
                        best[0] + best[1], best[1]):
                    best = cand
                break  # larger periods at this prefix are never better
    return best if best is not None else (n, 1)


# ----------------------------- layer ---------------------------------

def layer_init(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": norm_init(cfg.d_model, cfg.norm, dtype,
                                            cfg.use_bias)}
    if spec.mixer == "attn":
        p["mixer"] = attn.gqa_init(ks[0], cfg, dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn.mla_init(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_mod.mamba_init(ks[0], cfg, dtype)
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv_mod.rwkv_time_mix_init(ks[0], cfg, dtype)
    elif spec.mixer == "cross":
        p["mixer"] = attn.cross_attn_init(ks[0], cfg, dtype)
    if spec.cross:
        p["cross_norm"] = norm_init(cfg.d_model, cfg.norm, dtype,
                                    cfg.use_bias)
        p["cross"] = attn.cross_attn_init(ks[1], cfg, dtype)
    p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype, cfg.use_bias)
    if spec.moe:
        p["ffn"] = moe_mod.moe_init(ks[2], cfg, dtype)
    elif spec.mixer == "rwkv":
        p["ffn"] = rwkv_mod.rwkv_channel_mix_init(ks[2], cfg, dtype)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_d_ff:
            d_ff = cfg.moe.dense_d_ff
        if cfg.family == "audio":
            p["ffn"] = mlp_init(ks[2], cfg.d_model, d_ff, dtype,
                                use_bias=True)
        else:
            p["ffn"] = glu_mlp_init(ks[2], cfg.d_model, d_ff, dtype,
                                    cfg.use_bias)
    return p


def cache_init_layer(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, ctx_len: int, dtype):
    """Empty cache entry for one layer."""
    hd = cfg.kv_head_dim()
    c: dict[str, Any] = {}
    if spec.mixer == "attn":
        c["k"] = jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype)
        c["v"] = jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype)
    elif spec.mixer == "mla":
        m = cfg.mla
        c["ckv"] = jnp.zeros((batch, max_len, m.kv_lora_rank), dtype)
        c["kpe"] = jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)
    elif spec.mixer == "mamba":
        st = mamba_mod.mamba_state_init(cfg, batch)
        c["conv"], c["ssm"] = st.conv, st.ssm
    elif spec.mixer == "rwkv":
        st = rwkv_mod.rwkv_state_init(cfg, batch)
        c["tm"], c["cm"], c["wkv"] = st.tm_shift, st.cm_shift, st.wkv
    elif spec.mixer == "cross":
        c["xk"] = jnp.zeros((batch, ctx_len, cfg.n_kv_heads, hd), dtype)
        c["xv"] = jnp.zeros((batch, ctx_len, cfg.n_kv_heads, hd), dtype)
    if spec.cross:
        c["enc_k"] = jnp.zeros((batch, ctx_len, cfg.n_kv_heads, hd), dtype)
        c["enc_v"] = jnp.zeros((batch, ctx_len, cfg.n_kv_heads, hd), dtype)
    return c


def layer_apply(p, cfg: ModelConfig, spec: LayerSpec, x, *,
                positions, context=None, cache=None, cache_len=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}
    h = norm_apply(p["norm1"], x, cfg.norm)

    build = cache is not None  # train mode keeps no cache
    if spec.mixer == "attn":
        kv = (cache["k"], cache["v"]) if build else None
        y, kv_out = attn.gqa_apply(p["mixer"], cfg, h, positions,
                                   cache_kv=kv, cache_len=cache_len)
        if build:
            new_cache["k"], new_cache["v"] = kv_out
    elif spec.mixer == "mla":
        ckv = (cache["ckv"], cache["kpe"]) if build else None
        y, kv_out = attn.mla_apply(p["mixer"], cfg, h, positions,
                                   cache_ckv=ckv, cache_len=cache_len)
        if build:
            new_cache["ckv"], new_cache["kpe"] = kv_out
    elif spec.mixer == "mamba":
        st = (mamba_mod.MambaState(cache["conv"], cache["ssm"])
              if build else None)
        y, st_out = mamba_mod.mamba_apply(p["mixer"], cfg, h, st)
        if build:
            new_cache["conv"], new_cache["ssm"] = st_out.conv, st_out.ssm
    elif spec.mixer == "rwkv":
        tm = cache["tm"] if build else None
        wkv = cache["wkv"] if build else None
        y, tm_out, wkv_out = rwkv_mod.rwkv_time_mix_apply(
            p["mixer"], cfg, h, tm, wkv)
        if build:
            new_cache["tm"], new_cache["wkv"] = tm_out, wkv_out
    elif spec.mixer == "cross":
        cached = ((cache["xk"], cache["xv"])
                  if build and context is None else None)
        y, kv_out = attn.cross_attn_apply(p["mixer"], cfg, h, context,
                                          cached_kv=cached)
        if build:
            new_cache["xk"], new_cache["xv"] = kv_out
    x = x + y

    if spec.cross:
        h = norm_apply(p["cross_norm"], x, cfg.norm)
        cached = ((cache["enc_k"], cache["enc_v"])
                  if build and context is None else None)
        y, kv_out = attn.cross_attn_apply(p["cross"], cfg, h, context,
                                          cached_kv=cached)
        if build:
            new_cache["enc_k"], new_cache["enc_v"] = kv_out
        x = x + y

    h = norm_apply(p["norm2"], x, cfg.norm)
    if spec.moe:
        y, aux = moe_mod.moe_apply(p["ffn"], cfg, h, cfg.hidden_act)
    elif spec.mixer == "rwkv":
        cm = cache["cm"] if build else None
        y, cm_out = rwkv_mod.rwkv_channel_mix_apply(p["ffn"], cfg, h, cm)
        if build:
            new_cache["cm"] = cm_out
    elif cfg.family == "audio":
        y = mlp_apply(p["ffn"], h, "gelu")
    else:
        y = glu_mlp_apply(p["ffn"], h, cfg.hidden_act)
    x = x + y
    return x, new_cache, aux


# --------------------------- whole model ------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    specs = layer_specs(cfg)
    prefix, period = split_pattern(specs)
    n_super = (cfg.n_layers - prefix) // period

    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype,
                                cfg.use_bias),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], cfg.vocab_size,
                                       cfg.d_model, dtype)
    for i in range(prefix):
        params[f"prefix_{i}"] = layer_init(
            jax.random.fold_in(keys[2], i), cfg, specs[i], dtype)

    def superblock_init(k):
        sks = jax.random.split(k, period)
        return {f"sub{j}": layer_init(sks[j], cfg,
                                      specs[prefix + j], dtype)
                for j in range(period)}

    params["blocks"] = stack_layers(keys[3], n_super, superblock_init)

    if cfg.encoder_layers:
        enc_spec = LayerSpec(mixer="attn", moe=False, cross=False)
        params["encoder"] = {
            "blocks": stack_layers(
                keys[4], cfg.encoder_layers,
                lambda k: layer_init(k, cfg, enc_spec, dtype)),
            "final_norm": norm_init(cfg.d_model, cfg.norm, dtype,
                                    cfg.use_bias),
        }
    return params


def _sinusoid(positions, d):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, cfg: ModelConfig, frames):
    """Whisper-style encoder over stub frame embeddings (B, T, d)."""
    dtype = dtype_of(cfg.dtype)
    t = frames.shape[1]
    x = frames.astype(dtype) + _sinusoid(jnp.arange(t),
                                         cfg.d_model).astype(dtype)
    enc_spec = LayerSpec(mixer="attn", moe=False, cross=False)
    positions = jnp.arange(t)

    def body(x, block_p):
        # bidirectional self-attention: reuse gqa with causal off via
        # full-window trick (positions all equal -> no mask) is wrong;
        # instead call the internals directly.
        h = norm_apply(block_p["norm1"], x, cfg.norm)
        q, k, v = attn._project_qkv(block_p["mixer"], cfg, h)
        out = attn._sdpa(q, k, v, causal=False)
        b_, t_, _ = h.shape
        y = out.reshape(b_, t_, -1) @ block_p["mixer"]["wo"]
        if "bo" in block_p["mixer"]:
            y = y + block_p["mixer"]["bo"]
        x = x + y
        h = norm_apply(block_p["norm2"], x, cfg.norm)
        x = x + mlp_apply(block_p["ffn"], h, "gelu")
        return x, None

    # remat each encoder layer like the decoder superblocks: without it
    # the encoder's saved activations dominate whisper train memory
    # (measured 62 GB/device at train_4k).
    x, _ = jax.lax.scan(jax.checkpoint(body), x,
                        params["encoder"]["blocks"])
    return norm_apply(params["encoder"]["final_norm"], x, cfg.norm)


def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _logits(params, cfg: ModelConfig, x):
    head = (params["embed"] if cfg.tie_embeddings
            else params["lm_head"])
    return x @ head.T


def _run_layers(params, cfg: ModelConfig, x, *, positions,
                context=None, cache=None, cache_len=None):
    """Apply prefix layers + scanned superblocks.

    cache: pytree matching (prefix entries, stacked superblock entries);
    None in train mode.  Returns (x, new_cache, aux)."""
    specs = layer_specs(cfg)
    prefix, period = split_pattern(specs)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}

    for i in range(prefix):
        c = cache[f"prefix_{i}"] if cache is not None else None
        x, c_out, aux = layer_apply(
            params[f"prefix_{i}"], cfg, specs[i], x,
            positions=positions, context=context, cache=c,
            cache_len=cache_len)
        new_cache[f"prefix_{i}"] = c_out
        aux_total = aux_total + aux

    sub_specs = [specs[prefix + j] for j in range(period)]

    def constrain_residual(x):
        """Optional explicit activation sharding at layer boundaries
        (SSPerf: prevents XLA from flipping the residual stream into a
        d-sharded layout mid-stack, which costs an fp32 all-to-all at
        every norm/MoE boundary)."""
        axes = getattr(cfg, "residual_axes", ())
        if axes:
            from jax.sharding import PartitionSpec as P
            x = jax.lax.with_sharding_constraint(
                x, P(tuple(axes), *([None] * (x.ndim - 1))))
        return x

    def block_body(carry, inp):
        x, aux_acc = carry
        x = constrain_residual(x)
        block_p, block_c = inp
        c_outs = {}
        for j in range(period):
            c = block_c[f"sub{j}"] if block_c is not None else None
            x, c_out, aux = layer_apply(
                block_p[f"sub{j}"], cfg, sub_specs[j], x,
                positions=positions, context=context, cache=c,
                cache_len=cache_len)
            c_outs[f"sub{j}"] = c_out
            aux_acc = aux_acc + aux
        return (x, aux_acc), c_outs

    block_cache = cache["blocks"] if cache is not None else None
    n_super = (cfg.n_layers - prefix) // period
    if block_cache is None:
        # Train mode: remat each superblock (store only block-boundary
        # activations; interiors recompute in backward) - without this,
        # saved GLU hiddens alone are ~d_ff/d x the boundary footprint.
        body = jax.checkpoint(
            lambda carry, bp: block_body(carry, (bp, None)))
        (x, aux_total), c_stack = jax.lax.scan(
            body, (x, aux_total), params["blocks"])
        new_cache["blocks"] = c_stack
    else:
        # Serving path: fori_loop with the WHOLE stacked cache as loop
        # state, sliced/written in place per block.  A scan would carry
        # the cache as xs + ys, which XLA cannot alias across the while
        # loop - that double-buffers the entire KV cache (measured
        # +2.7 GB/device on command-r decode_32k, SSPerf iter 11).
        def loop_body(i, carry):
            x, cache_st, aux_acc = carry
            bp = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, i, 0, keepdims=False), params["blocks"])
            bc = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, i, 0, keepdims=False), cache_st)
            (x, aux_acc), c_outs = block_body((x, aux_acc), (bp, bc))
            cache_st = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0),
                cache_st, c_outs)
            return (x, cache_st, aux_acc)

        x, c_stack, aux_total = jax.lax.fori_loop(
            0, n_super, loop_body, (x, block_cache, aux_total))
        new_cache["blocks"] = c_stack
    return x, new_cache, aux_total


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               ctx_len: int = 0) -> dict:
    dtype = dtype_of(cfg.dtype)
    specs = layer_specs(cfg)
    prefix, period = split_pattern(specs)
    n_super = (cfg.n_layers - prefix) // period
    cache: dict[str, Any] = {}
    for i in range(prefix):
        cache[f"prefix_{i}"] = cache_init_layer(
            cfg, specs[i], batch, max_len, ctx_len, dtype)
    one_block = {f"sub{j}": cache_init_layer(
        cfg, specs[prefix + j], batch, max_len, ctx_len, dtype)
        for j in range(period)}
    cache["blocks"] = jax.tree.map(
        lambda x: jnp.broadcast_to(
            x[None], (n_super,) + x.shape).copy(), one_block)
    cache["length"] = jnp.zeros((batch,), jnp.int32)
    return cache


#: sequence-chunk size for the streamed cross-entropy (memory: the fp32
#: logit tensor only ever exists one chunk at a time; checkpointed so
#: the backward recomputes chunk logits instead of storing them).
CE_CHUNK = 512


def _chunked_ce(params, cfg: ModelConfig, x, labels) -> jax.Array:
    """Streamed softmax-xent over sequence chunks: never materializes
    the full (B, S, V) logit tensor - at 256k vocab that tensor is the
    single largest training buffer otherwise."""
    b, s, _ = x.shape
    shift_x = x[:, :-1]
    shift_y = labels[:, 1:]
    n = shift_x.shape[1]
    chunk = min(CE_CHUNK, n)
    head = (params["embed"] if cfg.tie_embeddings else params["lm_head"])
    rem = n % chunk
    main_len = n - rem

    def chunk_loss(xc, yc):
        logits = (xc @ head.T).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via a one-hot masked sum: with vocab-sharded
        # logits this reduces locally per shard + a scalar psum,
        # whereas take_along_axis forces an all-to-all of the logits
        # (measured 17.2 GB/device/step on olmoe train_4k, SSPerf it.2).
        vocab_ids = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(
            vocab_ids == yc[..., None].astype(jnp.int32), logits, 0.0),
            axis=-1)
        return jnp.sum(logz - gold)

    total = jnp.zeros((), jnp.float32)
    if main_len:
        xm = shift_x[:, :main_len].reshape(
            b, main_len // chunk, chunk, -1).swapaxes(0, 1)
        ym = shift_y[:, :main_len].reshape(
            b, main_len // chunk, chunk).swapaxes(0, 1)

        def body(acc, inp):
            xc, yc = inp
            return acc + chunk_loss(xc, yc), None

        total, _ = jax.lax.scan(jax.checkpoint(body), total, (xm, ym))
    if rem:
        total = total + chunk_loss(shift_x[:, main_len:],
                                   shift_y[:, main_len:])
    return total / (b * n)


def _constrain_batch_major(cfg: ModelConfig, x):
    """Pin x's leading (batch) dim to the configured DP axes - stops XLA
    flipping large fp32 intermediates (final norm, CE inputs) into a
    d-sharded layout that costs a full-activation all-to-all each way
    (measured 17.2 GB/device/step on olmoe train_4k, SSPerf iter 7)."""
    axes = getattr(cfg, "residual_axes", ())
    if axes:
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(
            x, P(tuple(axes), *([None] * (x.ndim - 1))))
    return x


def forward_train(params, cfg: ModelConfig, batch) -> jax.Array:
    """batch: {tokens, labels[, vision_embeds | frames]} -> mean loss."""
    if cfg.encoder_layers:
        context = encode(params, cfg, batch["frames"])
    else:
        context = batch.get("vision_embeds")
        if context is not None:
            context = context.astype(dtype_of(cfg.dtype))
    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]
    x, _, aux = _run_layers(params, cfg, x, positions=positions,
                            context=context)
    x = _constrain_batch_major(cfg, x)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    x = _constrain_batch_major(cfg, x)
    loss = _chunked_ce(params, cfg, x, batch["labels"])
    return loss + aux


def prefill(params, cfg: ModelConfig, tokens, cache,
            context=None):
    """Fill the cache from a full prompt; returns (last_logits, cache)."""
    if cfg.encoder_layers and context is not None:
        context = encode(params, cfg, context)
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]
    zero_len = jnp.zeros((tokens.shape[0],), jnp.int32)
    x, new_cache, _ = _run_layers(
        params, cfg, x, positions=positions, context=context,
        cache=cache, cache_len=zero_len)
    new_cache["length"] = zero_len + tokens.shape[1]
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return _logits(params, cfg, x[:, -1:]), new_cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """token: (B, 1) -> (logits (B,1,V), cache)."""
    x = _embed_tokens(params, cfg, token)
    length = cache["length"]
    positions = length[:, None]
    x, new_cache, _ = _run_layers(
        params, cfg, x, positions=positions, context=None,
        cache=cache, cache_len=length)
    new_cache["length"] = length + 1
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return _logits(params, cfg, x), new_cache
