"""RWKV-6 "Finch" block: attention-free time-mix with data-dependent
decay (arXiv:2404.05892) + squared-ReLU channel-mix.

Structure per layer:
  time-mix: token-shift ddlerp (low-rank data-dependent interpolation
  between x_t and x_{t-1}) produces r, k, v, w, g; the WKV recurrence
  carries a per-head (head_dim x head_dim) state with per-channel
  data-dependent decay w_t and a "bonus" u for the current token.
  channel-mix: token-shift lerp, relu^2 key, receptance-gated value.

State per layer is O(1) in sequence length (one token-shift vector per
mix + the WKV matrix state), which is what qualifies this arch for the
long_500k decode shape.  Scan is chunk-checkpointed like the Mamba
block.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RWKVConfig
from repro.models.common import dense_init, norm_apply, norm_init


class RWKVState(NamedTuple):
    tm_shift: jax.Array   # (B, d) last token seen by time-mix
    cm_shift: jax.Array   # (B, d) last token seen by channel-mix
    wkv: jax.Array        # (B, H, dh, dh) fp32 recurrence state


def _dims(cfg: ModelConfig):
    r: RWKVConfig = cfg.rwkv
    n_heads = cfg.d_model // r.head_size
    return r, n_heads, r.head_size


def rwkv_time_mix_init(key, cfg: ModelConfig, dtype):
    r, h, dh = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    p = {
        # ddlerp base mixing coefficients (5 streams: r,k,v,w,g)
        "mix_base": (jax.random.uniform(ks[0], (5, d), jnp.float32)
                     ).astype(jnp.float32),
        "mix_lora_a": dense_init(ks[1], d, 5 * r.mix_lora, dtype),
        "mix_lora_b": (jax.random.normal(
            ks[2], (5, r.mix_lora, d), jnp.float32) * 0.01).astype(dtype),
        "w_r": dense_init(ks[3], d, d, dtype),
        "w_k": dense_init(ks[4], d, d, dtype),
        "w_v": dense_init(ks[5], d, d, dtype),
        "w_g": dense_init(ks[6], d, d, dtype),
        "w_o": dense_init(ks[7], d, d, dtype),
        # data-dependent decay: w_t = exp(-exp(decay_base + lora(x)))
        "decay_base": (jax.random.uniform(
            ks[8], (d,), jnp.float32, -8.0, -5.0)),
        "decay_lora_a": dense_init(ks[9], d, r.decay_lora, dtype),
        "decay_lora_b": (jax.random.normal(
            ks[10], (r.decay_lora, d), jnp.float32) * 0.01).astype(dtype),
        "bonus": (jax.random.normal(ks[11], (h, dh), jnp.float32) * 0.1),
        "ln_x": norm_init(d, "rmsnorm", dtype),  # group-norm stand-in
    }
    return p


def rwkv_channel_mix_init(key, cfg: ModelConfig, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "w_k": dense_init(ks[0], d, dff, dtype),
        "w_v": dense_init(ks[1], dff, d, dtype),
        "w_r": dense_init(ks[2], d, d, dtype),
    }


def _token_shift(x, last):
    """x: (B,T,d); last: (B,d) -> x_{t-1} stream + new last."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev, x[:, -1, :]


def _wkv_step(h, r_t, k_t, v_t, w_t, bonus):
    """h: (B,H,dh,dh); r/k/v/w: (B,H,dh).  Returns (h', y_t (B,H,dh))."""
    kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,dh,dh)
    y = jnp.einsum("bhk,bhkv->bhv", r_t, h + bonus[..., :, None] * kv)
    h = w_t[..., :, None] * h + kv
    return h, y


def rwkv_time_mix_apply(p, cfg: ModelConfig, x,
                        tm_shift=None, wkv_state=None):
    r, n_h, dh = _dims(cfg)
    b, t, d = x.shape
    if tm_shift is None:
        tm_shift = jnp.zeros((b, d), x.dtype)
    prev, new_shift = _token_shift(x, tm_shift)

    # ddlerp: data-dependent interpolation between x_t and x_{t-1}
    delta = prev - x
    lora = jax.nn.tanh(x @ p["mix_lora_a"]).reshape(b, t, 5, r.mix_lora)
    dyn = jnp.einsum("btsr,srd->btsd", lora,
                     p["mix_lora_b"].astype(x.dtype))
    mix = jax.nn.sigmoid(p["mix_base"].astype(x.dtype) + dyn)  # (b,t,5,d)
    xr, xk, xv, xw, xg = [x + delta * mix[:, :, i] for i in range(5)]

    r_s = (xr @ p["w_r"]).reshape(b, t, n_h, dh).astype(jnp.float32)
    k_s = (xk @ p["w_k"]).reshape(b, t, n_h, dh).astype(jnp.float32)
    v_s = (xv @ p["w_v"]).reshape(b, t, n_h, dh).astype(jnp.float32)
    g_s = jax.nn.silu(xg @ p["w_g"])

    decay = (p["decay_base"].astype(jnp.float32)
             + (jax.nn.tanh(xw @ p["decay_lora_a"])
                @ p["decay_lora_b"]).astype(jnp.float32))
    w_s = jnp.exp(-jnp.exp(decay)).reshape(b, t, n_h, dh)  # (0,1)

    bonus = p["bonus"].astype(jnp.float32)
    if wkv_state is None:
        wkv_state = jnp.zeros((b, n_h, dh, dh), jnp.float32)

    if t == 1:
        h, y = _wkv_step(wkv_state, r_s[:, 0], k_s[:, 0], v_s[:, 0],
                         w_s[:, 0], bonus)
        ys = y[:, None]
    else:
        chunk = min(cfg.rwkv.chunk, t)
        assert t % chunk == 0
        nc = t // chunk

        def chunk_body(h, inp):
            def step(h, s):
                r_t, k_t, v_t, w_t = s
                return _wkv_step(h, r_t, k_t, v_t, w_t, bonus)
            return jax.lax.scan(step, h, inp)

        def tm_(a):  # (b,t,h,dh) -> (nc, chunk, b, h, dh)
            return a.swapaxes(0, 1).reshape(nc, chunk, b, n_h, dh)

        h, ys = jax.lax.scan(jax.checkpoint(chunk_body), wkv_state,
                             (tm_(r_s), tm_(k_s), tm_(v_s), tm_(w_s)))
        ys = ys.reshape(t, b, n_h, dh).swapaxes(0, 1)

    y = ys.reshape(b, t, d).astype(x.dtype)
    y = norm_apply(p["ln_x"], y) * g_s
    return y @ p["w_o"], new_shift, h


def rwkv_channel_mix_apply(p, cfg: ModelConfig, x, cm_shift=None):
    b, t, d = x.shape
    if cm_shift is None:
        cm_shift = jnp.zeros((b, d), x.dtype)
    prev, new_shift = _token_shift(x, cm_shift)
    xk = x + (prev - x) * p["mix_k"].astype(x.dtype)
    xr = x + (prev - x) * p["mix_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), new_shift


def rwkv_state_init(cfg: ModelConfig, batch: int) -> RWKVState:
    r, n_h, dh = _dims(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return RWKVState(
        tm_shift=jnp.zeros((batch, cfg.d_model), dt),
        cm_shift=jnp.zeros((batch, cfg.d_model), dt),
        wkv=jnp.zeros((batch, n_h, dh, dh), jnp.float32))
