"""Fault-tolerant, reshardable checkpointing.

Properties a 1000-node deployment needs:
  * **atomic**: write to a temp dir, fsync, rename - a crash mid-save
    never corrupts the latest checkpoint;
  * **async**: ``save_async`` hands the host copy to a background thread
    so the train loop resumes immediately (device->host transfer is the
    only synchronous part);
  * **reshardable / elastic**: arrays are stored with their *global*
    logical shapes (npz per leaf path); restore takes any mesh/sharding
    and re-shards via ``jax.device_put`` - scale from 256 to 512 chips
    (or to 1 CPU in tests) without converter tools;
  * **self-describing**: a JSON manifest records step, config name, and
    leaf paths; ``latest_step`` scans for the newest complete manifest;
  * **retention**: keep the last k checkpoints (bounded disk).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix="") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat: dict[str, Any]) -> Any:
    root: dict = {}
    for path, val in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path,
                 keep: int = 3) -> None:
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # ------------------------------ save ------------------------------
    def save(self, step: int, tree: Any, meta: Optional[dict] = None
             ) -> pathlib.Path:
        """Synchronous atomic save."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host, meta or {})

    def save_async(self, step: int, tree: Any,
                   meta: Optional[dict] = None) -> None:
        """Device->host copy now; disk IO in the background."""
        self.wait()  # one in flight at a time
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._pending = threading.Thread(
            target=self._write, args=(step, host, meta or {}), daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree, meta: dict) -> pathlib.Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        np.savez(tmp / "arrays.npz",
                 **{k: v for k, v in flat.items()})
        manifest = {"step": step, "paths": sorted(flat),
                    "meta": meta, "complete": True}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)          # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}",
                          ignore_errors=True)

    # ----------------------------- restore ----------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            mf = p / "manifest.json"
            if mf.exists():
                try:
                    m = json.loads(mf.read_text())
                    if m.get("complete"):
                        out.append(int(m["step"]))
                except (json.JSONDecodeError, KeyError):
                    continue  # torn manifest = incomplete checkpoint
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Load a checkpoint; optionally reshard onto ``shardings``
        (a pytree of jax.sharding.Sharding matching the saved tree).

        Elastic restart: the saved arrays are global, so any target mesh
        works - restoring a 256-chip checkpoint onto 512 chips (or onto
        this container's single CPU device) is the same call."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        with np.load(path / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return step, tree

    def meta(self, step: int) -> dict:
        path = self.dir / f"step_{step:010d}" / "manifest.json"
        return json.loads(path.read_text())["meta"]
