from repro.optim.adamw import (AdamWConfig, AdamWState, init_state,
                               apply_updates, lr_schedule,
                               clip_by_global_norm, compress_grads,
                               global_norm)

__all__ = ["AdamWConfig", "AdamWState", "init_state", "apply_updates",
           "lr_schedule", "clip_by_global_norm", "compress_grads",
           "global_norm"]
