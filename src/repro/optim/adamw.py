"""Pure-JAX AdamW with gradient clipping, schedules, and ZeRO-friendly
state layout (optax-free: only pytree maps, so sharding rules can
pattern-match optimizer state exactly like params).

Distributed-optimization extras (used by the runtime):
  * ``compress_grads`` - bf16 gradient representation with an fp32
    error-feedback residual (1-bit-Adam-style compression generalized to
    bf16): the all-reduce payload halves while the accumulated rounding
    error is re-injected next step, keeping convergence unbiased.
  * moments can be kept in bf16 (``moment_dtype``) for the 398B-class
    models where fp32 moments alone exceed per-device HBM.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" for ZeRO-lite footprint


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict      # first moment, pytree like params
    nu: dict      # second moment
    error: Optional[dict] = None   # compression error feedback


def init_state(cfg: AdamWConfig, params,
               with_error_feedback: bool = False) -> AdamWState:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda dtype: jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=zeros(dt), nu=zeros(dt),
        error=zeros(jnp.float32) if with_error_feedback else None)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), norm


def compress_grads(grads, error):
    """bf16 compression with fp32 error feedback.

    Returns (compressed_bf16, new_error).  The all-reduce runs on the
    bf16 payload; the representation error (g - bf16(g+e)) accumulates
    into ``error`` and is re-added next step.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        c = g32.astype(jnp.bfloat16)
        return c, g32 - c.astype(jnp.float32)
    pairs = jax.tree.map(one, grads, error)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_err


_NO_DECAY_TOKENS = ("norm", "bias", "scale", "a_log", "dt_bias",
                    "decay_base", "mix_base", "bonus", "gate")


def _decay_mask(path: str) -> bool:
    p = path.lower()
    return not any(tok in p for tok in _NO_DECAY_TOKENS)


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out[k] = _tree_paths(v, f"{prefix}/{k}")
        return out
    return prefix


def apply_updates(cfg: AdamWConfig, params, grads,
                  state: AdamWState):
    """One AdamW step.  Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    paths = _tree_paths(params)

    def upd(p, g, m, v, path):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (new_p.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    triples = jax.tree.map(upd, params, grads, state.mu, state.nu, paths)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_params = jax.tree.map(lambda t: t[0], triples, is_leaf=is3)
    new_mu = jax.tree.map(lambda t: t[1], triples, is_leaf=is3)
    new_nu = jax.tree.map(lambda t: t[2], triples, is_leaf=is3)
    new_state = AdamWState(step=step, mu=new_mu, nu=new_nu,
                           error=state.error)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
