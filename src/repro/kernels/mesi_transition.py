"""Batched MESI coherence tick as a Pallas TPU kernel.

This is the paper-specific compute hot-spot: parameter sweeps run
thousands of simulated deployments concurrently (fleet-scale evaluation,
SS8), and the per-tick work is a serialized-agent state transition over
the (n_agents x n_artifacts) coherence matrix of every simulation.

TPU adaptation: one program owns a ``block_sims`` slab of simulations
resident in VMEM; agents are processed with a sequential fori_loop
(the authority's serialization order - a *semantic* requirement, not a
perf artifact) while the simulation dimension is fully vectorized on the
8x128 VPU lanes.  Dynamic per-sim artifact indices become one-hot masks
over the artifact dim (m <= 16), trading a few lanes of redundancy for
fully static shapes - the standard TPU answer to data-dependent
indexing.

Counters layout (out[..., c]): 0 fetch_tokens, 1 signal_tokens,
2 push_tokens, 3 n_fetches, 4 n_hits, 5 n_invalidation_signals;
6-7 reserved (zero).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.states import MESIState
from repro.kernels.backend import resolve_interpret

_I, _S = int(MESIState.I), int(MESIState.S)
N_COUNTERS = 8


def episode_step_keys(keys: jax.Array, n_steps: int) -> jax.Array:
    """Per-step PRNG keys for a batch of kernel-routed episodes.

    ``keys`` is a ``(B, 2)`` batch of per-episode keys - in the sweep
    engine these come from ``repro.core.acs.run_keys`` (``fold_in`` on
    the **global** run index), so under ``shard_map`` each device
    derives the same schedule the single-device path derives for its
    slice of episodes.  Returns ``(n_steps, B, 2)``: step-major, the
    scan order of the batched episode loop, and step ``s`` holds
    exactly ``split(key, n_steps)[s]`` - the schedule
    ``acs.run_episode`` uses - so kernel-routed episodes consume the
    same action stream as the ``lax.scan`` path bit-for-bit.
    """
    step_keys = jax.vmap(lambda k: jax.random.split(k, n_steps))(keys)
    return jnp.swapaxes(step_keys, 0, 1)


def _mesi_kernel(state_ref, version_ref, sync_ref, reads_ref,
                 act_ref, art_ref, write_ref,
                 state_out, version_out, sync_out, reads_out, counter_out,
                 miss_out,
                 *, n_agents: int, n_artifacts: int, artifact_tokens: int,
                 eager: bool, access_k: int, signal_tokens: int):
    state = state_ref[...]          # (bs, n, m) int32
    version = version_ref[...]      # (bs, m)
    sync = sync_ref[...]            # (bs, n, m)
    reads = reads_ref[...]          # (bs, n, m)
    acts = act_ref[...]             # (bs, n)
    arts = art_ref[...]             # (bs, n)
    writes = write_ref[...]         # (bs, n)
    bs = state.shape[0]
    counters = jnp.zeros((bs, N_COUNTERS), jnp.int32)
    miss_mat = jnp.zeros((bs, n_agents), jnp.int32)

    def agent_body(a, carry):
        state, version, sync, reads, counters, miss_mat = carry
        act = acts[:, a] != 0                       # (bs,)
        is_write = jnp.logical_and(act, writes[:, a] != 0)
        is_read = jnp.logical_and(act, writes[:, a] == 0)
        d_oh = (jax.lax.broadcasted_iota(jnp.int32, (bs, n_artifacts), 1)
                == arts[:, a][:, None])             # (bs, m) one-hot

        st_a = state[:, a, :]                       # (bs, m)
        entry = jnp.sum(jnp.where(d_oh, st_a, 0), axis=1)        # (bs,)
        reads_at = jnp.sum(jnp.where(d_oh, reads[:, a, :], 0), axis=1)
        ver_at = jnp.sum(jnp.where(d_oh, version, 0), axis=1)

        expired = jnp.zeros_like(entry, jnp.bool_)
        if access_k > 0:
            expired = reads_at >= access_k
        miss = jnp.logical_and(act, jnp.logical_or(entry == _I, expired))
        hit = jnp.logical_and(act, jnp.logical_not(miss))

        # --- coherence fill on miss (read-modify-write prologue)
        fill = jnp.logical_and(miss[:, None], d_oh)
        st_a = jnp.where(fill, _S, st_a)
        sy_a = jnp.where(fill, version, sync[:, a, :])
        rd_a = jnp.where(fill, 0, reads[:, a, :])
        counters = counters.at[:, 0].add(jnp.where(
            miss, artifact_tokens + signal_tokens, 0))
        counters = counters.at[:, 3].add(miss.astype(jnp.int32))
        counters = counters.at[:, 4].add(hit.astype(jnp.int32))
        miss_mat = miss_mat.at[:, a].set(miss.astype(jnp.int32))

        state = state.at[:, a, :].set(st_a)
        sync = sync.at[:, a, :].set(sy_a)
        reads = reads.at[:, a, :].set(rd_a)

        # --- write path: invalidate peers, bump version, commit
        agent_ids = jax.lax.broadcasted_iota(
            jnp.int32, (bs, n_agents, n_artifacts), 1)
        peer = agent_ids != a                       # (bs, n, m)
        wmask = jnp.logical_and(is_write[:, None, None], d_oh[:, None, :])
        peer_valid = jnp.logical_and(
            jnp.logical_and(wmask, peer), state != _I)
        n_peers = jnp.sum(peer_valid.astype(jnp.int32), axis=(1, 2))
        counters = counters.at[:, 1].add(signal_tokens * n_peers)
        counters = counters.at[:, 5].add(n_peers)
        state = jnp.where(peer_valid, _I, state)

        new_ver = jnp.where(jnp.logical_and(is_write[:, None], d_oh),
                            version + 1, version)
        writer = jnp.logical_and(wmask, jnp.logical_not(peer))
        state = jnp.where(writer, _S, state)
        sync = jnp.where(writer, new_ver[:, None, :], sync)
        reads = jnp.where(writer, 0, reads)
        version = new_ver

        if eager:
            # push-on-commit to active sharers
            state = jnp.where(peer_valid, _S, state)
            sync = jnp.where(peer_valid, new_ver[:, None, :], sync)
            reads = jnp.where(peer_valid, 0, reads)
            counters = counters.at[:, 2].add(
                (artifact_tokens + signal_tokens) * n_peers)

        # --- read bookkeeping
        rmask = jnp.logical_and(is_read[:, None, None], d_oh[:, None, :])
        own = jnp.logical_and(rmask, jnp.logical_not(peer))
        reads = jnp.where(own, reads + 1, reads)
        return state, version, sync, reads, counters, miss_mat

    state, version, sync, reads, counters, miss_mat = jax.lax.fori_loop(
        0, n_agents, agent_body,
        (state, version, sync, reads, counters, miss_mat))
    state_out[...] = state
    version_out[...] = version
    sync_out[...] = sync
    reads_out[...] = reads
    counter_out[...] = counters
    miss_out[...] = miss_mat


def mesi_tick_pallas(state, version, last_sync, reads_since_fetch,
                     acts, arts, writes, *, artifact_tokens: int,
                     eager: bool = False, access_k: int = 0,
                     signal_tokens: int = 12, block_sims: int = 128,
                     interpret: bool | None = None):
    """One coherence tick over a batch of simulations.

    Shapes: state/last_sync/reads (B, n, m) int32; version (B, m) int32;
    acts/arts/writes (B, n) int32.  Returns (state', version', sync',
    reads', counters (B, 8), miss (B, n)) - ``miss`` is the per-agent
    coherence-fill indicator of this tick, which the chunk content
    plane (``repro.kernels.chunk_diff``) consumes to route delta
    fetches at the exact serialization slots the MESI decisions were
    made at.  ``interpret=None`` auto-detects the backend (compiled
    Mosaic on TPU, interpret mode elsewhere).
    """
    interpret = resolve_interpret(interpret)
    B, n, m = state.shape
    bs = min(block_sims, B)
    pad = (-B) % bs
    if pad:
        padded = []
        for arr in (state, version, last_sync, reads_since_fetch,
                    acts, arts, writes):
            padded.append(jnp.pad(arr, [(0, pad)] + [(0, 0)] *
                                  (arr.ndim - 1)))
        state, version, last_sync, reads_since_fetch, acts, arts, writes \
            = padded
    Bp = state.shape[0]
    grid = (Bp // bs,)
    kernel = functools.partial(
        _mesi_kernel, n_agents=n, n_artifacts=m,
        artifact_tokens=artifact_tokens, eager=eager, access_k=access_k,
        signal_tokens=signal_tokens)
    spec3 = pl.BlockSpec((bs, n, m), lambda i: (i, 0, 0))
    spec2n = pl.BlockSpec((bs, n), lambda i: (i, 0))
    spec2m = pl.BlockSpec((bs, m), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec3, spec2m, spec3, spec3, spec2n, spec2n, spec2n],
        out_specs=[spec3, spec2m, spec3, spec3,
                   pl.BlockSpec((bs, N_COUNTERS), lambda i: (i, 0)),
                   spec2n],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, n, m), jnp.int32),
            jax.ShapeDtypeStruct((Bp, m), jnp.int32),
            jax.ShapeDtypeStruct((Bp, n, m), jnp.int32),
            jax.ShapeDtypeStruct((Bp, n, m), jnp.int32),
            jax.ShapeDtypeStruct((Bp, N_COUNTERS), jnp.int32),
            jax.ShapeDtypeStruct((Bp, n), jnp.int32),
        ],
        interpret=interpret,
    )(state, version, last_sync, reads_since_fetch, acts, arts, writes)
    if pad:
        out = tuple(o[:B] for o in out)
    return out


def mesi_decision_batch(state, version, last_sync, reads_since_fetch,
                        acts, arts, writes, *, artifact_tokens: int,
                        eager: bool = False, access_k: int = 0,
                        signal_tokens: int = 12,
                        interpret: bool | None = None):
    """One micro-batch of live coherence decisions via prefix-replicated
    simulations (the ``repro.service.batching`` kernel route).

    The kernel emits per-*simulation* aggregate counters, not
    per-request outcomes, yet a live broker must answer each request
    individually (fill vs hit, served version).  Trick: replicate the
    single directory into ``B = k+1`` sims where sim ``j`` enables only
    the first ``j`` active agents (in the authority's ascending-agent
    serialization order).  Agent processing is sequential and
    deterministic, so sim ``j`` agrees with the full batch on its
    prefix, and request ``j``'s outcome is the counter delta between
    consecutive prefix sims - every decision of the batch falls out of
    ONE ``mesi_tick_pallas`` call, vectorized over the sim lanes the
    kernel already batches on.

    Inputs: single-directory arrays - ``state``/``last_sync``/``reads``
    (n, m) int32, ``version`` (m,) int32 - plus the request vectors
    ``acts``/``arts``/``writes`` (n,) (at most one request per agent).
    Returns ``(state', version', sync', reads', counters (8,),
    miss (n,) bool, served_version (n,) int32)`` where the primed
    arrays/counters are the full-batch transition.
    """
    n, m = state.shape
    acts_np = np.asarray(acts, bool)
    order = np.flatnonzero(acts_np)          # ascending agent order
    k = int(order.size)
    if k == 0:
        zc = jnp.zeros((N_COUNTERS,), jnp.int32)
        return (state, version, last_sync, reads_since_fetch, zc,
                jnp.zeros((n,), bool), jnp.zeros((n,), jnp.int32))
    # sim j enables the first j requests; sim 0 is the no-op baseline.
    # B is padded to the FIXED n+1 (rows past k repeat the full batch,
    # so their counter deltas are zero) - every micro-batch size shares
    # one compiled program instead of one Mosaic compile per distinct k.
    B = n + 1
    acts_b = np.zeros((B, n), np.int32)
    for j, a in enumerate(order):
        acts_b[j + 1:, a] = 1
    tile = lambda arr: jnp.broadcast_to(arr, (B,) + arr.shape)
    st, ver, sy, rd, cnt, _ = mesi_tick_pallas(
        tile(state), tile(version), tile(last_sync),
        tile(reads_since_fetch), jnp.asarray(acts_b),
        tile(jnp.asarray(arts, jnp.int32)),
        tile(jnp.asarray(writes, jnp.int32)),
        artifact_tokens=artifact_tokens, eager=eager, access_k=access_k,
        signal_tokens=signal_tokens, block_sims=B, interpret=interpret)
    cnt_np = np.asarray(cnt, np.int64)
    arts_np = np.asarray(arts, np.int64)
    sync_np = np.asarray(sy, np.int64)
    miss = np.zeros((n,), bool)
    served = np.zeros((n,), np.int32)
    for j, a in enumerate(order):
        # counter slot 3 = n_fetches; the delta between prefix j+1 and
        # prefix j is exactly request j's fill.
        miss[a] = (cnt_np[j + 1, 3] - cnt_np[j, 3]) == 1
        # sim j+1 processed request j last: its sync cell is the version
        # agent a is synced to at its serialization slot (later eager
        # pushes in the full batch must not leak into this answer).
        served[a] = sync_np[j + 1, a, arts_np[a]]
    return (st[-1], ver[-1], sy[-1], rd[-1], cnt[-1],
            jnp.asarray(miss), jnp.asarray(served))
