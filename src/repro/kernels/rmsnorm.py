"""Fused RMSNorm Pallas TPU kernel.

Tiling: rows are processed in blocks of ``block_rows`` with the full
feature dim resident in VMEM (d is <= 8192 for every assigned arch ->
block_rows x d x 4B << 16 MB VMEM).  The reduction runs in fp32 on the
VPU regardless of input dtype; the scale multiply fuses into the same
pass, saving one HBM round-trip vs norm-then-scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (normed * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
                   block_rows: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """x: (..., d); weight: (d,).  Returns same shape/dtype as x.

    ``interpret=None`` auto-detects the backend.
    """
    interpret = resolve_interpret(interpret)
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of block_rows
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, weight)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
