"""Public jit'd wrappers for the Pallas kernels.

On a real TPU these dispatch the compiled Mosaic kernels; on CPU (this
container) they run the same kernel bodies under ``interpret=True``,
which is how correctness is validated against the ``ref.py`` oracles.
Set ``REPRO_KERNEL_BACKEND=ref`` to route everything through the pure
jnp oracles (used by the dry-run path, where kernels are swapped for
reference ops so XLA cost analysis reflects the fused-op FLOPs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.backend import interpret_default, use_ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mesi_transition import mesi_tick_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas

# Backwards-compatible aliases (the auto-detect logic used to live here).
_use_ref = use_ref
_interpret = interpret_default


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, weight, eps: float = 1e-6, block_rows: int = 128):
    if _use_ref():
        return ref.rmsnorm_ref(x, weight, eps)
    return rmsnorm_pallas(x, weight, eps=eps, block_rows=block_rows,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128):
    if _use_ref():
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("scale", "block_k"))
def decode_attention(q, k_cache, v_cache, kv_len=None, scale=None,
                     block_k: int = 256):
    if _use_ref():
        return ref.decode_attention_ref(q, k_cache, v_cache, kv_len,
                                        scale=scale)
    return decode_attention_pallas(
        q, k_cache, v_cache, kv_len, scale=scale, block_k=block_k,
        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, w, bonus, initial_state=None, chunk: int = 64):
    if _use_ref():
        return ref.rwkv6_scan_ref(r, k, v, w, bonus, initial_state)
    return rwkv6_scan_pallas(r, k, v, w, bonus, initial_state,
                             chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=(
    "artifact_tokens", "eager", "access_k", "signal_tokens",
    "block_sims"))
def mesi_tick(state, version, last_sync, reads_since_fetch, acts, arts,
              writes, artifact_tokens: int, eager: bool = False,
              access_k: int = 0, signal_tokens: int = 12,
              block_sims: int = 128):
    return mesi_tick_pallas(
        state, version, last_sync, reads_since_fetch, acts, arts, writes,
        artifact_tokens=artifact_tokens, eager=eager, access_k=access_k,
        signal_tokens=signal_tokens, block_sims=block_sims,
        interpret=_interpret())
