"""Batched chunk-diff / delta-coherence tick as a Pallas TPU kernel.

The content plane (``repro.content``) tracks per-chunk version counters
at the authority and a per-chunk sync vector per (agent, artifact)
cache entry.  Per orchestration step, the hot work is: for every fill
the MESI tick decided, compare the reader's chunk vector against the
authority's chunk versions and count the stale chunks' bytes (delta
fetch); for every commit, bump the dirtied span's versions.  Fleet
sweeps run this batched over (sims x agents x artifacts x chunks) -
this kernel does one whole tick of it in one ``pallas_call``.

TPU adaptation mirrors ``mesi_transition``: one program owns a
``block_sims`` slab of simulations in VMEM; agents are processed with
a sequential fori_loop (the authority's serialization order - chunk
versions bumped by agent ``a`` must be visible to the fill of agent
``a+1`` in the same tick), while the sim dimension rides the VPU
lanes.  Per-sim artifact choice becomes a one-hot mask over the
artifact dim, exactly as in the MESI kernel.

The MESI decision itself is **not** recomputed here: the kernel takes
the per-agent ``miss`` indicator the MESI tick emits
(``mesi_tick_pallas``'s sixth output), so the two kernels compose into
one bit-exact tick and neither duplicates the other's state machine.

Counters layout (out[..., c]): 0 delta_bytes (shipped), 1 full_bytes
(what whole-artifact lazy would ship for the same fills),
2 n_chunks_fetched; 3 reserved (zero).

Routing matches ``mesi_tick``: ``interpret=None`` auto-detects via
``repro.kernels.backend`` (compiled Mosaic on TPU, interpret mode
elsewhere); ``REPRO_CHUNK_DIFF=scan|pallas`` forces the pure-jnp
reference (``chunk_tick_ref``, bit-identical by construction and by
the byte-exact oracle) or the kernel in the service decision layer and
anywhere :func:`resolve_chunk_route` is consulted.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.content.chunks import BYTES_PER_TOKEN, chunk_sizes
from repro.kernels.backend import resolve_interpret

N_CHUNK_COUNTERS = 4


def resolve_chunk_route(default: str = "auto") -> str:
    """'scan' (pure-jnp reference) | 'pallas' for content-plane ticks
    outside the fused engine (the engine follows ``REPRO_SIM_TICK``).
    Forced with ``REPRO_CHUNK_DIFF``; ``auto`` follows the caller's
    default."""
    forced = os.environ.get("REPRO_CHUNK_DIFF", default)
    if forced not in ("auto", "scan", "pallas"):
        raise ValueError(f"REPRO_CHUNK_DIFF must be auto|scan|pallas, "
                         f"got {forced!r}")
    return default if forced == "auto" else forced


def _chunk_kernel(cv_ref, cs_ref, dirty_ref, miss_ref, wact_ref, art_ref,
                  wmask_ref,
                  cv_out, cs_out, dirty_out, fetched_out, counter_out,
                  *, n_agents: int, n_artifacts: int, n_chunks: int,
                  chunk_tokens: int, artifact_tokens: int,
                  signal_tokens: int):
    cv = cv_ref[...]        # (bs, m, C) int32 authority chunk versions
    cs = cs_ref[...]        # (bs, n, m, C) reader chunk vectors
    dirty = dirty_ref[...]  # (bs, m, C) ever-written bitmap
    miss = miss_ref[...]    # (bs, n) fill indicator from the MESI tick
    wact = wact_ref[...]    # (bs, n) acting-write indicator
    arts = art_ref[...]     # (bs, n) chosen artifact
    wmask = wmask_ref[...]  # (bs, n, C) dirtied chunk span per writer
    bs = cv.shape[0]
    # (1, C) chunk token sizes from the static geometry (a ragged last
    # chunk); built with iota - array constants can't be captured.
    cidx = jax.lax.broadcasted_iota(jnp.int32, (1, n_chunks), 1)
    last = artifact_tokens - (n_chunks - 1) * chunk_tokens
    sizes_row = jnp.where(cidx < n_chunks - 1, chunk_tokens, last)
    counters = jnp.zeros((bs, N_CHUNK_COUNTERS), jnp.int32)
    fetched = jnp.zeros((bs, n_agents, n_chunks), jnp.int32)

    def agent_body(a, carry):
        cv, cs, dirty, fetched, counters = carry
        miss_a = miss[:, a] != 0                    # (bs,)
        w_a = wact[:, a] != 0
        d_oh = (jax.lax.broadcasted_iota(jnp.int32, (bs, n_artifacts), 1)
                == arts[:, a][:, None])             # (bs, m)
        d3 = d_oh[:, :, None]                       # (bs, m, 1)

        # --- delta fetch at this agent's serialization slot
        ver_at = jnp.sum(jnp.where(d3, cv, 0), axis=1)        # (bs, C)
        sync_at = jnp.sum(jnp.where(d3, cs[:, a, :, :], 0), axis=1)
        fetch = jnp.logical_and(miss_a[:, None], ver_at > sync_at)
        delta_tok = jnp.sum(jnp.where(fetch, sizes_row, 0), axis=1)
        counters = counters.at[:, 0].add(jnp.where(
            miss_a, (delta_tok + signal_tokens) * BYTES_PER_TOKEN, 0))
        counters = counters.at[:, 1].add(jnp.where(
            miss_a, (artifact_tokens + signal_tokens) * BYTES_PER_TOKEN,
            0))
        counters = counters.at[:, 2].add(
            jnp.sum(fetch.astype(jnp.int32), axis=1))
        fetched = fetched.at[:, a, :].set(fetch.astype(jnp.int32))
        fill = jnp.logical_and(miss_a[:, None, None], d3)     # (bs, m, 1)
        cs_a = jnp.where(fill, cv, cs[:, a, :, :])            # (bs, m, C)

        # --- chunk-granular commit: bump the dirtied span
        bump = jnp.logical_and(
            jnp.logical_and(w_a[:, None, None], d3),
            wmask[:, a, :][:, None, :] != 0)                  # (bs, m, C)
        cv = jnp.where(bump, cv + 1, cv)
        dirty = jnp.where(bump, 1, dirty)
        cs_a = jnp.where(jnp.logical_and(w_a[:, None, None], d3),
                         cv, cs_a)
        cs = cs.at[:, a, :, :].set(cs_a)
        return cv, cs, dirty, fetched, counters

    cv, cs, dirty, fetched, counters = jax.lax.fori_loop(
        0, n_agents, agent_body, (cv, cs, dirty, fetched, counters))
    cv_out[...] = cv
    cs_out[...] = cs
    dirty_out[...] = dirty
    fetched_out[...] = fetched
    counter_out[...] = counters


def chunk_tick_pallas(chunk_version, chunk_sync, chunk_dirty,
                      miss, write_acts, arts, write_chunks, *,
                      artifact_tokens: int, chunk_tokens: int,
                      signal_tokens: int = 12, block_sims: int = 128,
                      interpret: bool | None = None):
    """One content-plane tick over a batch of simulations.

    Shapes: chunk_version/chunk_dirty (B, m, C) int32, chunk_sync
    (B, n, m, C) int32, miss/write_acts/arts (B, n) int32,
    write_chunks (B, n, C) int32.  ``miss`` comes from the same tick's
    ``mesi_tick_pallas`` call; ``write_acts`` is act AND write.
    Returns (chunk_version', chunk_sync', chunk_dirty',
    fetched (B, n, C), counters (B, 4)).
    """
    interpret = resolve_interpret(interpret)
    B, n, m, C = chunk_sync.shape
    bs = min(block_sims, B)
    pad = (-B) % bs
    if pad:
        padded = []
        for arr in (chunk_version, chunk_sync, chunk_dirty, miss,
                    write_acts, arts, write_chunks):
            padded.append(jnp.pad(arr, [(0, pad)] + [(0, 0)] *
                                  (arr.ndim - 1)))
        (chunk_version, chunk_sync, chunk_dirty, miss, write_acts, arts,
         write_chunks) = padded
    Bp = chunk_version.shape[0]
    grid = (Bp // bs,)
    kernel = functools.partial(
        _chunk_kernel, n_agents=n, n_artifacts=m, n_chunks=C,
        chunk_tokens=chunk_tokens, artifact_tokens=artifact_tokens,
        signal_tokens=signal_tokens)
    spec_mc = pl.BlockSpec((bs, m, C), lambda i: (i, 0, 0))
    spec_nmc = pl.BlockSpec((bs, n, m, C), lambda i: (i, 0, 0, 0))
    spec_n = pl.BlockSpec((bs, n), lambda i: (i, 0))
    spec_nc = pl.BlockSpec((bs, n, C), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec_mc, spec_nmc, spec_mc, spec_n, spec_n, spec_n,
                  spec_nc],
        out_specs=[spec_mc, spec_nmc, spec_mc, spec_nc,
                   pl.BlockSpec((bs, N_CHUNK_COUNTERS),
                                lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, m, C), jnp.int32),
            jax.ShapeDtypeStruct((Bp, n, m, C), jnp.int32),
            jax.ShapeDtypeStruct((Bp, m, C), jnp.int32),
            jax.ShapeDtypeStruct((Bp, n, C), jnp.int32),
            jax.ShapeDtypeStruct((Bp, N_CHUNK_COUNTERS), jnp.int32),
        ],
        interpret=interpret,
    )(chunk_version, chunk_sync, chunk_dirty, miss, write_acts, arts,
      write_chunks)
    if pad:
        out = tuple(o[:B] for o in out)
    return out


def chunk_tick_ref(chunk_version, chunk_sync, chunk_dirty,
                   miss, write_acts, arts, write_chunks, *,
                   artifact_tokens: int, chunk_tokens: int,
                   signal_tokens: int = 12, block_sims: int = 128,
                   interpret: bool | None = None):
    """Pure-numpy reference of :func:`chunk_tick_pallas` (serialized
    agents, same signature/returns) - the scan-style oracle the kernel
    is asserted bit-identical against, and the route
    ``REPRO_CHUNK_DIFF=scan`` forces in the service layer."""
    cv = np.array(chunk_version, np.int32)
    cs = np.array(chunk_sync, np.int32)
    dirty = np.array(chunk_dirty, np.int32)
    miss = np.asarray(miss)
    wact = np.asarray(write_acts)
    arts = np.asarray(arts, np.int64)
    wmask = np.asarray(write_chunks)
    B, n, m, C = cs.shape
    sizes = chunk_sizes(artifact_tokens, chunk_tokens)
    fetched = np.zeros((B, n, C), np.int32)
    counters = np.zeros((B, N_CHUNK_COUNTERS), np.int32)
    for s in range(B):
        for a in range(n):
            d = int(arts[s, a])
            if miss[s, a]:
                stale = cv[s, d] > cs[s, a, d]
                counters[s, 0] += (int(sizes[stale].sum())
                                   + signal_tokens) * BYTES_PER_TOKEN
                counters[s, 1] += (artifact_tokens
                                   + signal_tokens) * BYTES_PER_TOKEN
                counters[s, 2] += int(stale.sum())
                fetched[s, a] = stale
                cs[s, a, d] = cv[s, d]
            if wact[s, a]:
                span = wmask[s, a] != 0
                cv[s, d][span] += 1
                dirty[s, d][span] = 1
                cs[s, a, d] = cv[s, d]
    return (jnp.asarray(cv), jnp.asarray(cs), jnp.asarray(dirty),
            jnp.asarray(fetched), jnp.asarray(counters))
