"""Backend auto-detection shared by every Pallas kernel wrapper.

One rule, one place: on a real TPU the kernels lower through Mosaic;
anywhere else (this CPU container, GPU hosts without a Pallas TPU
backend) they run under ``interpret=True`` against the same kernel
bodies.  Callers that need to force a mode (tests pinning
interpret=True, dry-run routing through the jnp oracles) still can -
``None`` means "auto".

``REPRO_KERNEL_BACKEND=ref`` routes the public ops through the pure-jnp
oracles in ``ref.py`` instead of Pallas (used by the dry-run path so XLA
cost analysis reflects fused-op FLOPs).
"""

from __future__ import annotations

import os

import jax


def use_ref() -> bool:
    """True when the jnp reference oracles should replace Pallas."""
    return os.environ.get("REPRO_KERNEL_BACKEND", "pallas") == "ref"


def interpret_default() -> bool:
    """Pallas interpret mode unless a real TPU backend is attached."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Map a kernel's ``interpret`` argument to a concrete mode."""
    return interpret_default() if interpret is None else bool(interpret)
