"""Pallas TPU kernels for the performance-critical compute layers.

Kernels (each: pl.pallas_call + explicit BlockSpec VMEM tiling; jit
wrappers in ``ops.py``; pure-jnp oracles in ``ref.py``):

  * ``flash_attention``  - FA-2-style GQA attention (train / prefill)
  * ``decode_attention`` - flash-decode split-K (single-token serving)
  * ``rmsnorm``          - fused RMS normalization
  * ``mesi_tick``        - batched coherence tick (fleet-scale DES)
"""

from repro.kernels.ops import (rmsnorm, flash_attention, decode_attention,
                               mesi_tick)
from repro.kernels import ref
from repro.kernels.backend import interpret_default, resolve_interpret

__all__ = ["rmsnorm", "flash_attention", "decode_attention", "mesi_tick",
           "ref", "interpret_default", "resolve_interpret"]
