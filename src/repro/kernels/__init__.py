"""Pallas TPU kernels for the performance-critical compute layers.

Kernels (each: pl.pallas_call + explicit BlockSpec VMEM tiling; jit
wrappers in ``ops.py``; pure-jnp oracles in ``ref.py``):

  * ``flash_attention``  - FA-2-style GQA attention (train / prefill)
  * ``decode_attention`` - flash-decode split-K (single-token serving)
  * ``rmsnorm``          - fused RMS normalization
  * ``mesi_tick``        - batched coherence tick (fleet-scale DES)
  * ``chunk_tick``       - batched chunk-diff / delta-coherence tick
                           (content plane; consumes mesi_tick's
                           per-agent miss output)
"""

from repro.kernels.ops import (rmsnorm, flash_attention, decode_attention,
                               mesi_tick)
from repro.kernels import ref
from repro.kernels.backend import interpret_default, resolve_interpret
from repro.kernels.chunk_diff import (chunk_tick_pallas, chunk_tick_ref,
                                      resolve_chunk_route)

__all__ = ["rmsnorm", "flash_attention", "decode_attention", "mesi_tick",
           "chunk_tick_pallas", "chunk_tick_ref", "resolve_chunk_route",
           "ref", "interpret_default", "resolve_interpret"]
