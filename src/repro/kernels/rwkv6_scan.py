"""RWKV6 WKV recurrence Pallas TPU kernel (chunked, state-in-VMEM).

The Finch recurrence per head (state S: dh x dh, data-dependent decay
w_t in (0,1) per channel):

    y_t = r_t . (S + u (x) (k_t^T v_t))        # bonus u for current token
    S   = diag(w_t) S + k_t^T v_t

This is the compute core of the long_500k serving path: O(T) time,
O(1) state.  TPU adaptation: grid = (B, H, T/chunk) with the chunk dim
innermost-sequential, so the (dh x dh) state lives in VMEM scratch and
persists across chunks of the same (batch, head); HBM traffic is the
r/k/v/w streams once each - the kernel is memory-bound by design and
the roofline is the stream bandwidth, matching the analytic model's
``recurrent`` term.

Orthogonal contrast with flash attention: there the state is the
(m, l, acc) softmax triplet over a growing KV; here it is a fixed-size
outer-product accumulator - same VMEM-resident-carry schedule, no
quadratic term.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, bonus_ref, s0_ref,
                y_ref, s_out_ref, state_scr, *, chunk: int):
    ct = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ct == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    def step(t, state):
        r_t = r_ref[0, t, 0].astype(jnp.float32)      # (dh,)
        k_t = k_ref[0, t, 0].astype(jnp.float32)
        v_t = v_ref[0, t, 0].astype(jnp.float32)
        w_t = w_ref[0, t, 0].astype(jnp.float32)
        u = bonus_ref[0].astype(jnp.float32)          # (dh,)
        kv = k_t[:, None] * v_t[None, :]              # (dh, dh)
        y_t = jnp.sum((state + u[:, None] * kv) * r_t[:, None], axis=0)
        y_ref[0, t, 0] = y_t.astype(y_ref.dtype)
        return w_t[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(ct == nc - 1)
    def _finalize():
        s_out_ref[0, 0] = state.astype(s_out_ref.dtype)


def rwkv6_scan_pallas(r, k, v, w, bonus, initial_state=None, *,
                      chunk: int = 64, interpret: bool | None = None):
    """r/k/v/w: (B, T, H, dh); bonus: (H, dh);
    initial_state: (B, H, dh, dh) fp32 or None.
    Returns (y (B, T, H, dh), final_state (B, H, dh, dh)).
    ``interpret=None`` auto-detects the backend."""
    interpret = resolve_interpret(interpret)
    b, t, h, dh = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, "T must divide the chunk size"
    if initial_state is None:
        initial_state = jnp.zeros((b, h, dh, dh), jnp.float32)
    grid = (b, h, t // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, dh), lambda b_, h_, c: (h_, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, dh), r.dtype),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, bonus, initial_state)
    return y, s_out
