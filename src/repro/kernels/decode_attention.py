"""Flash-decode (split-K) single-token GQA attention Pallas TPU kernel.

Decode is memory-bound: one query token attends over an L-long KV cache,
so arithmetic intensity ~ O(1) and the roofline is the HBM stream of the
cache.  The kernel's job is to stream K/V tiles through VMEM exactly
once with running-softmax combining - the TPU analogue of
FlashDecoding's split-K partial softmax.

Grid = (B, Hkv, L/bk): each program handles the whole GQA *group* of
query heads for one kv head (the group shares the K/V tile it just paid
to load - a TPU-friendly reuse the CUDA version gets from warp layout).
Running (m, l, acc) scratch persists across the sequential k dimension.

A ``kv_len`` vector masks the tail, so one compiled kernel serves any
cache occupancy (paged/ragged serving upstream).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, block_k: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, d) query group
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
    logits = jax.lax.dot_general(                    # (G, bk)
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    kv_len = len_ref[0]
    kpos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    logits = jnp.where(kpos < kv_len, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array,
                            kv_len: jax.Array | None = None, *,
                            scale: float | None = None,
                            block_k: int = 256,
                            interpret: bool | None = None) -> jax.Array:
    """q: (B, Hq, D); caches: (B, Hkv, L, D); kv_len: (B,) int32 or None.

    Returns (B, Hq, D).  ``interpret=None`` auto-detects the backend.
    """
    interpret = resolve_interpret(interpret)
    b, hq, d = q.shape
    hkv, lmax = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    block_k = min(block_k, lmax)
    assert lmax % block_k == 0, "cache length must divide block_k"
    if kv_len is None:
        kv_len = jnp.full((b,), lmax, jnp.int32)

    # regroup queries: (B, Hkv, G, D) so one program owns a kv head group
    qg = q.reshape(b, hkv, group, d)
    grid = (b, hkv, lmax // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1,), lambda b_, h, j: (b_,)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, kv_len)
    return out.reshape(b, hq, d)
