"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematically transparent reference the kernels are
validated against (``tests/test_kernels_*`` sweep shapes/dtypes and
assert_allclose kernel-vs-oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.states import MESIState

_I, _S = int(MESIState.I), int(MESIState.S)


def rmsnorm_ref(x: jax.Array, weight: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    """RMSNorm (Zhang & Sennrich 2019): x * rsqrt(mean(x^2)+eps) * w."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * weight


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  scale: float | None = None) -> jax.Array:
    """GQA softmax attention oracle.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D) with Hq % Hkv == 0.
    Computed in fp32 regardless of input dtype.
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    q32 = q.astype(jnp.float32) * scale
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    kg = jnp.repeat(k32, group, axis=1)
    vg = jnp.repeat(v32, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q32, kg)
    if causal:
        lk = k.shape[2]
        # rows are the LAST lq positions of the lk-length sequence
        qpos = jnp.arange(lq)[:, None] + (lk - lq)
        kpos = jnp.arange(lk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vg)
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, kv_len: jax.Array | None = None,
                         scale: float | None = None) -> jax.Array:
    """Single-token GQA decode oracle.

    q: (B, Hq, D); caches: (B, Hkv, Lmax, D); kv_len: (B,) valid lengths
    (None = full).  Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv, lmax = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    q32 = q.astype(jnp.float32) * scale
    kg = jnp.repeat(k_cache.astype(jnp.float32), group, axis=1)
    vg = jnp.repeat(v_cache.astype(jnp.float32), group, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q32, kg)
    if kv_len is not None:
        mask = jnp.arange(lmax)[None, None, :] < kv_len[:, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", probs, vg)
    return out.astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, bonus, initial_state=None):
    """WKV recurrence oracle: r/k/v/w (B, T, H, dh); bonus (H, dh).

    Matches the per-step recurrence in ``repro.models.rwkv6._wkv_step``
    (the production model path)."""
    b, t, h, dh = r.shape
    if initial_state is None:
        initial_state = jnp.zeros((b, h, dh, dh), jnp.float32)
    r32, k32, v32, w32 = (x.astype(jnp.float32) for x in (r, k, v, w))
    u = bonus.astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp               # (B, H, dh) each
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    inputs = tuple(x.transpose(1, 0, 2, 3) for x in (r32, k32, v32, w32))
    state, ys = jax.lax.scan(step, initial_state, inputs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state


def mesi_tick_ref(state, version, last_sync, reads_since_fetch,
                  acts, arts, writes,
                  artifact_tokens: int, eager: bool = False,
                  access_k: int = 0, signal_tokens: int = 12):
    """Batched one-tick MESI oracle (numpy, serialized agents).

    Shapes: state/last_sync/reads (B, n, m) int32; version (B, m);
    acts/writes (B, n) bool; arts (B, n) int32.
    Returns updated arrays + per-sim counters dict.  Semantics identical
    to repro.core.acs lazy/eager tick (without TTL/broadcast, which are
    whole-array ops handled outside the kernel).
    """
    state = np.array(state, dtype=np.int32)
    version = np.array(version, dtype=np.int32)
    last_sync = np.array(last_sync, dtype=np.int32)
    reads = np.array(reads_since_fetch, dtype=np.int32)
    B, n, m = state.shape
    fetch_tokens = np.zeros(B, np.int32)
    sig_tokens = np.zeros(B, np.int32)
    push_tokens = np.zeros(B, np.int32)
    n_fetches = np.zeros(B, np.int32)
    n_hits = np.zeros(B, np.int32)

    for s in range(B):
        for a in range(n):
            if not acts[s, a]:
                continue
            d = int(arts[s, a])
            # --- access prologue (read-modify-write needs a valid copy)
            expired = access_k > 0 and reads[s, a, d] >= access_k
            if state[s, a, d] == _I or expired:
                state[s, a, d] = _S
                last_sync[s, a, d] = version[s, d]
                reads[s, a, d] = 0
                fetch_tokens[s] += artifact_tokens + signal_tokens
                n_fetches[s] += 1
            else:
                n_hits[s] += 1
            if writes[s, a]:
                peers = [b for b in range(n)
                         if b != a and state[s, b, d] != _I]
                for b in peers:
                    state[s, b, d] = _I
                sig_tokens[s] += signal_tokens * len(peers)
                version[s, d] += 1
                state[s, a, d] = _S
                last_sync[s, a, d] = version[s, d]
                reads[s, a, d] = 0
                if eager:
                    for b in peers:
                        state[s, b, d] = _S
                        last_sync[s, b, d] = version[s, d]
                        reads[s, b, d] = 0
                        push_tokens[s] += artifact_tokens + signal_tokens
            else:
                reads[s, a, d] += 1
    return (state, version, last_sync, reads,
            {"fetch_tokens": fetch_tokens, "signal_tokens": sig_tokens,
             "push_tokens": push_tokens, "n_fetches": n_fetches,
             "n_hits": n_hits})
