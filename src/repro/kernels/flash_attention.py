"""FlashAttention-style GQA attention Pallas TPU kernel (train/prefill).

Adaptation of the FA-2 schedule to the TPU memory hierarchy:
  * grid = (batch, q_heads, Lq/bq, Lk/bk); the trailing (k) dimension is
    innermost and sequential on TPU, so the running (m, l, acc) softmax
    statistics live in VMEM scratch and persist across k-steps;
  * BlockSpec tiling keeps one (bq x d) query tile and one (bk x d)
    key/value tile in VMEM; the (bq x bk) logit tile never touches HBM -
    that is the IO saving that makes attention compute-bound on the MXU;
  * GQA is expressed in the index_map (kv head = q head // group), so no
    repeated K/V materialization in HBM;
  * block sizes default to 128 (MXU-aligned: the systolic array is
    128x128; last-dim tiles must be multiples of 128 lanes).

Causal masking keeps the full k-range and masks per-tile.  On real
hardware the obvious next step is skipping fully-masked k-tiles (saves
~2x on causal prefill); that is recorded as a perf-iteration candidate
in EXPERIMENTS.md SSPerf rather than hidden here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, lq: int, lk: int,
                  block_q: int, block_k: int):
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
    logits = jax.lax.dot_general(                    # (bq, bk) on the MXU
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    if causal:
        i = pl.program_id(2)
        # absolute positions; q rows are the last lq positions of lk
        qpos = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + (lk - lq)
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        logits = jnp.where(kpos <= qpos, logits, NEG_INF)

    m_prev = m_scr[...]                              # (bq, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                      # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D).  Returns (B, Hq, Lq, D).

    ``interpret=None`` auto-detects: compiled Mosaic on TPU, interpret
    mode elsewhere (``repro.kernels.backend``).
    """
    interpret = resolve_interpret(interpret)
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0, (
        "seq lens must divide block sizes; pad upstream")

    grid = (b, hq, lq // block_q, lk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, lq=lq, lk=lk,
        block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
