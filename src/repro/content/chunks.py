"""Chunk geometry + content-addressed chunk store (the content plane's
host side).

The paper's cost model treats an artifact as an opaque ``|d|``-token
scalar; real coherence hardware invalidates at cache-*line* granularity.
This module fixes the granularity mismatch: an artifact is a fixed
array of ``n_chunks`` chunks of ``chunk_tokens`` tokens each (the last
chunk may be ragged), every chunk is content-addressed by digest, and a
reader that already holds an older copy re-fetches only the chunks
whose authority version moved - the ``O((n+W)*|D|)`` term of Theorem 1
becomes ``O((n+W)*|delta|)``.

Two consumers:

  * the vectorized simulator / Pallas route (``repro.core.acs``,
    ``repro.kernels.chunk_diff``) track per-chunk *version counters*
    and account delta bytes-on-wire without materializing content;
  * the live service (``repro.service``) layers a :class:`ChunkStore`
    over ``repro.core.protocol.ArtifactStore`` so broker reads ship
    **actual** delta payloads and clients reassemble byte-exact copies.

Wire accounting uses ``BYTES_PER_TOKEN`` so the ledgers read in bytes;
the constant cancels in every savings ratio.
"""

from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

#: wire width of one token in the byte ledgers (constant factor only -
#: it cancels in every delta/full/broadcast savings ratio).
BYTES_PER_TOKEN = 4


def n_chunks(artifact_tokens: int, chunk_tokens: int) -> int:
    """Chunk count of one artifact (last chunk may be ragged)."""
    if chunk_tokens <= 0:
        raise ValueError(f"chunk_tokens must be positive, got "
                         f"{chunk_tokens}")
    if artifact_tokens <= 0:
        raise ValueError(f"artifact_tokens must be positive, got "
                         f"{artifact_tokens}")
    return -(-artifact_tokens // chunk_tokens)


def chunk_sizes(artifact_tokens: int, chunk_tokens: int) -> np.ndarray:
    """(C,) int32 token size per chunk; sums to ``artifact_tokens``."""
    C = n_chunks(artifact_tokens, chunk_tokens)
    sizes = np.full(C, chunk_tokens, np.int32)
    sizes[-1] = artifact_tokens - (C - 1) * chunk_tokens
    return sizes


def split_chunks(content: Sequence[int],
                 chunk_tokens: int) -> List[Tuple[int, ...]]:
    """Split a token sequence into its chunk array."""
    content = tuple(int(t) for t in content)
    return [content[i:i + chunk_tokens]
            for i in range(0, len(content), chunk_tokens)]


def reassemble(chunks: Iterable[Sequence[int]]) -> Tuple[int, ...]:
    """Inverse of :func:`split_chunks` (chunk -> reassembly identity)."""
    out: List[int] = []
    for c in chunks:
        out.extend(int(t) for t in c)
    return tuple(out)


def chunk_digest(chunk: Sequence[int]) -> str:
    """Content address of one chunk (sha1 over the token bytes)."""
    h = hashlib.sha1()
    h.update(np.asarray(chunk, np.int64).tobytes())
    return h.hexdigest()


def apply_delta(base: Sequence[int], delta, chunk_tokens: int
                ) -> Tuple[int, ...]:
    """Patch ``base`` with ``delta`` = iterable of (chunk_idx, payload)
    pairs - what a client does with a delta read response."""
    chunks = split_chunks(base, chunk_tokens)
    for idx, payload in delta:
        chunks[int(idx)] = tuple(int(t) for t in payload)
    return reassemble(chunks)


def diff_chunks(cur: Sequence[int], new: Sequence[int],
                chunk_tokens: int) -> np.ndarray:
    """(C,) bool digest-diff between two same-slot contents - the
    single measured-dirty-set implementation (store commits and the
    broker's mid-batch chaining both use it)."""
    old = [chunk_digest(c) for c in split_chunks(cur, chunk_tokens)]
    fresh = [chunk_digest(c) for c in split_chunks(new, chunk_tokens)]
    if len(fresh) != len(old):
        raise ValueError(
            f"write changes chunk count: {len(old)} -> {len(fresh)} "
            f"(fixed-slot artifacts only)")
    return np.array([a != b for a, b in zip(old, fresh)], bool)


class ChunkStore:
    """Content-addressed chunk index layered over an ``ArtifactStore``.

    The wrapped store stays the canonical whole-artifact content plane
    (``store.get`` is always the authority copy); this index maps every
    artifact to its current chunk-digest vector and deduplicates chunk
    payloads by digest, so identical chunks across versions (or across
    artifacts) are stored once and a delta response is assembled by
    digest lookup.
    """

    def __init__(self, store, chunk_tokens: int) -> None:
        self.store = store
        self.chunk_tokens = int(chunk_tokens)
        self._digests: Dict[str, List[str]] = {}
        self._payloads: Dict[str, Tuple[int, ...]] = {}

    # ------------------------------------------------------------ index
    def register(self, name: str) -> None:
        """Index the store's current content for ``name``."""
        chunks = split_chunks(self.store.get(name), self.chunk_tokens)
        digests = []
        for c in chunks:
            dg = chunk_digest(c)
            self._payloads[dg] = c
            digests.append(dg)
        self._digests[name] = digests

    def n_chunks_of(self, name: str) -> int:
        return len(self._digests[name])

    @property
    def n_unique_chunks(self) -> int:
        """Deduplicated payload count (content-addressing at work)."""
        return len(self._payloads)

    # ------------------------------------------------------------ write
    def diff_mask(self, name: str, new_content: Sequence[int]
                  ) -> np.ndarray:
        """(C,) bool: chunks whose digest would change if ``name`` were
        rewritten to ``new_content`` - the *actual* dirty set a live
        write carries (the simulator samples this; the service measures
        it)."""
        old = self._digests[name]
        new = [chunk_digest(c)
               for c in split_chunks(new_content, self.chunk_tokens)]
        if len(new) != len(old):
            raise ValueError(
                f"write changes chunk count of {name!r}: {len(old)} -> "
                f"{len(new)} (fixed-slot artifacts only)")
        return np.array([a != b for a, b in zip(old, new)], bool)

    def put(self, name: str, new_content: Sequence[int]) -> np.ndarray:
        """Commit ``new_content``; returns the (C,) bool dirty mask."""
        mask = self.diff_mask(name, new_content)
        self.store.put(name, list(new_content))
        self.register(name)
        return mask

    # ------------------------------------------------------------- read
    def chunk(self, name: str, idx: int) -> Tuple[int, ...]:
        return self._payloads[self._digests[name][int(idx)]]

    def delta(self, name: str, indices) -> Tuple[Tuple[int, Tuple[int, ...]], ...]:
        """Delta payload: ((chunk_idx, chunk_tokens), ...) for the
        requested stale chunk indices."""
        return tuple((int(i), self.chunk(name, i)) for i in indices)

    def reassembled(self, name: str) -> Tuple[int, ...]:
        """Rebuild the artifact from its chunk index (must equal the
        wrapped store's canonical copy - asserted by the oracle)."""
        return reassemble(self.chunk(name, i)
                          for i in range(len(self._digests[name])))
