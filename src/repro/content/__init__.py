"""Chunk-granular content plane: delta coherence below MESI's
whole-artifact granularity.

Geometry + host-side content-addressed store live in
:mod:`repro.content.chunks`; the vectorized per-chunk version/dirty
state machine is threaded through ``repro.core.acs`` (scan path) and
``repro.kernels.chunk_diff`` (batched Pallas kernel); the byte-exact
differential harness is ``repro.sim.oracle.check_content_trace``.
"""

from repro.content.chunks import (BYTES_PER_TOKEN, ChunkStore,
                                  apply_delta, chunk_digest, chunk_sizes,
                                  n_chunks, reassemble, split_chunks)

__all__ = [
    "BYTES_PER_TOKEN", "ChunkStore", "apply_delta", "chunk_digest",
    "chunk_sizes", "n_chunks", "reassemble", "split_chunks",
]
