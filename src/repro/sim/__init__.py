"""Tick-based discrete-event simulation of artifact coherence (paper SS8)."""

from repro.sim.scenarios import (
    ScenarioConfig, SCENARIOS, CLIFF_VOLATILITIES, SCALING_AGENT_COUNTS,
    SCALING_ARTIFACT_TOKENS, SCALING_STEPS, canonical, cliff_scenario,
    agent_scaling_scenario, artifact_size_scenario, step_scaling_scenario,
    pointer_semantics_scenario,
)
from repro.sim.engine import (
    RunStats, RunResult, Comparison, run_scenario, compare, compare_grid,
    compare_workloads, run_workload, sweep_volatility, sweep_cells,
    trace_count, reset_trace_count, trace_counter, TraceCounter,
    clear_compile_cache, resolve_tick_backend, resolve_sweep_devices,
    shard_plan, ShardPlan,
)
from repro.sim.workloads import (
    Workload, FAMILIES, FAMILY_SEEDS, make, zoo, random_workload,
    zipf_weights,
)

__all__ = [
    "ScenarioConfig", "SCENARIOS", "CLIFF_VOLATILITIES",
    "SCALING_AGENT_COUNTS", "SCALING_ARTIFACT_TOKENS", "SCALING_STEPS",
    "canonical", "cliff_scenario", "agent_scaling_scenario",
    "artifact_size_scenario", "step_scaling_scenario",
    "pointer_semantics_scenario",
    "RunStats", "RunResult", "Comparison", "run_scenario", "compare",
    "compare_grid", "compare_workloads", "run_workload",
    "sweep_volatility", "sweep_cells", "trace_count",
    "reset_trace_count", "trace_counter", "TraceCounter",
    "clear_compile_cache", "resolve_tick_backend",
    "resolve_sweep_devices", "shard_plan", "ShardPlan",
    "Workload", "FAMILIES", "FAMILY_SEEDS", "make", "zoo",
    "random_workload", "zipf_weights",
]
