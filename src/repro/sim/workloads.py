"""Heterogeneous workload generator (beyond-paper evaluation surface).

The paper's four scenarios (SS8.1) are homogeneous: every agent acts
with the same probability, picks artifacts uniformly, and writes with a
single scalar volatility V.  Real multi-agent deployments are dominated
by *structured, skewed* access - bursty writers, hot/cold artifact
skew, planner/worker hierarchies, read-heavy retrieval, pipeline
handoff, write ping-pong - and the MESI-transfer claim is only as
strong as the access diversity it survives.

A :class:`Workload` replaces the scalar ``(p_act, volatility)`` pair
with three rate tensors:

  * ``p_act``       (n,)    per-agent activity probability;
  * ``pick``        (n, m)  artifact-selection distribution per agent
                            (rows sum to 1);
  * ``write_rate``  (n, m)  P(write | agent a selected artifact d).

These are *traced* axes of the fused sweep engine
(``repro.sim.engine.compare_workloads``): one XLA compilation covers
every workload family that shares a static shape, Pallas tick route
included.  Each family below is a small closed-form generator, so
sweeps can perturb skew/burstiness without leaving the compiled
program.

Family taxonomy (also documented in ``benchmarks/README.md``):

  ``bursty``        a small clique of hot writers carries nearly all
                    writes; everyone else reads.
  ``zipf``          hot/cold artifact skew: selection follows a Zipf
                    law over artifacts, moderate uniform write rate.
  ``hierarchical``  planner/worker team: one planner rewrites the plan
                    artifact, workers read the plan and write private
                    output artifacts.
  ``rag``           read-heavy retrieval: near-zero write rates except
                    a single index-refresher agent.
  ``pipeline``      DAG handoff: stage i consumes artifact i and
                    produces artifact i+1 (mod m).
  ``ping_pong``     adversarial invalidation churn: two agents
                    alternate writes to one contended artifact while
                    spectators try to read it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.acs import ACSConfig, LAZY, RateMatrices

#: floor applied before log() so zero-probability picks become
#: effectively -inf logits without producing nan under categorical.
_LOG_FLOOR = 1e-30


@dataclasses.dataclass(frozen=True)
class Workload:
    """One heterogeneous evaluation workload.

    ``acs`` supplies the static shape/strategy fields; its scalar
    ``p_act`` / ``volatility`` are ignored by the heterogeneous path
    (the rate tensors below take precedence).
    """

    name: str
    family: str
    acs: ACSConfig
    p_act: np.ndarray       # (n,)
    pick: np.ndarray        # (n, m), rows sum to 1
    write_rate: np.ndarray  # (n, m)
    seed: int
    n_runs: int = 10
    description: str = ""
    #: fraction of an artifact's chunks one write dirties (the content
    #: plane's sampled span length; see ``acs.draw_write_chunks``).  A
    #: *traced* axis of the fused engine, like the rate tensors - only
    #: meaningful when the workload's config enables ``chunk_tokens``.
    write_locality: float = 1.0

    def __post_init__(self):
        n, m = self.acs.n_agents, self.acs.n_artifacts
        p = np.asarray(self.p_act, np.float64)
        pick = np.asarray(self.pick, np.float64)
        wr = np.asarray(self.write_rate, np.float64)
        if p.shape != (n,) or pick.shape != (n, m) or wr.shape != (n, m):
            raise ValueError(
                f"rate shapes {p.shape}/{pick.shape}/{wr.shape} do not "
                f"match config (n={n}, m={m})")
        for arr, label in ((p, "p_act"), (pick, "pick"),
                           (wr, "write_rate")):
            if (arr < 0).any() or (arr > 1).any():
                raise ValueError(f"{label} outside [0, 1]")
        if not np.allclose(pick.sum(axis=1), 1.0, atol=1e-6):
            raise ValueError("pick rows must sum to 1")

    # -- engine interface -------------------------------------------------
    def rates(self) -> RateMatrices:
        """The traced-tensor form consumed by the fused engine."""
        return RateMatrices(
            p_act=jnp.asarray(self.p_act, jnp.float32),
            log_pick=jnp.log(jnp.maximum(
                jnp.asarray(self.pick, jnp.float32), _LOG_FLOOR)),
            write_rate=jnp.asarray(self.write_rate, jnp.float32),
        )

    def effective_volatility(self) -> float:
        """E[write | action], averaged over acting agents - the scalar
        V this workload collapses to under homogenization."""
        per_agent = (self.pick * self.write_rate).sum(axis=1)
        weights = np.asarray(self.p_act, np.float64)
        total = weights.sum()
        if total <= 0:
            return 0.0
        return float((per_agent * weights).sum() / total)

    def with_strategy(self, strategy_code: int) -> "Workload":
        return dataclasses.replace(
            self, acs=dataclasses.replace(self.acs,
                                          strategy=strategy_code))

    def with_overrides(self, **acs_overrides) -> "Workload":
        return dataclasses.replace(
            self, acs=dataclasses.replace(self.acs, **acs_overrides))

    def with_volatility(self, volatility: float) -> "Workload":
        """Rescale the write-rate tensor so ``effective_volatility()``
        hits ``volatility`` while preserving the family's *structure*
        (who writes what stays fixed; only how often changes).  Rates
        clip at 1, so the realized volatility can undershoot for
        extreme targets on saturated families - callers sweeping V use
        ``effective_volatility()`` of the result as the realized
        axis value."""
        eff = self.effective_volatility()
        if eff <= 0:
            raise ValueError(
                f"workload {self.name!r} has zero effective volatility;"
                f" cannot rescale to {volatility}")
        scaled = np.clip(np.asarray(self.write_rate, np.float64)
                         * (volatility / eff), 0.0, 1.0)
        return dataclasses.replace(self, write_rate=scaled)

    def with_locality(self, write_locality: float) -> "Workload":
        return dataclasses.replace(self,
                                   write_locality=float(write_locality))


# ---------------------------------------------------------------------------
# Shared structure helpers.


def _uniform_rows(n: int, m: int) -> np.ndarray:
    return np.full((n, m), 1.0 / m)


def zipf_weights(m: int, s: float = 1.2) -> np.ndarray:
    """Zipf-law selection weights over artifact ranks (hot -> cold)."""
    w = 1.0 / np.arange(1, m + 1, dtype=np.float64) ** s
    return w / w.sum()


def _base_cfg(n_agents: int, n_artifacts: int, **overrides) -> ACSConfig:
    params = dict(n_agents=n_agents, n_artifacts=n_artifacts,
                  artifact_tokens=4096, n_steps=40, strategy=LAZY)
    params.update(overrides)
    return ACSConfig(**params)


# ---------------------------------------------------------------------------
# Family generators.  Each returns a Workload; shapes/strategy are
# controlled by **cfg overrides so a whole zoo can share one static
# signature (= one compilation).


def bursty(n_agents: int = 8, n_artifacts: int = 6, seed: int = 0,
           n_runs: int = 10, n_writers: int = 2, hot_rate: float = 0.9,
           cold_rate: float = 0.02, write_locality: float = 0.25,
           **cfg) -> Workload:
    """A small clique of hot writers; the rest of the fleet reads."""
    n, m = n_agents, n_artifacts
    wr = np.full((n, m), cold_rate)
    wr[:n_writers, :] = hot_rate
    p_act = np.full(n, 0.6)
    p_act[:n_writers] = 0.9
    return Workload(
        name=f"bursty w={n_writers}", family="bursty",
        acs=_base_cfg(n, m, **cfg), p_act=p_act,
        pick=_uniform_rows(n, m), write_rate=wr, seed=seed,
        n_runs=n_runs, write_locality=write_locality,
        description=f"{n_writers} agents carry ~all writes at "
                    f"rate {hot_rate}; others read at {cold_rate}.")


def zipf(n_agents: int = 8, n_artifacts: int = 6, seed: int = 0,
         n_runs: int = 10, skew: float = 1.2, volatility: float = 0.15,
         write_locality: float = 0.4, **cfg) -> Workload:
    """Hot/cold artifact skew: Zipf(s) selection, uniform write rate."""
    n, m = n_agents, n_artifacts
    pick = np.tile(zipf_weights(m, skew), (n, 1))
    return Workload(
        name=f"zipf s={skew}", family="zipf",
        acs=_base_cfg(n, m, **cfg), p_act=np.full(n, 0.75),
        pick=pick, write_rate=np.full((n, m), volatility), seed=seed,
        n_runs=n_runs, write_locality=write_locality,
        description=f"Zipf({skew}) artifact selection, uniform "
                    f"V={volatility}.")


def hierarchical(n_agents: int = 8, n_artifacts: int = 6, seed: int = 0,
                 n_runs: int = 10, plan_write: float = 0.35,
                 out_write: float = 0.55,
                 write_locality: float = 0.2, **cfg) -> Workload:
    """Planner/worker team: agent 0 rewrites the plan (artifact 0) and
    monitors outputs; workers read the plan and write their own output
    artifact (1 + (a-1) mod (m-1))."""
    n, m = n_agents, n_artifacts
    if m < 2:
        raise ValueError("hierarchical needs >= 2 artifacts")
    pick = np.zeros((n, m))
    wr = np.zeros((n, m))
    # planner: 60% plan focus, 40% spread over worker outputs
    pick[0, 0] = 0.6
    pick[0, 1:] = 0.4 / (m - 1)
    wr[0, 0] = plan_write
    for a in range(1, n):
        own = 1 + (a - 1) % (m - 1)
        pick[a, 0] = 0.5          # read the plan
        pick[a, own] = 0.5        # work on own output
        wr[a, own] = out_write
    return Workload(
        name="hierarchical", family="hierarchical",
        acs=_base_cfg(n, m, **cfg), p_act=np.full(n, 0.8),
        pick=pick, write_rate=wr, seed=seed, n_runs=n_runs,
        write_locality=write_locality,
        description="1 planner rewriting the plan; workers read plan, "
                    "write private outputs.")


def rag(n_agents: int = 8, n_artifacts: int = 6, seed: int = 0,
        n_runs: int = 10, skew: float = 1.1, read_write: float = 0.01,
        refresh_write: float = 0.25, write_locality: float = 0.1,
        **cfg) -> Workload:
    """Read-heavy retrieval: everyone reads Zipf-hot corpus shards;
    one index-refresher agent occasionally rewrites the hot shards."""
    n, m = n_agents, n_artifacts
    pick = np.tile(zipf_weights(m, skew), (n, 1))
    wr = np.full((n, m), read_write)
    wr[n - 1, :] = refresh_write * zipf_weights(m, skew) / zipf_weights(
        m, skew).max()
    return Workload(
        name="rag read-heavy", family="rag",
        acs=_base_cfg(n, m, **cfg), p_act=np.full(n, 0.85),
        pick=pick, write_rate=wr, seed=seed, n_runs=n_runs,
        write_locality=write_locality,
        description="near-zero write rates except one index refresher.")


def pipeline(n_agents: int = 8, n_artifacts: int = 6, seed: int = 0,
             n_runs: int = 10, produce_rate: float = 0.7,
             write_locality: float = 0.5, **cfg) -> Workload:
    """Pipeline-DAG handoff: stage i consumes artifact i mod m and
    produces artifact (i+1) mod m."""
    n, m = n_agents, n_artifacts
    pick = np.zeros((n, m))
    wr = np.zeros((n, m))
    for a in range(n):
        upstream, own = a % m, (a + 1) % m
        if upstream == own:       # m == 1 degenerate case
            pick[a, own] = 1.0
        else:
            pick[a, upstream] = 0.5
            pick[a, own] = 0.5
        wr[a, own] = produce_rate
    return Workload(
        name="pipeline dag", family="pipeline",
        acs=_base_cfg(n, m, **cfg), p_act=np.full(n, 0.75),
        pick=pick, write_rate=wr, seed=seed, n_runs=n_runs,
        write_locality=write_locality,
        description="stage i reads artifact i, writes artifact i+1.")


def ping_pong(n_agents: int = 8, n_artifacts: int = 6, seed: int = 0,
              n_runs: int = 10, spectator_focus: float = 0.7,
              write_locality: float = 0.15, **cfg) -> Workload:
    """Adversarial write ping-pong: two agents write the same contended
    artifact every action; spectators keep trying to read it.  The
    worst case for invalidation protocols - every write invalidates
    every reader, so coherent traffic approaches broadcast."""
    n, m = n_agents, n_artifacts
    if n < 2:
        raise ValueError("ping_pong needs >= 2 agents")
    pick = np.zeros((n, m))
    wr = np.zeros((n, m))
    pick[:2, 0] = 1.0
    wr[:2, 0] = 1.0
    for a in range(2, n):
        if m == 1:
            pick[a, 0] = 1.0
        else:
            pick[a, 0] = spectator_focus
            pick[a, 1:] = (1.0 - spectator_focus) / (m - 1)
    p_act = np.full(n, 0.5)
    p_act[:2] = 1.0
    return Workload(
        name="write ping-pong", family="ping_pong",
        acs=_base_cfg(n, m, **cfg), p_act=p_act,
        pick=pick, write_rate=wr, seed=seed, n_runs=n_runs,
        write_locality=write_locality,
        description="2 agents alternate writes to one hot artifact; "
                    "spectators read it.")


FAMILIES: Dict[str, Callable[..., Workload]] = {
    "bursty": bursty,
    "zipf": zipf,
    "hierarchical": hierarchical,
    "rag": rag,
    "pipeline": pipeline,
    "ping_pong": ping_pong,
}

#: deterministic per-family seeds (same convention as SS8.1 scenarios).
FAMILY_SEEDS = {f: 20260401 + i for i, f in enumerate(FAMILIES)}


def make(family: str, **kw) -> Workload:
    """Build one family instance; unknown keys go to the ACS config."""
    try:
        builder = FAMILIES[family]
    except KeyError:
        raise KeyError(
            f"unknown workload family {family!r}; "
            f"have {sorted(FAMILIES)}") from None
    kw.setdefault("seed", FAMILY_SEEDS[family])
    return builder(**kw)


def zoo(n_agents: int = 8, n_artifacts: int = 6, n_runs: int = 10,
        families: Sequence[str] = tuple(FAMILIES),
        **cfg) -> list[Workload]:
    """The standard workload zoo: one instance per family, all sharing
    one static signature so ``compare_workloads`` fuses the whole zoo
    into a single compiled program."""
    return [make(f, n_agents=n_agents, n_artifacts=n_artifacts,
                 n_runs=n_runs, **cfg) for f in families]


def random_workload(seed: int, n_agents: int = 4, n_artifacts: int = 3,
                    n_runs: int = 4, **cfg) -> Workload:
    """A fully random rate-matrix workload (property-test fodder):
    Dirichlet selection rows, iid uniform write rates and activities."""
    rng = np.random.default_rng(seed)
    n, m = n_agents, n_artifacts
    return Workload(
        name=f"random-{seed}", family="random",
        acs=_base_cfg(n, m, **cfg),
        p_act=rng.uniform(0.2, 1.0, n),
        pick=rng.dirichlet(np.ones(m), size=n),
        write_rate=rng.uniform(0.0, 1.0, (n, m)),
        seed=seed, n_runs=n_runs,
        write_locality=float(rng.uniform(0.05, 1.0)),
        description="random rates (hypothesis property tests).")
