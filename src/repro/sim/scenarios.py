"""Scenario registry for the paper's evaluation (SS8.1).

Canonical parameters (all configurations): n = 4 agents, m = 3 artifacts,
|d_i| = 4,096 tokens, S = 40 steps, action probability 0.75, 10 runs per
configuration with scenario-specific deterministic seeds (A-D use
20260305-20260308; run r uses fold_in(seed, r)).
"""

from __future__ import annotations

import dataclasses

from repro.core.acs import ACSConfig, LAZY


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """One evaluation workload: an ACSConfig plus run bookkeeping."""

    name: str
    acs: ACSConfig
    seed: int
    n_runs: int = 10
    description: str = ""

    def with_strategy(self, strategy_code: int) -> "ScenarioConfig":
        return dataclasses.replace(
            self, acs=dataclasses.replace(self.acs, strategy=strategy_code))

    def with_overrides(self, **acs_overrides) -> "ScenarioConfig":
        return dataclasses.replace(
            self, acs=dataclasses.replace(self.acs, **acs_overrides))


CANONICAL = dict(n_agents=4, n_artifacts=3, artifact_tokens=4096,
                 n_steps=40, p_act=0.75, strategy=LAZY)


def canonical(name: str, volatility: float, seed: int,
              description: str = "", **overrides) -> ScenarioConfig:
    params = dict(CANONICAL, volatility=volatility, **overrides)
    return ScenarioConfig(name=name, acs=ACSConfig(**params), seed=seed,
                          description=description)


#: The four workload scenarios of SS8.1 with the published seeds.
SCENARIOS: dict[str, ScenarioConfig] = {
    "A": canonical(
        "A: Planning", 0.05, 20260305,
        "Infrequent plan revisions (W ~= 2 writes per artifact)."),
    "B": canonical(
        "B: Analysis", 0.10, 20260306,
        "Periodic shared-document updates (W ~= 4)."),
    "C": canonical(
        "C: Development", 0.25, 20260307,
        "Moderate artifact churn (W ~= 10)."),
    "D": canonical(
        "D: High Churn", 0.50, 20260308,
        "Frequent modification by multiple agents (W ~= 20)."),
}

#: SS8.3 volatility-cliff sweep (canonical params, V varies).
CLIFF_VOLATILITIES = (0.01, 0.05, 0.10, 0.25, 0.50, 0.75, 0.90, 1.00)

#: SS8.5 agent-count scaling (Scenario B volatility).
SCALING_AGENT_COUNTS = (2, 4, 8, 16)

#: SS8.6 artifact-size scaling (Scenario A volatility).
SCALING_ARTIFACT_TOKENS = (4096, 8192, 32768, 65536)

#: SS8.7 step-count scaling (fixed W ~= 2 -> V = 2/S).
SCALING_STEPS = (5, 10, 20, 40, 50, 100)


def cliff_scenario(v: float) -> ScenarioConfig:
    return canonical(f"cliff V={v}", v, 20260310 + int(round(v * 100)))


def agent_scaling_scenario(n: int) -> ScenarioConfig:
    return canonical(f"agents n={n}", 0.10, 20260320 + n, n_agents=n)


def artifact_size_scenario(tokens: int) -> ScenarioConfig:
    return canonical(f"size |d|={tokens}", 0.05,
                     20260330 + tokens % 97, artifact_tokens=tokens)


def step_scaling_scenario(s: int) -> ScenarioConfig:
    # fixed write budget W ~= 2 per artifact: V = W/S = 2/S (Def. 4)
    return canonical(f"steps S={s}", 2.0 / s, 20260340 + s, n_steps=s)


def pointer_semantics_scenario() -> ScenarioConfig:
    """SS8.8: pointer-reference architecture with frequent cold fetches.

    One shared artifact that every agent dereferences every step
    (p_act = 1.0, m = 1) under moderate churn.  Under lazy, every
    write-invalidation turns the next dereference into a synchronous
    full fetch (a stall); under eager, push-on-commit keeps cache
    occupancy near-perfect and only the n initial fills hit the
    critical path.  sync_tokens counts critical-path traffic only;
    eager's background push bytes are reported separately.
    """
    return canonical("pointer semantics", 0.25, 20260350,
                     p_act=1.0, n_steps=40, n_artifacts=1)
