"""Four-way differential conformance oracle.

The repo carries four independent executions of the CCS protocol:

  1. the message-level reference implementation
     (``repro.core.protocol``: coordinator / event bus / agent caches);
  2. the vectorized JAX state machine (``repro.core.acs``);
  3. the batched Pallas MESI tick (``repro.kernels.mesi_transition``);
  4. the model checker's transition relation
     (``repro.core.model_check.successors``).

Each was only ever cross-checked pairwise on canonical scenarios.  This
module samples ONE action trace from a (possibly heterogeneous)
workload - using the exact PRNG key schedule of the fused sweep engine,
so the trace is precisely what ``run_episode`` executes - and replays
it through all four, asserting **bit-exact token-ledger and final
MESI-state agreement**.  ``tests/differential`` drives it over the
workload families; every future scaling PR is validated against it.

Scope notes:

  * The differential strategies are the invalidation family
    (lazy / eager / access_count) - broadcast and TTL are bulk-inject
    paths with no per-agent transition to diff.
  * The model-checker leg covers LAZY only: the spec has no push
    (eager) or expiry-refetch-from-valid (access_count) action.  It
    verifies the trace is a *path* of the transition relation and that
    the final abstract state agrees, under the abstraction
    ``M -> S`` for the committed writer (the spec's Write leaves the
    writer in M; the executable protocol commits the writer back to S,
    paper SS5.3).
  * The Pallas kernel tracks token counters, not the staleness
    diagnostics; its ledger comparison covers every counter the kernel
    emits (fetch/signal/push tokens, fetches, hits, invalidations).

Beyond the four token-ledger legs, :func:`check_content_trace` is the
**byte-exact** leg for the chunk-granular content plane
(``repro.content``): the same trace replays through the chunked scan
path, the Pallas chunk-diff route, a real-payload content-addressed
chunk store (asserting every patched reader copy reassembles to the
authority artifact), and the whole-artifact protocol baseline -
bit-identical byte ledgers, ``delta <= full`` per fill.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acs, model_check as mc
from repro.core.protocol import (AgentRuntime, ArtifactStore,
                                 CoordinatorService, EventBus)
from repro.core.states import MESIState
from repro.kernels.mesi_transition import mesi_tick_pallas

_I, _S, _E, _M = (int(MESIState.I), int(MESIState.S),
                  int(MESIState.E), int(MESIState.M))

#: strategies the differential harness covers (see module docstring).
DIFFERENTIAL_STRATEGIES = (acs.LAZY, acs.EAGER, acs.ACCESS_COUNT)


class ConformanceError(AssertionError):
    """Two implementations of the protocol disagreed on a trace."""


@dataclasses.dataclass(frozen=True)
class Trace:
    """One sampled episode of actions, (n_steps, n_agents) arrays.

    ``write_chunks`` is the content plane's per-write dirty chunk mask
    ((n_steps, n_agents, C) bool; ``None`` for whole-artifact traces):
    engine traces sample it from the write-locality span distribution,
    service traces record the *measured* content diff of each commit.
    """

    acts: np.ndarray    # bool: agent a acted at step s
    arts: np.ndarray    # int32: artifact chosen
    writes: np.ndarray  # bool: action was a write
    write_chunks: np.ndarray | None = None

    @property
    def n_actions(self) -> int:
        return int(self.acts.sum())


@dataclasses.dataclass(frozen=True)
class Ledger:
    """Implementation-neutral token ledger (all exact integers)."""

    fetch_tokens: int
    signal_tokens: int
    push_tokens: int
    n_fetches: int
    n_hits: int
    n_reads: int
    n_writes: int
    n_invalidation_signals: int

    @property
    def total_tokens(self) -> int:
        return self.fetch_tokens + self.signal_tokens + self.push_tokens


@dataclasses.dataclass(frozen=True)
class DiffReport:
    """Agreed-upon results of a conformance run (post-assertion)."""

    workload: str
    strategy: str
    trace: Trace
    ledger: Ledger
    state: np.ndarray      # (n, m) final MESI states
    version: np.ndarray    # (m,) final canonical versions
    last_sync: np.ndarray  # (n, m) version at last fill/commit
    implementations: tuple


# ---------------------------------------------------------------------------
# Trace sampling - the engine's exact action stream.


def episode_key(seed: int, run: int = 0) -> jax.Array:
    """The engine's per-run key: ``fold_in(PRNGKey(seed), run)``
    (``engine._grid_keys``), so replays target a specific grid cell."""
    return jax.random.fold_in(jax.random.PRNGKey(int(seed)), run)


def sample_trace(cfg: acs.ACSConfig, key: jax.Array,
                 rates: acs.RateMatrices | None = None,
                 locality: float | None = None) -> Trace:
    """Sample the action stream ``run_episode(cfg, key, rates=rates)``
    executes, via the shared ``acs.draw_actions`` sampler and the same
    per-step key split.  With the content plane enabled the per-step
    write spans are sampled too (``acs.draw_write_chunks``, same
    fold-in key schedule the engine uses), so the trace pins byte
    ledgers as exactly as it pins token ledgers."""
    keys = jax.random.split(key, cfg.n_steps)
    acts, arts, writes = jax.vmap(
        lambda k: acs.draw_actions(k, cfg.n_agents, cfg.n_artifacts,
                                   cfg.volatility, cfg.p_act, rates))(keys)
    write_chunks = None
    if acs.content_enabled(cfg):
        loc = cfg.write_locality if locality is None else locality
        write_chunks = np.asarray(jax.vmap(
            lambda k: acs.draw_write_chunks(
                k, cfg.n_agents, acs.content_chunks(cfg), loc))(keys),
            bool)
    return Trace(acts=np.asarray(acts, bool),
                 arts=np.asarray(arts, np.int32),
                 writes=np.asarray(writes, bool),
                 write_chunks=write_chunks)


def _actions(trace: Trace):
    """Serialized (step, agent, artifact, is_write) stream - authority
    order: steps ascending, agents ascending within a step (the
    ``fori_loop`` order of ``acs.tick``)."""
    n_steps, n_agents = trace.acts.shape
    for s in range(n_steps):
        for a in range(n_agents):
            if trace.acts[s, a]:
                yield s, a, int(trace.arts[s, a]), bool(trace.writes[s, a])


# ---------------------------------------------------------------------------
# Leg 1: message-level protocol.


def replay_protocol(cfg: acs.ACSConfig, trace: Trace):
    """Replay through coordinator / event bus / agent runtimes."""
    strategy = acs.STRATEGY_NAMES[cfg.strategy]
    bus = EventBus()
    store = ArtifactStore()
    coord = CoordinatorService(bus, store, strategy=strategy)
    for d in range(cfg.n_artifacts):
        coord.register_artifact(f"artifact-{d}",
                                list(range(cfg.artifact_tokens)))
    agents = [AgentRuntime(f"agent-{a}", coord, bus, strategy=strategy,
                           access_k=cfg.access_k,
                           max_stale_steps=cfg.max_stale_steps)
              for a in range(cfg.n_agents)]
    for s, a, d, is_write in _actions(trace):
        if is_write:
            agents[a].write(f"artifact-{d}", [s] * cfg.artifact_tokens)
        else:
            agents[a].read(f"artifact-{d}")

    led = coord.ledger
    ledger = Ledger(
        fetch_tokens=led.fetch_tokens, signal_tokens=led.signal_tokens,
        push_tokens=led.push_tokens, n_fetches=led.n_fetches,
        n_hits=led.n_hits, n_reads=led.n_reads, n_writes=led.n_writes,
        n_invalidation_signals=led.n_invalidation_signals)
    state = np.array([[int(ag.state_of(f"artifact-{d}"))
                       for d in range(cfg.n_artifacts)] for ag in agents],
                     np.int32)
    # the authority directory must mirror the agent-side cache states
    # (immediate bus delivery); a divergence is a protocol bug.
    dir_state = np.array(
        [[int(coord.agent_state(f"agent-{a}", f"artifact-{d}"))
          for d in range(cfg.n_artifacts)] for a in range(cfg.n_agents)],
        np.int32)
    if not np.array_equal(state, dir_state):
        raise ConformanceError(
            "protocol authority directory diverged from agent caches:\n"
            f"agents:\n{state}\ndirectory:\n{dir_state}")
    version = np.array([coord.directory[f"artifact-{d}"].version
                        for d in range(cfg.n_artifacts)], np.int32)
    sync = np.zeros((cfg.n_agents, cfg.n_artifacts), np.int32)
    for a, ag in enumerate(agents):
        for d in range(cfg.n_artifacts):
            entry = ag.cache.get(f"artifact-{d}")
            if entry is not None:
                sync[a, d] = entry.version
    return ledger, state, version, sync


# ---------------------------------------------------------------------------
# Leg 2: vectorized JAX state machine (eager replay of the tick bodies).


def replay_vectorized(cfg: acs.ACSConfig, trace: Trace):
    content = acs.content_enabled(cfg)
    arrays = acs.init_arrays(cfg)
    met = acs.init_metrics()
    for s, a, d, is_write in _actions(trace):
        arrays = arrays._replace(
            agent_actions=arrays.agent_actions.at[a].add(1))
        if is_write:
            wchunks = (jnp.asarray(trace.write_chunks[s, a])
                       if content else None)
            arrays, met = acs._do_write(cfg, arrays, met, a, d,
                                        wchunks=wchunks)
        else:
            arrays, met = acs._do_read(cfg, arrays, met, a, d)
    ledger = Ledger(
        fetch_tokens=int(met.fetch_tokens),
        signal_tokens=int(met.signal_tokens),
        push_tokens=int(met.push_tokens),
        n_fetches=int(met.n_fetches), n_hits=int(met.n_hits),
        n_reads=int(met.n_reads), n_writes=int(met.n_writes),
        n_invalidation_signals=int(met.n_invalidation_signals))
    return (ledger, np.asarray(arrays.state, np.int32),
            np.asarray(arrays.version, np.int32),
            np.asarray(arrays.last_sync, np.int32))


# ---------------------------------------------------------------------------
# Leg 3: Pallas MESI tick kernel (batch of one simulation).


def replay_pallas(cfg: acs.ACSConfig, trace: Trace):
    if cfg.strategy not in DIFFERENTIAL_STRATEGIES:
        raise ValueError("pallas leg covers the invalidation strategies")
    n, m = cfg.n_agents, cfg.n_artifacts
    state = jnp.full((1, n, m), _I, jnp.int32)
    version = jnp.ones((1, m), jnp.int32)
    sync = jnp.zeros((1, n, m), jnp.int32)
    reads = jnp.zeros((1, n, m), jnp.int32)
    counters = np.zeros(8, np.int64)
    n_steps = trace.acts.shape[0]
    for s in range(n_steps):
        a = jnp.asarray(trace.acts[s][None], jnp.int32)
        d = jnp.asarray(trace.arts[s][None], jnp.int32)
        w = jnp.asarray(trace.writes[s][None], jnp.int32)
        state, version, sync, reads, cnt, _ = mesi_tick_pallas(
            state, version, sync, reads, a, d, w,
            artifact_tokens=cfg.artifact_tokens,
            eager=cfg.strategy == acs.EAGER,
            access_k=(cfg.access_k
                      if cfg.strategy == acs.ACCESS_COUNT else 0),
            signal_tokens=acs.SIGNAL_TOKENS)
        counters += np.asarray(cnt[0], np.int64)
    ledger = Ledger(
        fetch_tokens=int(counters[0]), signal_tokens=int(counters[1]),
        push_tokens=int(counters[2]), n_fetches=int(counters[3]),
        n_hits=int(counters[4]),
        n_reads=int((trace.acts & ~trace.writes).sum()),
        n_writes=int((trace.acts & trace.writes).sum()),
        n_invalidation_signals=int(counters[5]))
    return (ledger, np.asarray(state[0], np.int32),
            np.asarray(version[0], np.int32),
            np.asarray(sync[0], np.int32))


# ---------------------------------------------------------------------------
# Leg 4: model-checker transition relation (abstract, per-artifact).


#: exploration caps large enough that replay guards never bind.
_UNCAPPED = 1 << 28


def replay_model_check(cfg: acs.ACSConfig, trace: Trace):
    """Drive ``model_check.successors`` with the trace's micro-actions.

    Each artifact runs an independent instance of the single-artifact
    spec (artifacts never interact; the sharded-directory argument).
    An ACS read decomposes into ``[Fetch] Read``; an ACS write into
    ``[Fetch] [Upgrade] Write`` (Fetch iff Invalid, Upgrade iff Shared
    - a committed writer is the spec's M owner and writes directly).
    Every micro-action must be *enabled* in the spec's Next relation at
    the current state, so the whole trace is a path of the model the
    paper model-checked.  Invariants are asserted on every visited
    state.
    """
    if cfg.strategy != acs.LAZY:
        raise ValueError("model-check leg covers LAZY only")
    n, m = cfg.n_agents, cfg.n_artifacts
    mc_cfg = mc.CheckConfig(n_agents=n, max_stale_steps=_UNCAPPED,
                            max_version=_UNCAPPED, max_steps=_UNCAPPED)
    # ACS cold start: all Invalid at version 1, never synced.
    states = [(1, (mc.I,) * n, (0,) * n, (0,) * n) for _ in range(m)]

    def apply(d: int, label: str) -> None:
        succ = dict(mc.successors(mc_cfg, states[d]))
        if label not in succ:
            raise ConformanceError(
                f"micro-action {label} not enabled at model state "
                f"{states[d]} (artifact {d}); enabled: {sorted(succ)}")
        states[d] = succ[label]
        if not mc.inv_single_writer(mc_cfg, states[d]):
            raise ConformanceError(
                f"SWMR violated at model state {states[d]}")

    for _, a, d, is_write in _actions(trace):
        if states[d][1][a] == mc.I:
            apply(d, f"Fetch({a})")
        if is_write:
            if states[d][1][a] == mc.S:
                apply(d, f"Upgrade({a})")
            apply(d, f"Write({a})")
        else:
            apply(d, f"Read({a})")

    # Abstraction map: the spec's Write leaves the committed writer in
    # M; the executable protocol downgrades it to S on commit (SS5.3).
    # E never persists (Upgrade is always immediately followed by
    # Write in the decomposition above).
    state = np.empty((n, m), np.int32)
    version = np.empty(m, np.int32)
    sync = np.empty((n, m), np.int32)
    for d in range(m):
        ver, sts, _steps, syn = states[d]
        version[d] = ver
        for a in range(n):
            if sts[a] == _E:
                raise ConformanceError(
                    f"Exclusive state persisted at artifact {d}")
            state[a, d] = _S if sts[a] in (_S, _M) else _I
            sync[a, d] = syn[a]
    return state, version, sync


# ---------------------------------------------------------------------------
# The four-way check.


def _expect(label: str, got, want, context: str) -> None:
    if isinstance(got, np.ndarray) or isinstance(want, np.ndarray):
        equal = np.array_equal(np.asarray(got), np.asarray(want))
    else:
        equal = got == want
    if not equal:
        raise ConformanceError(
            f"{context}: {label} mismatch\n  got:  {got}\n  want: {want}")


def check_trace(cfg: acs.ACSConfig, trace: Trace, *,
                name: str = "trace", context: str | None = None
                ) -> DiffReport:
    """Replay a *given* action trace through every implementation and
    assert bit-exact agreement.

    This is the trace-level core of :func:`differential_check`, exposed
    so traces captured from the **live coherence service**
    (``repro.service.trace``) replay through the identical four-way
    harness - the trace need not come from the engine's PRNG schedule.
    ``cfg.n_steps`` must equal ``trace.acts.shape[0]``.  Returns the
    agreed-upon :class:`DiffReport`; raises :class:`ConformanceError`
    on any divergence.
    """
    if cfg.strategy not in DIFFERENTIAL_STRATEGIES:
        raise ValueError(
            f"differential harness covers "
            f"{[acs.STRATEGY_NAMES[s] for s in DIFFERENTIAL_STRATEGIES]},"
            f" got {acs.STRATEGY_NAMES[cfg.strategy]}")
    if cfg.max_stale_steps > 0:
        raise ValueError("K-staleness revalidation is scan-path only; "
                         "run the differential check with "
                         "max_stale_steps=0")
    if trace.acts.shape != (cfg.n_steps, cfg.n_agents):
        raise ValueError(
            f"trace shape {trace.acts.shape} does not match config "
            f"({cfg.n_steps} steps x {cfg.n_agents} agents)")
    ctx = context or f"trace {name!r}"

    led_vec, st_vec, ver_vec, sync_vec = replay_vectorized(cfg, trace)
    led_pro, st_pro, ver_pro, sync_pro = replay_protocol(cfg, trace)
    led_pal, st_pal, ver_pal, sync_pal = replay_pallas(cfg, trace)

    for field in dataclasses.fields(Ledger):
        _expect(f"ledger.{field.name} (protocol vs vectorized)",
                getattr(led_pro, field.name),
                getattr(led_vec, field.name), ctx)
        _expect(f"ledger.{field.name} (pallas vs vectorized)",
                getattr(led_pal, field.name),
                getattr(led_vec, field.name), ctx)
    _expect("state (protocol vs vectorized)", st_pro, st_vec, ctx)
    _expect("state (pallas vs vectorized)", st_pal, st_vec, ctx)
    _expect("version (protocol vs vectorized)", ver_pro, ver_vec, ctx)
    _expect("version (pallas vs vectorized)", ver_pal, ver_vec, ctx)
    _expect("last_sync (pallas vs vectorized)", sync_pal, sync_vec, ctx)
    # protocol caches only materialize entries on first touch and keep
    # the committed version on them; compare where an entry is valid.
    valid = st_vec != _I
    _expect("last_sync on valid entries (protocol vs vectorized)",
            sync_pro[valid], sync_vec[valid], ctx)

    implementations = ["protocol", "vectorized", "pallas"]
    if cfg.strategy == acs.LAZY:
        st_mc, ver_mc, sync_mc = replay_model_check(cfg, trace)
        _expect("state (model-check vs vectorized)", st_mc, st_vec, ctx)
        _expect("version (model-check vs vectorized)", ver_mc, ver_vec,
                ctx)
        _expect("last_sync (model-check vs vectorized)", sync_mc,
                sync_vec, ctx)
        implementations.append("model_check")

    return DiffReport(
        workload=name,
        strategy=acs.STRATEGY_NAMES[cfg.strategy],
        trace=trace, ledger=led_vec, state=st_vec, version=ver_vec,
        last_sync=sync_vec, implementations=tuple(implementations))


# ---------------------------------------------------------------------------
# Cross-shard conformance leg: the sharded authority plane partitions
# the directory BY ARTIFACT, so every shard's committed history is the
# global history restricted to its own columns.


def shard_subtrace(trace: Trace, artifact_shards, shard: int):
    """Project a global trace onto one authority shard.

    Returns ``(sub_trace, cols)`` where ``cols`` are the global
    artifact indices owned by ``shard`` and ``sub_trace`` keeps only
    the actions on those artifacts (artifact indices remapped to the
    shard-local ``0..len(cols)-1`` range, steps with no action on this
    shard dropped).  Because exclusivity, versions and sync are all
    per-artifact, this projection is exactly the history the shard's
    local authority executed.
    """
    shards = np.asarray(artifact_shards, np.int32)
    cols = np.flatnonzero(shards == shard)
    lut = np.zeros(shards.size, np.int32)
    lut[cols] = np.arange(cols.size, dtype=np.int32)
    sel = trace.acts & np.isin(trace.arts, cols)
    keep = np.flatnonzero(sel.any(axis=1))
    acts = sel[keep]
    arts = np.where(acts, lut[trace.arts[keep]], 0).astype(np.int32)
    writes = trace.writes[keep] & acts
    write_chunks = None
    if trace.write_chunks is not None:
        write_chunks = trace.write_chunks[keep] & writes[:, :, None]
    return Trace(acts=acts, arts=arts, writes=writes,
                 write_chunks=write_chunks), cols


def check_sharded_trace(cfg: acs.ACSConfig, trace: Trace,
                        artifact_shards, *, name: str = "sharded",
                        context: str | None = None) -> DiffReport:
    """Conformance harness for the sharded authority plane.

    Two legs, both bit-exact:

    1. **Global serializability** - the interleaved per-shard batch
       stream replays through the full four-way harness
       (:func:`check_trace`) as if ONE authority had committed it.
    2. **Cross-shard decomposition** - each shard's projected
       sub-trace (:func:`shard_subtrace`) replays through the
       vectorized ACS *independently*; its directory columns, versions
       and last_sync must equal the global replay restricted to that
       shard's artifacts, and the per-shard ledgers must SUM to the
       global ledger.  Together these prove sharding the authority by
       artifact changed nothing observable: SWMR, monotonic versions
       and the token charges survive the partition.
    """
    shards = np.asarray(artifact_shards, np.int32)
    if shards.shape != (cfg.n_artifacts,):
        raise ValueError(
            f"artifact_shards has shape {shards.shape}; expected one "
            f"shard id per artifact ({cfg.n_artifacts},)")
    ctx = context or f"sharded trace {name!r}"
    report = check_trace(cfg, trace, name=name, context=ctx)
    sums = {f.name: 0 for f in dataclasses.fields(Ledger)}
    for shard in range(int(shards.max()) + 1 if shards.size else 1):
        sub, cols = shard_subtrace(trace, shards, shard)
        if cols.size == 0:
            continue
        sub_cfg = dataclasses.replace(
            cfg, n_artifacts=int(cols.size),
            n_steps=max(sub.acts.shape[0], 1))
        led, st, ver, sync = replay_vectorized(sub_cfg, sub)
        for f in sums:
            sums[f] += getattr(led, f)
        sctx = f"{ctx} [shard {shard}]"
        _expect("state (shard-local vs global columns)", st,
                report.state[:, cols], sctx)
        _expect("version (shard-local vs global columns)", ver,
                report.version[cols], sctx)
        _expect("last_sync (shard-local vs global columns)", sync,
                report.last_sync[:, cols], sctx)
    for f in sums:
        _expect(f"ledger.{f} (sum over shards vs global)", sums[f],
                getattr(report.ledger, f), ctx)
    return report


# ---------------------------------------------------------------------------
# Content plane: byte-exact differential harness (chunk-granular delta
# coherence, ``repro.content``).


@dataclasses.dataclass(frozen=True)
class ByteLedger:
    """Bytes-on-wire ledger of the chunk content plane (exact ints)."""

    delta_bytes: int        # shipped under delta coherence
    full_bytes: int         # what whole-artifact lazy ships, same fills
    n_chunks_fetched: int

    @property
    def savings_vs_full(self) -> float:
        return 1.0 - self.delta_bytes / max(self.full_bytes, 1)


@dataclasses.dataclass(frozen=True)
class FillEvent:
    """One coherence fill as the content plane served it."""

    step: int
    agent: int
    artifact: int
    fetched: np.ndarray      # (C,) bool chunks shipped
    sync_before: np.ndarray  # (C,) reader chunk vector before the fill
    dirty: np.ndarray        # (C,) dirty bitmap at fill time
    delta_inc: int           # bytes this fill shipped
    full_inc: int            # bytes whole-artifact lazy would ship


@dataclasses.dataclass(frozen=True)
class ContentReport:
    """Agreed-upon content-plane results (post-assertion)."""

    workload: str
    strategy: str
    trace: Trace
    ledger: ByteLedger
    chunk_version: np.ndarray  # (m, C)
    chunk_sync: np.ndarray     # (n, m, C)
    chunk_dirty: np.ndarray    # (m, C)
    fills: tuple               # FillEvent per coherence fill
    implementations: tuple


def _content_cfg_check(cfg: acs.ACSConfig) -> None:
    if not acs.content_enabled(cfg):
        raise ValueError("content harness needs cfg.chunk_tokens > 0")
    if cfg.strategy not in acs.CONTENT_STRATEGIES:
        raise ValueError(
            f"content plane covers "
            f"{[acs.STRATEGY_NAMES[s] for s in acs.CONTENT_STRATEGIES]},"
            f" got {acs.STRATEGY_NAMES[cfg.strategy]}")
    if cfg.max_stale_steps > 0:
        raise ValueError("content harness runs with max_stale_steps=0")


def replay_content_vectorized(cfg: acs.ACSConfig, trace: Trace):
    """Eager replay of the content plane through the production
    ``acs._do_read`` / ``_do_write`` bodies.

    Returns ``(ByteLedger, chunk_version, chunk_sync, chunk_dirty,
    fills)`` where ``fills`` carries per-fill byte increments and the
    dirty bitmap snapshot (the delta-subset-of-dirty property surface).
    """
    _content_cfg_check(cfg)
    arrays = acs.init_arrays(cfg)
    met = acs.init_metrics()
    fills = []
    for s, a, d, is_write in _actions(trace):
        arrays = arrays._replace(
            agent_actions=arrays.agent_actions.at[a].add(1))
        ver_b = np.asarray(arrays.chunk_version[d], np.int32)
        sync_b = np.asarray(arrays.chunk_sync[a, d], np.int32)
        dirty_b = np.asarray(arrays.chunk_dirty[d], np.int32)
        before = (int(met.n_fetches), int(met.delta_bytes),
                  int(met.full_bytes))
        if is_write:
            arrays, met = acs._do_write(
                cfg, arrays, met, a, d,
                wchunks=jnp.asarray(trace.write_chunks[s, a]))
        else:
            arrays, met = acs._do_read(cfg, arrays, met, a, d)
        if int(met.n_fetches) > before[0]:
            fills.append(FillEvent(
                step=s, agent=a, artifact=d,
                fetched=ver_b > sync_b,
                sync_before=sync_b, dirty=dirty_b.astype(bool),
                delta_inc=int(met.delta_bytes) - before[1],
                full_inc=int(met.full_bytes) - before[2]))
    ledger = ByteLedger(
        delta_bytes=int(met.delta_bytes),
        full_bytes=int(met.full_bytes),
        n_chunks_fetched=int(met.n_chunks_fetched))
    return (ledger, np.asarray(arrays.chunk_version, np.int32),
            np.asarray(arrays.chunk_sync, np.int32),
            np.asarray(arrays.chunk_dirty, np.int32), tuple(fills))


def replay_content_pallas(cfg: acs.ACSConfig, trace: Trace):
    """Replay through the Pallas route: ``mesi_tick_pallas`` (per-agent
    miss output) chased by ``chunk_tick_pallas``, batch of one sim."""
    from repro.kernels.chunk_diff import chunk_tick_pallas
    _content_cfg_check(cfg)
    n, m = cfg.n_agents, cfg.n_artifacts
    C = acs.content_chunks(cfg)
    state = jnp.full((1, n, m), _I, jnp.int32)
    version = jnp.ones((1, m), jnp.int32)
    sync = jnp.zeros((1, n, m), jnp.int32)
    reads = jnp.zeros((1, n, m), jnp.int32)
    cv = jnp.ones((1, m, C), jnp.int32)
    cs = jnp.zeros((1, n, m, C), jnp.int32)
    dirty = jnp.zeros((1, m, C), jnp.int32)
    counters = np.zeros(4, np.int64)
    for s in range(trace.acts.shape[0]):
        a = jnp.asarray(trace.acts[s][None], jnp.int32)
        d = jnp.asarray(trace.arts[s][None], jnp.int32)
        w = jnp.asarray(trace.writes[s][None], jnp.int32)
        state, version, sync, reads, _, miss = mesi_tick_pallas(
            state, version, sync, reads, a, d, w,
            artifact_tokens=cfg.artifact_tokens,
            access_k=(cfg.access_k
                      if cfg.strategy == acs.ACCESS_COUNT else 0),
            signal_tokens=acs.SIGNAL_TOKENS)
        cv, cs, dirty, _, ccnt = chunk_tick_pallas(
            cv, cs, dirty, miss, a * w, d,
            jnp.asarray(trace.write_chunks[s][None], jnp.int32),
            artifact_tokens=cfg.artifact_tokens,
            chunk_tokens=cfg.chunk_tokens,
            signal_tokens=acs.SIGNAL_TOKENS)
        counters += np.asarray(ccnt[0], np.int64)
    ledger = ByteLedger(delta_bytes=int(counters[0]),
                        full_bytes=int(counters[1]),
                        n_chunks_fetched=int(counters[2]))
    return (ledger, np.asarray(cv[0], np.int32),
            np.asarray(cs[0], np.int32), np.asarray(dirty[0], np.int32))


def replay_content_store(cfg: acs.ACSConfig, trace: Trace, fills):
    """Message-level content leg with REAL payloads: a content-addressed
    :class:`repro.content.ChunkStore` over the canonical
    ``ArtifactStore``, per-reader chunk caches patched by shipped
    deltas.

    ``fills`` is the serialized miss sequence (from
    :func:`replay_content_vectorized`) - this leg does not re-decide
    MESI, it *serves content* for the decided fills and proves the
    bytes the vectorized ledger charged are exactly the bytes real
    chunks occupy, and that every patched reader copy reassembles to
    the authority artifact byte-for-byte.

    Returns ``(ByteLedger, n_reassembly_checks)``.
    """
    from repro.content.chunks import (BYTES_PER_TOKEN, ChunkStore,
                                      reassemble)
    _content_cfg_check(cfg)
    n, m, C = cfg.n_agents, cfg.n_artifacts, acs.content_chunks(cfg)
    store = ArtifactStore()
    chunks = ChunkStore(store, cfg.chunk_tokens)
    for d in range(m):
        store.put(f"artifact-{d}",
                  [(d * 1009 + i) % 65521 for i in
                   range(cfg.artifact_tokens)])
        chunks.register(f"artifact-{d}")
    cv = np.ones((m, C), np.int64)
    cs = np.zeros((n, m, C), np.int64)
    reader = {}   # (a, d) -> list of chunk payloads (stale allowed)
    fill_iter = iter(fills)
    next_fill = next(fill_iter, None)
    delta_bytes = full_bytes = n_chunks_fetched = 0
    n_checks = 0
    write_counter = 0
    for s, a, d, is_write in _actions(trace):
        name = f"artifact-{d}"
        is_miss = (next_fill is not None and next_fill.step == s
                   and next_fill.agent == a)
        if is_miss:
            stale = np.flatnonzero(cv[d] > cs[a, d])
            payload = chunks.delta(name, stale)
            base = reader.get((a, d))
            if base is None:
                base = [None] * C
            for idx, chunk in payload:
                base[idx] = chunk
            reader[(a, d)] = base
            shipped = sum(len(chunk) for _, chunk in payload)
            delta_bytes += (shipped + acs.SIGNAL_TOKENS) * BYTES_PER_TOKEN
            full_bytes += (cfg.artifact_tokens
                           + acs.SIGNAL_TOKENS) * BYTES_PER_TOKEN
            n_chunks_fetched += len(stale)
            cs[a, d] = cv[d]
            got = reassemble(base)
            want = tuple(store.get(name))
            if got != want:
                raise ConformanceError(
                    f"reassembled copy of {name} at agent {a} (step {s})"
                    f" diverged from the authority artifact")
            n_checks += 1
            next_fill = next(fill_iter, None)
        if is_write:
            span = np.flatnonzero(trace.write_chunks[s, a])
            new_content = list(store.get(name))
            write_counter += 1
            ct = cfg.chunk_tokens
            for c in span:
                lo = c * ct
                hi = min(lo + ct, cfg.artifact_tokens)
                for i in range(lo, hi):
                    # unique value per commit: every spanned chunk's
                    # digest is guaranteed to move
                    new_content[i] = 100000 + write_counter
            measured = chunks.put(name, new_content)
            if not np.array_equal(np.flatnonzero(measured), span):
                raise ConformanceError(
                    f"measured content diff {np.flatnonzero(measured)} "
                    f"!= sampled span {span} (step {s}, agent {a})")
            cv[d][span] += 1
            reader[(a, d)] = [chunks.chunk(name, i) for i in range(C)]
            cs[a, d] = cv[d]
    ledger = ByteLedger(delta_bytes=delta_bytes, full_bytes=full_bytes,
                        n_chunks_fetched=n_chunks_fetched)
    return ledger, n_checks


def check_content_trace(cfg: acs.ACSConfig, trace: Trace, *,
                        name: str = "trace",
                        context: str | None = None) -> ContentReport:
    """Byte-exact differential leg of the oracle.

    Replays one (possibly service-captured) trace through the chunked
    scan path, the Pallas chunk-diff route, the real-payload chunk
    store, and the message-level whole-artifact baseline, asserting:

      * bit-identical byte ledgers and chunk state across scan and
        Pallas backends;
      * the real-payload leg charges exactly the same bytes and every
        patched reader copy reassembles to the authority artifact;
      * the whole-artifact baseline (the message-level protocol's
        token ledger, in bytes) equals the ``full_bytes`` column - so
        ``delta <= full`` is measured against the actual baseline;
      * ``delta_inc <= full_inc`` for every individual fill.
    """
    from repro.content.chunks import BYTES_PER_TOKEN
    _content_cfg_check(cfg)
    if trace.write_chunks is None:
        raise ValueError("content check needs trace.write_chunks")
    ctx = context or f"content trace {name!r}"

    led_vec, cv_vec, cs_vec, dirty_vec, fills = \
        replay_content_vectorized(cfg, trace)
    led_pal, cv_pal, cs_pal, dirty_pal = replay_content_pallas(cfg, trace)

    for field in dataclasses.fields(ByteLedger):
        _expect(f"byte ledger.{field.name} (pallas vs vectorized)",
                getattr(led_pal, field.name),
                getattr(led_vec, field.name), ctx)
    _expect("chunk_version (pallas vs vectorized)", cv_pal, cv_vec, ctx)
    _expect("chunk_sync (pallas vs vectorized)", cs_pal, cs_vec, ctx)
    _expect("chunk_dirty (pallas vs vectorized)", dirty_pal, dirty_vec,
            ctx)

    led_store, n_checks = replay_content_store(cfg, trace, fills)
    for field in dataclasses.fields(ByteLedger):
        _expect(f"byte ledger.{field.name} (chunk store vs vectorized)",
                getattr(led_store, field.name),
                getattr(led_vec, field.name), ctx)

    # whole-artifact baseline: the message-level protocol's fetch
    # ledger, converted to wire bytes, IS the full_bytes column.
    led_pro, _, _, _ = replay_protocol(cfg, trace)
    _expect("full_bytes vs whole-artifact protocol fetch bytes",
            led_vec.full_bytes,
            led_pro.fetch_tokens * BYTES_PER_TOKEN, ctx)

    for f in fills:
        if f.delta_inc > f.full_inc:
            raise ConformanceError(
                f"{ctx}: fill (step {f.step}, agent {f.agent}, artifact"
                f" {f.artifact}) shipped {f.delta_inc} delta bytes > "
                f"{f.full_inc} whole-artifact bytes")
    if led_vec.delta_bytes > led_vec.full_bytes:
        raise ConformanceError(
            f"{ctx}: total delta {led_vec.delta_bytes} > full "
            f"{led_vec.full_bytes}")

    return ContentReport(
        workload=name, strategy=acs.STRATEGY_NAMES[cfg.strategy],
        trace=trace, ledger=led_vec, chunk_version=cv_vec,
        chunk_sync=cs_vec, chunk_dirty=dirty_vec, fills=fills,
        implementations=("vectorized", "pallas", "chunk_store",
                         "protocol_baseline"))


def content_differential_check(workload, run: int = 0) -> ContentReport:
    """Sample one engine-schedule trace of a chunked workload and run
    the byte-exact harness, then close the loop against the fused
    tensor path's own byte ledger."""
    cfg = workload.acs
    rates = workload.rates() if hasattr(workload, "rates") else None
    locality = getattr(workload, "write_locality", cfg.write_locality)
    key = episode_key(workload.seed, run)
    trace = sample_trace(cfg, key, rates, locality=locality)
    ctx = f"content workload {workload.name!r} run {run}"
    report = check_content_trace(cfg, trace, name=workload.name,
                                 context=ctx)
    met = acs.run_episode(cfg, key, rates=rates, locality=locality)
    _expect("run_episode delta_bytes vs replay",
            int(met.delta_bytes), report.ledger.delta_bytes, ctx)
    _expect("run_episode full_bytes vs replay",
            int(met.full_bytes), report.ledger.full_bytes, ctx)
    _expect("run_episode n_chunks_fetched vs replay",
            int(met.n_chunks_fetched), report.ledger.n_chunks_fetched,
            ctx)
    return dataclasses.replace(
        report,
        implementations=report.implementations + ("run_episode",))


def differential_check(workload, run: int = 0,
                       strategies=None) -> DiffReport:
    """Replay one sampled trace of ``workload`` through every
    implementation and assert bit-exact agreement.

    ``workload``: a ``repro.sim.workloads.Workload`` (heterogeneous
    rates) or a ``ScenarioConfig``-like object with ``.acs`` and
    ``.seed`` (scalar rates).  ``run`` selects the engine grid cell the
    trace reproduces.  Returns the agreed-upon :class:`DiffReport`;
    raises :class:`ConformanceError` on any divergence.
    """
    cfg = workload.acs
    rates = workload.rates() if hasattr(workload, "rates") else None
    key = episode_key(workload.seed, run)
    trace = sample_trace(cfg, key, rates)
    ctx = f"workload {workload.name!r} run {run}"
    report = check_trace(cfg, trace, name=workload.name, context=ctx)
    led_vec = report.ledger

    # Close the loop: the fused tensor path executes this very trace.
    met = acs.run_episode(cfg, key, rates=rates)
    _expect("run_episode fetch_tokens vs replay",
            int(met.fetch_tokens), led_vec.fetch_tokens, ctx)
    _expect("run_episode signal_tokens vs replay",
            int(met.signal_tokens), led_vec.signal_tokens, ctx)
    _expect("run_episode push_tokens vs replay",
            int(met.push_tokens), led_vec.push_tokens, ctx)
    _expect("run_episode n_hits vs replay",
            int(met.n_hits), led_vec.n_hits, ctx)
    return dataclasses.replace(
        report,
        implementations=report.implementations + ("run_episode",))
