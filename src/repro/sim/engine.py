"""Tick-based discrete-event simulation engine (paper SS8).

The engine runs the vectorized ACS state machine (``repro.core.acs``)
over S steps via ``lax.scan`` and over independent seeded runs via
``vmap``; an optional outer ``vmap`` sweeps whole scenario grids in one
XLA program (thousands of concurrent simulated deployments - the
fleet-scale evaluation mode).  Per-tick MESI transitions can optionally
be routed through the Pallas kernel (``repro.kernels.mesi_transition``)
for the batched path.

Population statistics (mean, population std) are reported exactly as the
paper does (10 runs, sigma over the population).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acs
from repro.sim.scenarios import ScenarioConfig


@dataclasses.dataclass(frozen=True)
class RunStats:
    """Per-configuration population statistics over n_runs."""

    name: str
    strategy: str
    n_runs: int
    total_tokens_mean: float
    total_tokens_std: float
    sync_tokens_mean: float
    sync_tokens_std: float
    fetch_tokens_mean: float
    signal_tokens_mean: float
    push_tokens_mean: float
    broadcast_tokens_mean: float
    cache_hit_rate_mean: float
    cache_hit_rate_std: float
    n_fetches_mean: float
    n_writes_mean: float
    n_reads_mean: float
    max_staleness_max: int
    max_version_lag_max: int

    def savings_vs(self, baseline: "RunStats") -> float:
        return 1.0 - self.total_tokens_mean / baseline.total_tokens_mean

    def savings_std_vs(self, baseline: "RunStats",
                       per_run_tokens: np.ndarray,
                       baseline_mean: Optional[float] = None) -> float:
        b = baseline.total_tokens_mean if baseline_mean is None \
            else baseline_mean
        return float(np.std(1.0 - per_run_tokens / b))


@dataclasses.dataclass(frozen=True)
class RunResult:
    stats: RunStats
    per_run_total_tokens: np.ndarray  # (n_runs,)
    per_run_chr: np.ndarray


def _episode_metrics(cfg: acs.ACSConfig, key: jax.Array) -> dict:
    met = acs.run_episode(cfg, key)
    return {
        "total_tokens": met.total_tokens,
        "sync_tokens": met.sync_tokens,
        "fetch_tokens": met.fetch_tokens,
        "signal_tokens": met.signal_tokens,
        "push_tokens": met.push_tokens,
        "broadcast_tokens": met.broadcast_tokens,
        "cache_hit_rate": met.cache_hit_rate,
        "n_fetches": met.n_fetches,
        "n_writes": met.n_writes,
        "n_reads": met.n_reads,
        "max_staleness": met.max_staleness,
        "max_version_lag": met.max_version_lag,
    }


def run_scenario(scn: ScenarioConfig) -> RunResult:
    """Run ``scn.n_runs`` independent seeded episodes, vmapped."""
    base = jax.random.PRNGKey(scn.seed)
    keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
        jnp.arange(scn.n_runs))
    fn = jax.jit(jax.vmap(lambda k: _episode_metrics(scn.acs, k)))
    out = jax.device_get(fn(keys))
    total = np.asarray(out["total_tokens"], dtype=np.float64)
    chr_ = np.asarray(out["cache_hit_rate"], dtype=np.float64)
    stats = RunStats(
        name=scn.name,
        strategy=acs.STRATEGY_NAMES[scn.acs.strategy],
        n_runs=scn.n_runs,
        total_tokens_mean=float(total.mean()),
        total_tokens_std=float(total.std()),
        sync_tokens_mean=float(np.mean(out["sync_tokens"])),
        sync_tokens_std=float(np.std(np.asarray(
            out["sync_tokens"], dtype=np.float64))),
        fetch_tokens_mean=float(np.mean(out["fetch_tokens"])),
        signal_tokens_mean=float(np.mean(out["signal_tokens"])),
        push_tokens_mean=float(np.mean(out["push_tokens"])),
        broadcast_tokens_mean=float(np.mean(out["broadcast_tokens"])),
        cache_hit_rate_mean=float(chr_.mean()),
        cache_hit_rate_std=float(chr_.std()),
        n_fetches_mean=float(np.mean(out["n_fetches"])),
        n_writes_mean=float(np.mean(out["n_writes"])),
        n_reads_mean=float(np.mean(out["n_reads"])),
        max_staleness_max=int(np.max(out["max_staleness"])),
        max_version_lag_max=int(np.max(out["max_version_lag"])),
    )
    return RunResult(stats=stats, per_run_total_tokens=total,
                     per_run_chr=chr_)


@dataclasses.dataclass(frozen=True)
class Comparison:
    """Coherent strategy vs broadcast baseline for one scenario."""

    scenario: str
    volatility: float
    strategy: str
    broadcast: RunStats
    coherent: RunStats
    savings_mean: float
    savings_std: float
    crr: float           # Coherence Reduction Ratio (SS8.2)
    chr_mean: float
    chr_std: float


def compare(scn: ScenarioConfig, strategy_code: Optional[int] = None
            ) -> Comparison:
    """Run broadcast + coherent variants of one scenario."""
    coh_scn = scn if strategy_code is None else scn.with_strategy(
        strategy_code)
    bc = run_scenario(scn.with_strategy(acs.BROADCAST))
    co = run_scenario(coh_scn)
    savings_runs = 1.0 - co.per_run_total_tokens / bc.stats.total_tokens_mean
    return Comparison(
        scenario=scn.name,
        volatility=scn.acs.volatility,
        strategy=co.stats.strategy,
        broadcast=bc.stats,
        coherent=co.stats,
        savings_mean=float(savings_runs.mean()),
        savings_std=float(savings_runs.std()),
        crr=co.stats.total_tokens_mean / bc.stats.total_tokens_mean,
        chr_mean=co.stats.cache_hit_rate_mean,
        chr_std=co.stats.cache_hit_rate_std,
    )


def sweep_volatility(base_scn: ScenarioConfig, volatilities,
                     n_runs: Optional[int] = None) -> list[Comparison]:
    """Vectorized V-sweep: one jitted program per strategy, vmapped over
    (volatility x run).  Volatility is a *traced* Bernoulli parameter, so
    a single compilation covers the whole sweep - the fleet-scale path."""
    import dataclasses as dc
    runs = n_runs or base_scn.n_runs
    out = []
    for v in volatilities:
        scn = dc.replace(
            base_scn, acs=dc.replace(base_scn.acs, volatility=float(v)),
            n_runs=runs,
            seed=base_scn.seed + int(round(float(v) * 1000)))
        out.append(compare(scn))
    return out
