"""Tick-based discrete-event simulation engine (paper SS8).

Fleet-scale sweep architecture: an entire ``(variant x volatility x
run)`` evaluation grid compiles **once** and runs as **one** batched XLA
program - and on a multi-device host that single program is
**device-sharded** with ``jax.shard_map`` over a 1-D mesh
(``repro.launch.mesh.make_sweep_mesh``), so an 8-device host executes 8
grid slices of the same compiled program in parallel.  Four mechanisms
make that possible:

  1. **Traced sweep axes.**  ``volatility`` and ``p_act`` (and the PRNG
     key, as always) are traced scalars of the episode runner
     (``repro.core.acs.run_episode``), so a single compiled program
     covers every point of a volatility sweep - and the heterogeneous
     generalization (``compare_workloads``) traces whole per-agent x
     per-artifact rate matrices the same way, so one program covers an
     entire zoo of workload families.  Strategy and the
     shape-determining fields (agents, artifacts, steps) stay static -
     they select code, not data.
  2. **Module-level jit cache.**  Compiled grid programs are cached per
     static ``ACSConfig`` signature (``_static_key``), so repeated
     ``run_scenario`` / ``compare`` calls never retrace.  The cache is
     instrumented (``trace_count``) so benchmarks and tests can assert
     the one-compilation property.
  3. **Fused baseline.**  ``compare`` / ``sweep_volatility`` stack the
     broadcast baseline and the coherent variant along a leading variant
     axis *inside* the same jitted program - one launch, not two.
  4. **Device sharding with a global key schedule.**  When more than
     one local device is attached (``resolve_sweep_devices``; force
     with ``REPRO_SWEEP_DEVICES=n`` or the ``devices=`` argument), the
     grid program is wrapped in ``shard_map`` over a 1-D mesh: the
     ``runs`` axis is sharded (falling back to the ``workloads`` /
     scenario-cell axis, else padding runs - ``shard_plan``).  Episode
     keys are derived *inside* the program by ``acs.run_keys`` -
     ``fold_in`` on the **global** run index carried by a sharded
     ``run_ids`` operand, never on device-local position - so sharded
     ledgers are bit-identical to the single-device path and replayable
     through the ``repro.sim.oracle`` conformance harness.  The key
     operands are donated to the program (freshly built every call, so
     XLA may reuse their buffers for episode state).

Per-tick MESI transitions route through the Pallas kernel
(``repro.kernels.mesi_transition``) when a real TPU backend is attached
and the flattened batch is large enough to fill it; otherwise the
vectorized ``lax.scan`` path (vmapped ``acs.run_episode``) is used.
Force either with ``REPRO_SIM_TICK=pallas|scan``.  Under ``shard_map``
the kernel is invoked per device on that device's slice of the episode
batch.

Population statistics (mean, population std) are reported exactly as the
paper does (10 runs, sigma over the population).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import warnings
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # newer jax exposes shard_map at the top level
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax <= 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def _make_shard_map(fn, mesh, in_specs, out_specs):
    """shard_map with the replication check disabled, across jax's API
    drift (``check_rep`` -> ``check_vma`` -> possibly neither).  The
    check must be off where supported: the grid body is collective-free
    (episodes are independent) and older jax has no replication rule
    for ``pallas_call``, so the per-device MESI-tick kernel route would
    be rejected under a checked shard_map."""
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map_impl(fn, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise TypeError("no compatible shard_map signature found")

from repro.content.chunks import BYTES_PER_TOKEN
from repro.core import acs
from repro.core.states import MESIState
from repro.kernels.backend import interpret_default
from repro.kernels.chunk_diff import (N_CHUNK_COUNTERS,
                                      chunk_tick_pallas)
from repro.kernels.mesi_transition import (N_COUNTERS, episode_step_keys,
                                           mesi_tick_pallas)
from repro.launch.mesh import make_sweep_mesh
from repro.sim.scenarios import ScenarioConfig

# ---------------------------------------------------------------------------
# Compilation accounting.  ``_note_trace`` runs as a Python side effect at
# *trace* time only, so the counter increments once per compiled program
# (and once more per shape-driven retrace) - never per execution.

_TRACE_COUNT = 0


def _note_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def trace_count() -> int:
    """Number of sweep/episode program compilations since last reset."""
    return _TRACE_COUNT


def reset_trace_count() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT = 0


class TraceCounter:
    """Compilations observed since a fixed starting point (see
    ``trace_counter``)."""

    def __init__(self, start: int) -> None:
        self._start = start

    @property
    def count(self) -> int:
        return _TRACE_COUNT - self._start


@contextlib.contextmanager
def trace_counter(clear_cache: bool = True):
    """Scoped compilation accounting.

    ``trace_count`` is process-global: a bare ``reset_trace_count()`` in
    one test module stomps the accounting every other module sees, so
    recompile-guard assertions become import-order dependent.  This
    context manager yields a ``TraceCounter`` whose ``.count`` is the
    number of compilations *inside the with-block only* - no reset, no
    cross-module leak.  ``clear_cache=True`` (default) also drops the
    jit caches on entry so the block starts cold.
    """
    if clear_cache:
        clear_compile_cache()
    yield TraceCounter(_TRACE_COUNT)


# ---------------------------------------------------------------------------
# Static signature + jit cache.

#: ACSConfig fields baked into compiled code.  ``volatility``,
#: ``p_act`` and ``write_locality`` are deliberately absent: they are
#: traced sweep axes.  ``chunk_tokens`` is static (it sets the chunk
#: axis shape); one compiled program covers every locality x
#: volatility x family point of a given chunk geometry.
_STATIC_FIELDS = ("n_agents", "n_artifacts", "artifact_tokens", "n_steps",
                  "strategy", "ttl_events", "access_k", "max_stale_steps",
                  "chunk_tokens")

_GRID_CACHE: dict = {}

#: Minimum flattened episode batch before the Pallas tick path pays off
#: on TPU (below this the grid underfills the VPU slabs).
PALLAS_MIN_BATCH = 256

_PALLAS_STRATEGIES = (acs.LAZY, acs.EAGER, acs.ACCESS_COUNT)

_I = int(MESIState.I)


def _static_key(cfg: acs.ACSConfig) -> tuple:
    return tuple(getattr(cfg, f) for f in _STATIC_FIELDS)


def clear_compile_cache() -> None:
    """Drop cached grid programs (benchmarks measuring cold compiles)."""
    _GRID_CACHE.clear()
    jax.clear_caches()


def _pallas_tick_supported(cfg: acs.ACSConfig) -> bool:
    """The batched MESI kernel implements the invalidation strategies
    (lazy / eager / access-count) without K-staleness enforcement;
    broadcast and TTL are bulk-inject paths with no per-agent kernel."""
    return cfg.strategy in _PALLAS_STRATEGIES and cfg.max_stale_steps == 0


def resolve_tick_backend(cfg: acs.ACSConfig, batch: int) -> str:
    """'pallas' | 'scan' for a grid of ``batch`` flattened episodes."""
    forced = os.environ.get("REPRO_SIM_TICK", "auto")
    if forced == "scan":
        return "scan"
    if forced == "pallas":
        return "pallas" if _pallas_tick_supported(cfg) else "scan"
    if (not interpret_default() and _pallas_tick_supported(cfg)
            and batch >= PALLAS_MIN_BATCH):
        return "pallas"
    return "scan"


# ---------------------------------------------------------------------------
# Device sharding.  Sweep grids are embarrassingly parallel along their
# batch axes; ``shard_plan`` picks which axis a given grid shards over.


def resolve_sweep_devices() -> int:
    """Device count the sweep engine shards over (1 = unsharded).

    ``REPRO_SWEEP_DEVICES=n`` forces a count (capped at the local
    device count; ``1`` disables sharding); default is every local
    device.  On a single-device host this is 1 and the engine takes the
    plain-jit path - byte-for-byte the pre-sharding behavior.
    """
    forced = os.environ.get("REPRO_SWEEP_DEVICES", "auto")
    n_local = jax.local_device_count()
    if forced != "auto":
        try:
            n = int(forced)
        except ValueError:
            raise ValueError(
                f"REPRO_SWEEP_DEVICES must be an integer or 'auto', "
                f"got {forced!r}") from None
        return max(1, min(n, n_local))
    return n_local


class ShardPlan(NamedTuple):
    """How one grid call maps onto the device mesh.

    ``axis`` is ``None`` (unsharded single-device program), ``"runs"``
    (run axis sharded) or ``"workloads"`` (scenario/workload cell axis
    sharded).  ``pad_runs`` is the padded run-axis length the program
    sees; padding runs is the always-available fallback because run
    keys are derived from **global** run indices, so extra trailing
    runs are real (discarded) episodes, not perturbed ones.
    """

    devices: int
    axis: Optional[str]
    pad_runs: int


def shard_plan(n_cells: int, n_runs: int,
               devices: Optional[int] = None) -> ShardPlan:
    """Pick the mesh axis for an ``(n_cells x n_runs)`` grid.

    Preference order: shard ``runs`` when it divides the device count,
    else shard the cell (``workloads``) axis when that divides, else
    pad ``runs`` up to the next multiple and shard it (the padded tail
    is sliced off on the host).  ``devices=None`` resolves via
    ``resolve_sweep_devices``.
    """
    if devices is None:
        devices = resolve_sweep_devices()
    devices = max(1, min(devices, jax.local_device_count()))
    if devices <= 1:
        return ShardPlan(1, None, n_runs)
    if n_runs % devices == 0:
        return ShardPlan(devices, "runs", n_runs)
    if n_cells % devices == 0:
        return ShardPlan(devices, "workloads", n_runs)
    pad = -n_runs % devices
    return ShardPlan(devices, "runs", n_runs + pad)


def _shard_wrap(run_grid, plan: ShardPlan, n_cell_operands: int,
                n_key_operands: int = 2):
    """Wrap a grid program per the plan and jit it.

    Operand convention: ``n_cell_operands`` leading operands carry the
    cell axis (volatilities / rate matrices / base keys), then
    ``run_ids`` last.  Outputs are ``(variant, cell, run)`` stacks.
    The trailing ``n_key_operands`` operands (base keys + run ids) are
    donated - they are rebuilt host-side on every call.
    """
    n_args = n_cell_operands + 1
    donate = tuple(range(n_args - n_key_operands, n_args))
    if plan.axis is None:
        return jax.jit(run_grid, donate_argnums=donate)
    mesh = make_sweep_mesh(plan.devices, plan.axis)
    if plan.axis == "runs":
        in_specs = (P(),) * n_cell_operands + (P("runs"),)
        out_specs = P(None, None, "runs")
    else:
        in_specs = (P("workloads"),) * n_cell_operands + (P(),)
        out_specs = P(None, "workloads", None)
    return jax.jit(
        _make_shard_map(run_grid, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs),
        donate_argnums=donate)


def _call_grid(fn, *args) -> dict:
    """Execute a compiled grid program and gather to host.

    The donated key operands rarely alias an output buffer on CPU
    (dtype/shape mismatch), and XLA warns about every unusable
    donation at compile time; that warning is noise here - donation is
    an upper bound the backend may use, not a promise - so it is
    silenced for the call.
    """
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return jax.device_get(fn(*args))


# ---------------------------------------------------------------------------
# Result containers (unchanged public shape).


@dataclasses.dataclass(frozen=True)
class RunStats:
    """Per-configuration population statistics over n_runs.

    ``max_staleness_max`` / ``max_version_lag_max`` are ``-1`` when the
    episodes ran on the Pallas tick path, which does not track staleness
    diagnostics (use ``tick_backend="scan"`` to audit them).
    """

    name: str
    strategy: str
    n_runs: int
    total_tokens_mean: float
    total_tokens_std: float
    sync_tokens_mean: float
    sync_tokens_std: float
    fetch_tokens_mean: float
    signal_tokens_mean: float
    push_tokens_mean: float
    broadcast_tokens_mean: float
    cache_hit_rate_mean: float
    cache_hit_rate_std: float
    n_fetches_mean: float
    n_writes_mean: float
    n_reads_mean: float
    max_staleness_max: int
    max_version_lag_max: int
    #: worst staleness a served cache hit carried (post-revalidation);
    #: ``-1`` on the Pallas tick path (not tracked there).
    max_consumed_staleness_max: int = -1
    #: content-plane bytes-on-wire (``-1`` when ``chunk_tokens == 0``):
    #: delta = what chunk coherence shipped, full = what whole-artifact
    #: lazy would ship for the same miss sequence.
    delta_bytes_mean: float = -1.0
    full_bytes_mean: float = -1.0
    n_chunks_fetched_mean: float = -1.0

    def savings_vs(self, baseline: "RunStats") -> float:
        return 1.0 - self.total_tokens_mean / baseline.total_tokens_mean

    def savings_std_vs(self, baseline: "RunStats",
                       per_run_tokens: np.ndarray,
                       baseline_mean: Optional[float] = None) -> float:
        b = baseline.total_tokens_mean if baseline_mean is None \
            else baseline_mean
        return float(np.std(1.0 - per_run_tokens / b))


@dataclasses.dataclass(frozen=True)
class RunResult:
    stats: RunStats
    per_run_total_tokens: np.ndarray  # (n_runs,)
    per_run_chr: np.ndarray


@dataclasses.dataclass(frozen=True)
class Comparison:
    """Coherent strategy vs broadcast baseline for one scenario."""

    scenario: str
    volatility: float
    strategy: str
    broadcast: RunStats
    coherent: RunStats
    savings_mean: float
    savings_std: float
    crr: float           # Coherence Reduction Ratio (SS8.2)
    chr_mean: float
    chr_std: float


# ---------------------------------------------------------------------------
# Episode programs.


def _episode_metrics(cfg: acs.ACSConfig, key: jax.Array,
                     volatility=None, p_act=None, rates=None,
                     locality=None) -> dict:
    met = acs.run_episode(cfg, key, volatility=volatility, p_act=p_act,
                          rates=rates, locality=locality)
    out = {
        "total_tokens": met.total_tokens,
        "sync_tokens": met.sync_tokens,
        "fetch_tokens": met.fetch_tokens,
        "signal_tokens": met.signal_tokens,
        "push_tokens": met.push_tokens,
        "broadcast_tokens": met.broadcast_tokens,
        "cache_hit_rate": met.cache_hit_rate,
        "n_fetches": met.n_fetches,
        "n_writes": met.n_writes,
        "n_reads": met.n_reads,
        "max_staleness": met.max_staleness,
        "max_version_lag": met.max_version_lag,
        "max_consumed_staleness": met.max_consumed_staleness,
    }
    if acs.content_enabled(cfg):
        out["delta_bytes"] = met.delta_bytes
        out["full_bytes"] = met.full_bytes
        out["n_chunks_fetched"] = met.n_chunks_fetched
    return out


def _broadcast_content_fill(cfg: acs.ACSConfig, out: dict) -> dict:
    """Analytic bytes-on-wire of the broadcast baseline (content-plane
    grids only): every step injects every artifact into every agent,
    so delta and whole-artifact accounting coincide - ``n_steps * n *
    m * (|d| + signal)`` bytes, exactly mirroring the token-ledger's
    ``broadcast_tokens`` accumulation."""
    per_ep = (cfg.n_steps * cfg.n_agents * cfg.n_artifacts
              * (cfg.artifact_tokens + acs.SIGNAL_TOKENS)
              * BYTES_PER_TOKEN)
    like = out["total_tokens"]
    out = dict(out)
    out["delta_bytes"] = jnp.full_like(like, per_ep)
    out["full_bytes"] = jnp.full_like(like, per_ep)
    out["n_chunks_fetched"] = jnp.full_like(
        like, cfg.n_steps * cfg.n_agents * cfg.n_artifacts
        * acs.content_chunks(cfg))
    return out


def _episodes_pallas(cfg: acs.ACSConfig, keys: jax.Array, vols: jax.Array,
                     p_acts: jax.Array,
                     rates: Optional[acs.RateMatrices] = None,
                     locs: Optional[jax.Array] = None) -> dict:
    """B episodes through the batched Pallas MESI tick.

    ``keys`` (B, 2) uint32, ``vols`` / ``p_acts`` (B,) traced scalars,
    ``rates`` an optional batched ``RateMatrices`` ((B, n) / (B, n, m)
    leaves; overrides the scalars - the heterogeneous workload route),
    ``locs`` the (B,) traced write-locality scalars (content plane
    only).  Returns the metrics dict of (B,) arrays.  Staleness
    diagnostics (``max_staleness`` / ``max_version_lag`` /
    ``max_consumed_staleness``) are not tracked by the kernel and
    report the ``-1`` not-tracked sentinel - this is the throughput
    path for token-traffic metrics; use the scan path when auditing
    staleness invariants.  With the content plane enabled, every MESI
    tick is chased by one ``chunk_tick_pallas`` call fed the MESI
    kernel's per-agent miss output - same serialization order, so the
    byte ledger is bit-identical to the scan path.
    """
    B = keys.shape[0]
    n, m = cfg.n_agents, cfg.n_artifacts
    content = acs.content_enabled(cfg)
    C = acs.content_chunks(cfg) if content else 0
    step_keys = episode_step_keys(keys, cfg.n_steps)  # (S, B, 2)

    def draw(k, v, p, r):
        # acs.draw_actions is the single sampling source of truth, so
        # the action streams (and hence all token counters) match the
        # scan path bit-for-bit.
        a, d, w = acs.draw_actions(k, n, m, v, p, r)
        return a.astype(jnp.int32), d, w.astype(jnp.int32)

    def body(carry, ks):
        (state, version, sync, reads, counters, n_reads, n_writes,
         cv, cs, dirty, ccounters) = carry
        if rates is None:
            a, d, w = jax.vmap(
                lambda k, v, p: draw(k, v, p, None))(ks, vols, p_acts)
        else:
            a, d, w = jax.vmap(
                lambda k, r: draw(k, None, None, r))(ks, rates)
        state, version, sync, reads, cnt, miss = mesi_tick_pallas(
            state, version, sync, reads, a, d, w,
            artifact_tokens=cfg.artifact_tokens,
            eager=cfg.strategy == acs.EAGER,
            access_k=cfg.access_k
            if cfg.strategy == acs.ACCESS_COUNT else 0,
            signal_tokens=acs.SIGNAL_TOKENS)
        counters = counters + cnt
        n_reads = n_reads + jnp.sum(a * (1 - w), axis=1)
        n_writes = n_writes + jnp.sum(a * w, axis=1)
        if content:
            wch = jax.vmap(
                lambda k, loc: acs.draw_write_chunks(k, n, C, loc)
            )(ks, locs).astype(jnp.int32)
            cv, cs, dirty, _, ccnt = chunk_tick_pallas(
                cv, cs, dirty, miss, a * w, d, wch,
                artifact_tokens=cfg.artifact_tokens,
                chunk_tokens=cfg.chunk_tokens,
                signal_tokens=acs.SIGNAL_TOKENS)
            ccounters = ccounters + ccnt
        return (state, version, sync, reads, counters,
                n_reads, n_writes, cv, cs, dirty, ccounters), None

    init = (
        jnp.full((B, n, m), _I, jnp.int32),
        jnp.ones((B, m), jnp.int32),
        jnp.zeros((B, n, m), jnp.int32),
        jnp.zeros((B, n, m), jnp.int32),
        jnp.zeros((B, N_COUNTERS), jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.ones((B, m, C), jnp.int32) if content else None,
        jnp.zeros((B, n, m, C), jnp.int32) if content else None,
        jnp.zeros((B, m, C), jnp.int32) if content else None,
        jnp.zeros((B, N_CHUNK_COUNTERS), jnp.int32) if content else None,
    )
    (_, _, _, _, counters, n_reads, n_writes, _, _, _, ccounters), _ = \
        jax.lax.scan(body, init, step_keys)

    fetch, signal, push = counters[:, 0], counters[:, 1], counters[:, 2]
    n_fetches, n_hits = counters[:, 3], counters[:, 4]
    z = jnp.zeros((B,), jnp.int32)
    untracked = jnp.full((B,), -1, jnp.int32)   # sentinel, see docstring
    denom = jnp.maximum(n_hits + n_fetches, 1)
    out = {
        "total_tokens": fetch + signal + push,
        "sync_tokens": fetch + signal,
        "fetch_tokens": fetch,
        "signal_tokens": signal,
        "push_tokens": push,
        "broadcast_tokens": z,
        "cache_hit_rate": n_hits.astype(jnp.float32) / denom,
        "n_fetches": n_fetches,
        "n_writes": n_writes,
        "n_reads": n_reads,
        "max_staleness": untracked,
        "max_version_lag": untracked,
        "max_consumed_staleness": untracked,
    }
    if content:
        out["delta_bytes"] = ccounters[:, 0]
        out["full_bytes"] = ccounters[:, 1]
        out["n_chunks_fetched"] = ccounters[:, 2]
    return out


def _grid_fn(cfg: acs.ACSConfig, include_broadcast: bool,
             tick_backend: str, plan: ShardPlan):
    """Cached (possibly device-sharded) grid program for one static
    configuration.

    Signature of the returned callable::

        fn(vols (V,), p_acts (V,), base_keys (V, 2), run_ids (R,))
            -> dict of (n_variants, V, R) arrays

    Episode keys are derived in-program as ``fold_in(base_keys[v],
    run_ids[r])`` (``acs.run_keys``).  ``run_ids`` carries global run
    indices, so when the plan shards the ``runs`` axis each device
    still derives the exact keys of the single-device schedule for its
    slice.  Variant axis: ``[broadcast, coherent]`` when
    ``include_broadcast``, else ``[coherent]`` - the baseline runs
    *inside* the same XLA program as the coherent variant (one
    compilation, one launch, every device).
    """
    if tick_backend == "pallas" and not _pallas_tick_supported(cfg):
        # The kernel only implements the invalidation strategies; a
        # forced "pallas" on TTL/broadcast/K-staleness configs would
        # silently compute lazy semantics.
        tick_backend = "scan"
    # the FULL resolved plan is part of the key: two plans over the same
    # devices/axis can still pad the run axis differently (pad_runs), and
    # a stale hit would silently run the wrong grid padding
    cache_key = (_static_key(cfg), include_broadcast, tick_backend, plan)
    fn = _GRID_CACHE.get(cache_key)
    if fn is not None:
        return fn
    content = acs.content_enabled(cfg)
    # Broadcast has no content plane (bulk injection ships everything);
    # its byte columns are filled analytically below.
    bc_cfg = dataclasses.replace(cfg, strategy=acs.BROADCAST,
                                 chunk_tokens=0)

    def scan_variant(vcfg, vols, p_acts, locs, keys):
        def cell(v, p, loc, ks):
            return jax.vmap(lambda k: _episode_metrics(
                vcfg, k, v, p, locality=loc))(ks)
        return jax.vmap(cell)(vols, p_acts, locs, keys)

    def pallas_variant(vcfg, vols, p_acts, locs, keys):
        V, R = keys.shape[0], keys.shape[1]
        out = _episodes_pallas(
            vcfg, keys.reshape(V * R, keys.shape[2]),
            jnp.repeat(vols, R), jnp.repeat(p_acts, R),
            locs=jnp.repeat(locs, R) if content else None)
        return {k: a.reshape(V, R) for k, a in out.items()}

    coherent = pallas_variant if tick_backend == "pallas" else scan_variant

    def run_grid(*args):
        if content:
            vols, p_acts, locs, base_keys, run_ids = args
        else:
            vols, p_acts, base_keys, run_ids = args
            locs = jnp.zeros_like(vols)
        _note_trace()
        keys = jax.vmap(lambda bk: acs.run_keys(bk, run_ids))(base_keys)
        outs = []
        if include_broadcast:
            # Broadcast is a bulk-inject path with no per-agent kernel;
            # it always takes the scan variant.
            bc = scan_variant(bc_cfg, vols, p_acts, locs, keys)
            if content:
                bc = _broadcast_content_fill(cfg, bc)
            outs.append(bc)
        outs.append(coherent(cfg, vols, p_acts, locs, keys))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

    fn = _shard_wrap(run_grid, plan, n_cell_operands=4 if content else 3)
    _GRID_CACHE[cache_key] = fn
    return fn


def _het_grid_fn(cfg: acs.ACSConfig, include_broadcast: bool,
                 tick_backend: str, plan: ShardPlan):
    """Cached (possibly device-sharded) grid program for heterogeneous
    (rate-matrix) workloads sharing one static configuration.

    Signature of the returned callable::

        fn(rates: RateMatrices with (W, n) / (W, n, m) leaves,
           base_keys (W, 2), run_ids (R,))
            -> dict of (n_variants, W, R) arrays

    The rate matrices are *traced* tensor axes: one compilation covers
    every workload family of the same static shape, and re-running with
    different rates (new families, perturbed skews) retraces nothing.
    Key derivation and sharding exactly as ``_grid_fn``; the
    ``workloads`` fallback shards the leading W axis of every rate
    leaf.  Variant axis exactly as ``_grid_fn``.
    """
    if tick_backend == "pallas" and not _pallas_tick_supported(cfg):
        tick_backend = "scan"
    cache_key = ("het", _static_key(cfg), include_broadcast, tick_backend,
                 plan)   # full plan: see _grid_fn (pad_runs matters)
    fn = _GRID_CACHE.get(cache_key)
    if fn is not None:
        return fn
    content = acs.content_enabled(cfg)
    bc_cfg = dataclasses.replace(cfg, strategy=acs.BROADCAST,
                                 chunk_tokens=0)

    def scan_variant(vcfg, rates, locs, keys):
        def cell(r, loc, ks):
            return jax.vmap(lambda k: _episode_metrics(
                vcfg, k, rates=r, locality=loc))(ks)
        return jax.vmap(cell)(rates, locs, keys)

    def pallas_variant(vcfg, rates, locs, keys):
        W, R = keys.shape[0], keys.shape[1]
        flat = jax.tree_util.tree_map(
            lambda x: jnp.repeat(x, R, axis=0), rates)
        out = _episodes_pallas(
            vcfg, keys.reshape(W * R, keys.shape[2]),
            None, None, rates=flat,
            locs=jnp.repeat(locs, R) if content else None)
        return {k: a.reshape(W, R) for k, a in out.items()}

    coherent = pallas_variant if tick_backend == "pallas" else scan_variant

    def run_grid(*args):
        if content:
            rates, locs, base_keys, run_ids = args
        else:
            rates, base_keys, run_ids = args
            locs = jnp.zeros_like(rates.p_act[..., 0])
        _note_trace()
        keys = jax.vmap(lambda bk: acs.run_keys(bk, run_ids))(base_keys)
        outs = []
        if include_broadcast:
            bc = scan_variant(bc_cfg, rates, locs, keys)
            if content:
                bc = _broadcast_content_fill(cfg, bc)
            outs.append(bc)
        outs.append(coherent(cfg, rates, locs, keys))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

    fn = _shard_wrap(run_grid, plan, n_cell_operands=3 if content else 2)
    _GRID_CACHE[cache_key] = fn
    return fn


def _base_keys(seeds: Sequence[int]) -> jax.Array:
    """(V, 2) per-cell base keys: ``PRNGKey(seed_v)``.  Rebuilt fresh
    on every grid call (the operand is donated to the program)."""
    return jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])


def _grid_keys(seeds: Sequence[int], n_runs: int) -> jax.Array:
    """(V, R, 2) uint32 key grid: ``fold_in(PRNGKey(seed_v), r)`` -
    exactly the per-run key schedule the grid programs derive in-device
    via ``acs.run_keys`` (loop baselines in tests/benches consume this
    host-side form)."""
    rs = jnp.arange(n_runs)
    return jnp.stack([
        acs.run_keys(jax.random.PRNGKey(int(s)), rs) for s in seeds])


def _grid_call(fn, plan: ShardPlan, n_runs: int, *cell_args) -> dict:
    """Run a grid program: append the (padded) global ``run_ids``
    operand, execute, and slice off any padded trailing runs."""
    run_ids = jnp.arange(plan.pad_runs, dtype=jnp.int32)
    out = _call_grid(fn, *cell_args, run_ids)
    if plan.pad_runs != n_runs:
        out = {k: a[..., :n_runs] for k, a in out.items()}
    return out


# ---------------------------------------------------------------------------
# Host-side aggregation.


def _result_from(cell: dict, name: str, strategy_name: str,
                 n_runs: int) -> RunResult:
    total = np.asarray(cell["total_tokens"], dtype=np.float64)
    chr_ = np.asarray(cell["cache_hit_rate"], dtype=np.float64)
    stats = RunStats(
        name=name,
        strategy=strategy_name,
        n_runs=n_runs,
        total_tokens_mean=float(total.mean()),
        total_tokens_std=float(total.std()),
        sync_tokens_mean=float(np.mean(cell["sync_tokens"])),
        sync_tokens_std=float(np.std(np.asarray(
            cell["sync_tokens"], dtype=np.float64))),
        fetch_tokens_mean=float(np.mean(cell["fetch_tokens"])),
        signal_tokens_mean=float(np.mean(cell["signal_tokens"])),
        push_tokens_mean=float(np.mean(cell["push_tokens"])),
        broadcast_tokens_mean=float(np.mean(cell["broadcast_tokens"])),
        cache_hit_rate_mean=float(chr_.mean()),
        cache_hit_rate_std=float(chr_.std()),
        n_fetches_mean=float(np.mean(cell["n_fetches"])),
        n_writes_mean=float(np.mean(cell["n_writes"])),
        n_reads_mean=float(np.mean(cell["n_reads"])),
        max_staleness_max=int(np.max(cell["max_staleness"])),
        max_version_lag_max=int(np.max(cell["max_version_lag"])),
        max_consumed_staleness_max=int(
            np.max(cell["max_consumed_staleness"])),
        delta_bytes_mean=float(np.mean(cell["delta_bytes"]))
        if "delta_bytes" in cell else -1.0,
        full_bytes_mean=float(np.mean(cell["full_bytes"]))
        if "full_bytes" in cell else -1.0,
        n_chunks_fetched_mean=float(np.mean(cell["n_chunks_fetched"]))
        if "n_chunks_fetched" in cell else -1.0,
    )
    return RunResult(stats=stats, per_run_total_tokens=total,
                     per_run_chr=chr_)


def _cell(out: dict, variant: int, v: int) -> dict:
    return {k: np.asarray(a)[variant, v] for k, a in out.items()}


def _comparison_of(name: str, volatility: float, bc: RunResult,
                   co: RunResult) -> Comparison:
    savings_runs = (1.0 - co.per_run_total_tokens
                    / bc.stats.total_tokens_mean)
    return Comparison(
        scenario=name,
        volatility=volatility,
        strategy=co.stats.strategy,
        broadcast=bc.stats,
        coherent=co.stats,
        savings_mean=float(savings_runs.mean()),
        savings_std=float(savings_runs.std()),
        crr=co.stats.total_tokens_mean / bc.stats.total_tokens_mean,
        chr_mean=co.stats.cache_hit_rate_mean,
        chr_std=co.stats.cache_hit_rate_std,
    )


def _comparison_from(scn: ScenarioConfig, bc: RunResult,
                     co: RunResult) -> Comparison:
    return _comparison_of(scn.name, scn.acs.volatility, bc, co)


# ---------------------------------------------------------------------------
# Public API.


def run_scenario(scn: ScenarioConfig,
                 tick_backend: Optional[str] = None,
                 devices: Optional[int] = None) -> RunResult:
    """Run ``scn.n_runs`` independent seeded episodes, vmapped.

    Uses the module-level jit cache: repeated calls with the same static
    configuration (any volatility / p_act / seed) reuse one compiled
    program.  ``devices`` caps the shard count (default: every local
    device; 1 forces the unsharded program).
    """
    backend = tick_backend or resolve_tick_backend(scn.acs, scn.n_runs)
    plan = shard_plan(1, scn.n_runs, devices)
    fn = _grid_fn(scn.acs, include_broadcast=False, tick_backend=backend,
                  plan=plan)
    cell_ops = [
        jnp.asarray([scn.acs.volatility], jnp.float32),
        jnp.asarray([scn.acs.p_act], jnp.float32),
    ]
    if acs.content_enabled(scn.acs):
        cell_ops.append(jnp.asarray([scn.acs.write_locality],
                                    jnp.float32))
    out = _grid_call(fn, plan, scn.n_runs, *cell_ops,
                     _base_keys([scn.seed]))
    return _result_from(
        _cell(out, 0, 0), scn.name,
        acs.STRATEGY_NAMES[scn.acs.strategy], scn.n_runs)


def compare_grid(scns: Sequence[ScenarioConfig],
                 tick_backend: Optional[str] = None,
                 devices: Optional[int] = None) -> list[Comparison]:
    """Broadcast-vs-coherent for many scenarios, fused.

    Scenarios sharing a static signature (and n_runs) are batched into a
    single XLA program: variant x scenario x run.  Heterogeneous lists
    still work - each static group compiles once.  On a multi-device
    host each group's program is device-sharded per ``shard_plan``
    (``devices=1`` forces single-device execution).
    """
    groups: dict = {}
    for i, s in enumerate(scns):
        groups.setdefault((_static_key(s.acs), s.n_runs), []).append(i)
    results: list = [None] * len(scns)
    for (_, n_runs), idxs in groups.items():
        sub = [scns[i] for i in idxs]
        cfg = sub[0].acs
        # Only the coherent variant can take the kernel (broadcast is a
        # bulk-inject scan path), so size the threshold on that half.
        backend = tick_backend or resolve_tick_backend(
            cfg, len(sub) * n_runs)
        plan = shard_plan(len(sub), n_runs, devices)
        fn = _grid_fn(cfg, include_broadcast=True, tick_backend=backend,
                      plan=plan)
        cell_ops = [
            jnp.asarray([s.acs.volatility for s in sub], jnp.float32),
            jnp.asarray([s.acs.p_act for s in sub], jnp.float32),
        ]
        if acs.content_enabled(cfg):
            cell_ops.append(jnp.asarray(
                [s.acs.write_locality for s in sub], jnp.float32))
        out = _grid_call(fn, plan, n_runs, *cell_ops,
                         _base_keys([s.seed for s in sub]))
        for j, i in enumerate(idxs):
            bc = _result_from(_cell(out, 0, j), sub[j].name,
                              acs.STRATEGY_NAMES[acs.BROADCAST], n_runs)
            co = _result_from(_cell(out, 1, j), sub[j].name,
                              acs.STRATEGY_NAMES[cfg.strategy], n_runs)
            results[i] = _comparison_from(sub[j], bc, co)
    return results


def compare(scn: ScenarioConfig, strategy_code: Optional[int] = None,
            tick_backend: Optional[str] = None,
            devices: Optional[int] = None) -> Comparison:
    """Run broadcast + coherent variants of one scenario (one program)."""
    coh_scn = scn if strategy_code is None else scn.with_strategy(
        strategy_code)
    return compare_grid([coh_scn], tick_backend=tick_backend,
                        devices=devices)[0]


def sweep_cells(base_scn: ScenarioConfig, volatilities,
                n_runs: Optional[int] = None) -> list[ScenarioConfig]:
    """The per-volatility scenario cells of a V-sweep (deterministic
    per-cell seeds derived from the base seed).  Single source of truth
    for the grid both the fused path and any loop baseline run over."""
    runs = n_runs or base_scn.n_runs
    return [dataclasses.replace(
        base_scn,
        acs=dataclasses.replace(base_scn.acs, volatility=float(v)),
        n_runs=runs,
        seed=base_scn.seed + int(round(float(v) * 1000)))
        for v in volatilities]


def _rate_stack(workloads) -> acs.RateMatrices:
    """Stack per-workload rate matrices along a leading W axis."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[w.rates() for w in workloads])


def _locality_stack(workloads) -> jax.Array:
    """(W,) traced write-locality operand of a content-plane grid."""
    return jnp.asarray(
        [getattr(w, "write_locality", w.acs.write_locality)
         for w in workloads], jnp.float32)


def compare_workloads(workloads, tick_backend: Optional[str] = None,
                      devices: Optional[int] = None
                      ) -> list["Comparison"]:
    """Broadcast-vs-coherent for heterogeneous workloads, fused.

    ``workloads``: ``repro.sim.workloads.Workload`` instances (anything
    with ``.acs``, ``.seed``, ``.n_runs``, ``.name``,
    ``.effective_volatility()`` and ``.rates()`` works).  Workloads
    sharing a static signature (and n_runs) batch into a single XLA
    program - variant x workload x run - with the rate matrices as
    traced axes, so an entire zoo of families costs ONE compilation and
    re-running with new or perturbed families costs zero more.  On a
    multi-device host the program shards per ``shard_plan`` (run axis,
    falling back to the workload axis).
    """
    groups: dict = {}
    for i, w in enumerate(workloads):
        groups.setdefault((_static_key(w.acs), w.n_runs), []).append(i)
    results: list = [None] * len(workloads)
    for (_, n_runs), idxs in groups.items():
        sub = [workloads[i] for i in idxs]
        cfg = sub[0].acs
        backend = tick_backend or resolve_tick_backend(
            cfg, len(sub) * n_runs)
        plan = shard_plan(len(sub), n_runs, devices)
        fn = _het_grid_fn(cfg, include_broadcast=True,
                          tick_backend=backend, plan=plan)
        cell_ops = [_rate_stack(sub)]
        if acs.content_enabled(cfg):
            cell_ops.append(_locality_stack(sub))
        out = _grid_call(fn, plan, n_runs, *cell_ops,
                         _base_keys([w.seed for w in sub]))
        for j, i in enumerate(idxs):
            bc = _result_from(_cell(out, 0, j), sub[j].name,
                              acs.STRATEGY_NAMES[acs.BROADCAST], n_runs)
            co = _result_from(_cell(out, 1, j), sub[j].name,
                              acs.STRATEGY_NAMES[cfg.strategy], n_runs)
            results[i] = _comparison_of(
                sub[j].name, sub[j].effective_volatility(), bc, co)
    return results


def run_workload(w, tick_backend: Optional[str] = None,
                 devices: Optional[int] = None) -> RunResult:
    """Run one heterogeneous workload (no baseline), fused and cached."""
    backend = tick_backend or resolve_tick_backend(w.acs, w.n_runs)
    plan = shard_plan(1, w.n_runs, devices)
    fn = _het_grid_fn(w.acs, include_broadcast=False,
                      tick_backend=backend, plan=plan)
    cell_ops = [_rate_stack([w])]
    if acs.content_enabled(w.acs):
        cell_ops.append(_locality_stack([w]))
    out = _grid_call(fn, plan, w.n_runs, *cell_ops,
                     _base_keys([w.seed]))
    return _result_from(_cell(out, 0, 0), w.name,
                        acs.STRATEGY_NAMES[w.acs.strategy], w.n_runs)


def sweep_volatility(base_scn: ScenarioConfig, volatilities,
                     n_runs: Optional[int] = None,
                     tick_backend: Optional[str] = None,
                     devices: Optional[int] = None
                     ) -> list[Comparison]:
    """Fused V-sweep: ONE jitted program for the whole
    ``(variant x volatility x run)`` grid.  Volatility is a traced
    Bernoulli parameter, so a single compilation covers the sweep and is
    reused across sweeps of any volatility values - the fleet-scale
    path.  On a multi-device host the program is device-sharded
    (``shard_plan``); ledgers are bit-identical at any device count."""
    return compare_grid(sweep_cells(base_scn, volatilities, n_runs),
                        tick_backend=tick_backend, devices=devices)
