"""Token Coherence Theorem (paper SS4.3-4.5): analytic cost model and bounds.

All quantities are in tokens.  Notation follows the paper:
    n  - agent count            S  - reasoning steps
    m  - artifact count         |d| - artifact size (tokens)
    W  - writes per artifact    V = W / S  - volatility factor
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadParams:
    """Closed-form workload description for the analytic model."""

    n_agents: int
    n_steps: int
    artifact_sizes: tuple[int, ...]          # |d_i| in tokens
    writes_per_artifact: tuple[float, ...]   # W(d_i)

    @property
    def n_artifacts(self) -> int:
        return len(self.artifact_sizes)

    @classmethod
    def uniform(
        cls, n_agents: int, n_steps: int, n_artifacts: int,
        artifact_tokens: int, volatility: float,
    ) -> "WorkloadParams":
        """Canonical uniform workload: identical sizes, V(d_i) = V.

        The paper defines W(d_i) = V * S (Def. 4 inverted).
        """
        w = volatility * n_steps
        return cls(
            n_agents=n_agents,
            n_steps=n_steps,
            artifact_sizes=tuple([artifact_tokens] * n_artifacts),
            writes_per_artifact=tuple([w] * n_artifacts),
        )


def broadcast_cost(p: WorkloadParams) -> float:
    """T_broadcast = n * S * sum_i |d_i|   (paper SS4.3)."""
    return float(p.n_agents) * p.n_steps * float(sum(p.artifact_sizes))


def coherent_cost_upper_bound(p: WorkloadParams) -> float:
    """Def. 3: T_coherent <= sum_i n * (n + W(d_i)) * |d_i|."""
    total = 0.0
    for size, w in zip(p.artifact_sizes, p.writes_per_artifact):
        total += p.n_agents * (p.n_agents + w) * size
    return total


def savings_lower_bound(p: WorkloadParams) -> float:
    """Theorem 1: Savings >= 1 - T_coherent_upper / T_broadcast.

    For uniform sizes this reduces to 1 - (n + W)/S.  The bound may be
    negative (Corollary 2, the collapse condition W >= S - n).
    """
    return 1.0 - coherent_cost_upper_bound(p) / broadcast_cost(p)


def savings_lower_bound_uniform(
    n_agents: int, n_steps: int, volatility: float
) -> float:
    """Closed form 1 - n/S - V (paper SS4.5)."""
    return 1.0 - n_agents / n_steps - volatility


def coherence_condition(p: WorkloadParams) -> bool:
    """S > n + W(d_i) for every artifact (Theorem 1 positivity condition)."""
    return all(
        p.n_steps > p.n_agents + w for w in p.writes_per_artifact
    )


def volatility_cliff(n_agents: int, n_steps: int) -> float:
    """Def. 5: V* = 1 - n/S, above which the *lower bound* goes negative.

    SS8.3 shows simulation does not actually collapse there (lazy
    deferred-fetch collapse); the cliff is a property of the bound only.
    """
    return 1.0 - n_agents / n_steps


def max_savings_bound(n_agents: int, n_steps: int) -> float:
    """Corollary 1: read-only artifacts (W = 0) -> bound = 1 - n/S."""
    return 1.0 - n_agents / n_steps


def theorem_table(
    n_agents: int, n_steps: int, volatilities: Sequence[float]
) -> np.ndarray:
    """Vectorized lower-bound column of the SS8.3 cliff table."""
    v = np.asarray(volatilities, dtype=np.float64)
    return 1.0 - n_agents / n_steps - v


def prompt_cache_amplification(
    volatility: float, cache_discount: float = 0.9
) -> dict[str, float]:
    """SS8.4: provider-side prompt-cache hit-rate model.

    Broadcast re-embeds artifact content each step, so the prefix is
    invalidated whenever any artifact changed: hit-rate ~= 1 - V.  Under
    coherent sync the prefix carries only O(1) references, so the
    structural prefix stays stable: hit-rate -> 1.0.  ``cache_discount``
    is the per-hit cost reduction (50-90% per the paper; default 90%).
    Returns effective cost multipliers (lower is better).
    """
    hit_broadcast = max(0.0, 1.0 - volatility)
    hit_coherent = 1.0
    eff_broadcast = 1.0 - cache_discount * hit_broadcast
    eff_coherent = 1.0 - cache_discount * hit_coherent
    return {
        "hit_rate_broadcast": hit_broadcast,
        "hit_rate_coherent": hit_coherent,
        "effective_cost_mult_broadcast": eff_broadcast,
        "effective_cost_mult_coherent": eff_coherent,
        "amplification": (
            eff_broadcast / eff_coherent if eff_coherent > 0 else float("inf")
        ),
    }
