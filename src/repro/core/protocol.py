"""CCS v0.1 message-level protocol implementation (paper SS5, SS7).

Four entities: CoordinatorService (authority), AgentRuntime (per-agent
cache + protocol client), EventBus (invalidations / version updates),
ArtifactStore (canonical content).  This is the control-plane that a real
deployment runs beside the JAX data plane; messages carry metadata and
artifact token payloads, never tensors.

Token accounting uses the same constants as the vectorized simulator
(``repro.core.acs``): a cache-miss fetch costs ``len(content) + 12``
tokens, every invalidation/validation signal costs 12, an eager push
costs ``len(content) + 12``.  ``tests/test_protocol.py`` drives this
implementation and the vectorized simulator with identical action traces
and asserts the ledgers agree exactly.

Beyond the paper: ``ShardedCoordinator`` partitions the artifact
namespace over multiple authority shards (directory-based coherence,
paper SS10 "Centralized authority service" future work).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.lease import LeaseTable
from repro.core.states import MESIState
from repro.core.clock import MonotonicVersioner, VectorClock

SIGNAL_TOKENS = 12

I, S, E, M = (MESIState.I, MESIState.S, MESIState.E, MESIState.M)


# ----------------------------- messages -------------------------------

_msg_counter = itertools.count()


@dataclasses.dataclass
class Message:
    """Common envelope (paper SS5.4)."""

    type: str
    agent_id: str
    artifact_id: str
    version: int
    payload: Any = None
    timestamp: float = 0.0
    msg_id: int = dataclasses.field(default_factory=lambda: next(_msg_counter))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TokenLedger:
    fetch_tokens: int = 0
    push_tokens: int = 0
    signal_tokens: int = 0
    n_fetches: int = 0
    n_hits: int = 0
    n_reads: int = 0
    n_writes: int = 0
    n_invalidation_signals: int = 0

    @property
    def total_tokens(self) -> int:
        return self.fetch_tokens + self.push_tokens + self.signal_tokens

    def merge(self, other: "TokenLedger") -> "TokenLedger":
        return TokenLedger(*[a + b for a, b in
                             zip(dataclasses.astuple(self),
                                 dataclasses.astuple(other))])


# ----------------------------- event bus ------------------------------

class EventBus:
    """Async pub/sub with at-least-once delivery semantics (AS2).

    ``duplicate_every``: deliver every k-th event twice, to exercise the
    idempotency requirement in tests.  ``deliver_immediately=False``
    queues events until ``flush()`` (models bus latency).
    """

    def __init__(self, deliver_immediately: bool = True,
                 duplicate_every: int = 0) -> None:
        self._subs: Dict[str, List[Callable[[Message], None]]] = {}
        self._queue: List[Message] = []
        self.deliver_immediately = deliver_immediately
        self.duplicate_every = duplicate_every
        self._published = 0

    def subscribe(self, agent_id: str,
                  handler: Callable[[Message], None]) -> None:
        self._subs.setdefault(agent_id, []).append(handler)

    def publish(self, msg: Message,
                targets: Optional[Sequence[str]] = None) -> None:
        self._published += 1
        copies = 1
        if self.duplicate_every and self._published % self.duplicate_every == 0:
            copies = 2  # at-least-once: duplicated delivery
        for _ in range(copies):
            for agent_id, handlers in self._subs.items():
                if targets is not None and agent_id not in targets:
                    continue
                for h in handlers:
                    if self.deliver_immediately:
                        h(msg)
                    else:
                        self._queue.append(msg)

    def flush(self) -> None:
        queue, self._queue = self._queue, []
        for msg in queue:
            for handlers in self._subs.values():
                for h in handlers:
                    h(msg)


# --------------------------- artifact store ---------------------------

class ArtifactStore:
    """Canonical artifact versions; serves fetch requests."""

    def __init__(self) -> None:
        self._content: Dict[str, Sequence[int]] = {}

    def put(self, artifact_id: str, content: Sequence[int]) -> None:
        self._content[artifact_id] = content

    def get(self, artifact_id: str) -> Sequence[int]:
        return self._content[artifact_id]

    def token_len(self, artifact_id: str) -> int:
        return len(self._content[artifact_id])


# ----------------------------- authority ------------------------------

@dataclasses.dataclass
class DirectoryEntry:
    version: int = 1
    last_writer: Optional[str] = None
    states: Dict[str, MESIState] = dataclasses.field(default_factory=dict)


class CoordinatorService:
    """Authority service: global artifact directory + serialization point.

    All writes to an artifact serialize through here (assumption A2 /
    AS1); Exclusive grants carry a lease (SS5.2) so an agent crash in M
    state cannot permanently orphan the artifact.
    """

    def __init__(self, bus: EventBus, store: ArtifactStore,
                 lease_ttl: float = LeaseTable.DEFAULT_TTL,
                 strategy: str = "lazy") -> None:
        assert strategy in ("lazy", "eager", "access_count", "ttl")
        self.bus = bus
        self.store = store
        self.strategy = strategy
        self.directory: Dict[str, DirectoryEntry] = {}
        self.versioner = MonotonicVersioner()
        self.leases = LeaseTable(lease_ttl)
        self.ledger = TokenLedger()
        self.vclock = VectorClock()
        self.now: float = 0.0

    # -- registration ---------------------------------------------------
    def register_artifact(self, artifact_id: str,
                          content: Sequence[int]) -> None:
        self.store.put(artifact_id, content)
        self.directory.setdefault(artifact_id, DirectoryEntry())

    def _entry(self, artifact_id: str) -> DirectoryEntry:
        return self.directory[artifact_id]

    def agent_state(self, agent_id: str, artifact_id: str) -> MESIState:
        return self._entry(artifact_id).states.get(agent_id, I)

    # -- time / recovery -------------------------------------------------
    def advance(self, now: float) -> List[Message]:
        """Advance the authority clock; recover orphaned M-state leases."""
        self.now = now
        recovered = []
        for lease in self.leases.collect_expired(now):
            entry = self._entry(lease.artifact_id)
            # revert to last committed version: invalidate EVERYONE,
            # including the (presumed crashed) owner.
            for agent_id in list(entry.states):
                entry.states[agent_id] = I
            msg = Message("LEASE_REVOKED", lease.agent_id,
                          lease.artifact_id, entry.version,
                          timestamp=now)
            self.bus.publish(msg)
            recovered.append(msg)
        return recovered

    # -- protocol operations (SS5.3) --------------------------------------
    def read_request(self, agent_id: str, artifact_id: str
                     ) -> tuple[Sequence[int], int]:
        """READ_REQUEST / FETCH_REQUEST: respond with content+version."""
        entry = self._entry(artifact_id)
        content = self.store.get(artifact_id)
        entry.states[agent_id] = S
        self.ledger.fetch_tokens += len(content) + SIGNAL_TOKENS
        self.ledger.n_fetches += 1
        return content, entry.version

    def validate(self, agent_id: str, artifact_id: str,
                 cached_version: int) -> bool:
        """Staleness check round-trip: True iff cached version current."""
        self.ledger.signal_tokens += SIGNAL_TOKENS
        return self._entry(artifact_id).version == cached_version

    def upgrade_request(self, agent_id: str, artifact_id: str
                        ) -> tuple[bool, List[str]]:
        """UPGRADE_REQUEST: invalidate peers, grant E, start lease."""
        entry = self._entry(artifact_id)
        if self.leases.holder(artifact_id) not in (None, agent_id):
            return False, []  # someone else holds the write lease
        invalidated = []
        for peer, st in entry.states.items():
            if peer != agent_id and st != I:
                entry.states[peer] = I
                invalidated.append(peer)
                self.bus.publish(Message(
                    "INVALIDATE", agent_id, artifact_id, entry.version,
                    timestamp=self.now), targets=[peer])
        self.ledger.signal_tokens += SIGNAL_TOKENS * len(invalidated)
        self.ledger.n_invalidation_signals += len(invalidated)
        entry.states[agent_id] = E
        if self.leases.holder(artifact_id) is None:
            self.leases.grant(agent_id, artifact_id, self.now)
        return True, invalidated

    def commit(self, agent_id: str, artifact_id: str,
               content: Sequence[int],
               push_targets: Optional[Sequence[str]] = None) -> int:
        """COMMIT: store canonical version, writer -> S, publish update.

        Under the eager strategy the authority pushes the fresh content
        to ``push_targets`` (the active sharers at upgrade time),
        pre-populating their caches (SS8.8).
        """
        entry = self._entry(artifact_id)
        if self.leases.holder(artifact_id) != agent_id:
            raise RuntimeError(
                f"commit from {agent_id!r} without lease on {artifact_id!r}"
                " (lease expired? write is lost, re-fetch and re-apply)")
        new_version = self.versioner.bump(artifact_id)
        entry.version = new_version
        entry.last_writer = agent_id
        entry.states[agent_id] = S
        self.store.put(artifact_id, content)
        self.vclock = self.vclock.tick(agent_id)
        self.leases.release(agent_id, artifact_id)
        self.ledger.n_writes += 1
        if self.strategy == "eager" and push_targets:
            for peer in push_targets:
                entry.states[peer] = S
                self.bus.publish(Message(
                    "PUSH", agent_id, artifact_id, new_version,
                    payload=content, timestamp=self.now), targets=[peer])
                self.ledger.push_tokens += len(content) + SIGNAL_TOKENS
        else:
            self.bus.publish(Message(
                "VERSION_UPDATE", agent_id, artifact_id, new_version,
                timestamp=self.now))
        return new_version


class ShardedCoordinator:
    """Directory-sharded authority (beyond-paper, SS10 extension).

    Artifact namespace is hash-partitioned across ``n_shards``
    coordinators; each artifact has a single home shard, so SWMR and
    monotonicity hold per-artifact exactly as in the single-authority
    case (no cross-shard writes exist by construction)."""

    def __init__(self, n_shards: int, bus: EventBus, store: ArtifactStore,
                 strategy: str = "lazy") -> None:
        self.shards = [CoordinatorService(bus, store, strategy=strategy)
                       for _ in range(n_shards)]

    def shard_of(self, artifact_id: str) -> CoordinatorService:
        h = int(hashlib.sha1(artifact_id.encode()).hexdigest(), 16)
        return self.shards[h % len(self.shards)]

    def register_artifact(self, artifact_id, content):
        self.shard_of(artifact_id).register_artifact(artifact_id, content)

    def __getattr__(self, name):
        # route single-artifact ops by artifact_id (2nd positional arg)
        def route(agent_id, artifact_id, *a, **kw):
            return getattr(self.shard_of(artifact_id), name)(
                agent_id, artifact_id, *a, **kw)
        return route

    @property
    def ledger(self) -> TokenLedger:
        total = TokenLedger()
        for s in self.shards:
            total = total.merge(s.ledger)
        return total


# --------------------------- agent runtime ----------------------------

@dataclasses.dataclass
class CacheEntry:
    content: Sequence[int]
    version: int
    state: MESIState
    reads_since_fetch: int = 0
    last_validate_action: int = 0


class AgentRuntime:
    """Per-agent protocol client with a local MESI cache (SS5.2, SS7.1)."""

    def __init__(self, agent_id: str, coordinator, bus: EventBus,
                 strategy: str = "lazy", access_k: int = 8,
                 max_stale_steps: int = 0) -> None:
        self.agent_id = agent_id
        self.coordinator = coordinator
        self.strategy = strategy
        self.access_k = access_k
        self.max_stale_steps = max_stale_steps
        self.cache: Dict[str, CacheEntry] = {}
        self.actions = 0
        self.crashed = False
        bus.subscribe(agent_id, self._on_event)

    # -- event handlers (idempotent, AS2) --------------------------------
    def _on_event(self, msg: Message) -> None:
        if self.crashed:
            return
        entry = self.cache.get(msg.artifact_id)
        if msg.type in ("INVALIDATE", "LEASE_REVOKED"):
            if entry is not None:
                entry.state = I  # re-invalidation is a no-op by design
        elif msg.type == "PUSH":
            self.cache[msg.artifact_id] = CacheEntry(
                msg.payload, msg.version, S,
                last_validate_action=self.actions)
        elif msg.type == "VERSION_UPDATE":
            # Defensive: a valid entry older than the committed version is
            # stale (can occur if a fetch raced an in-flight write lease).
            if (entry is not None and entry.state != I
                    and entry.version < msg.version):
                entry.state = I

    # -- cache freshness --------------------------------------------------
    def _fresh(self, entry: Optional[CacheEntry]) -> bool:
        if entry is None or entry.state == I:
            return False
        if (self.strategy == "access_count"
                and entry.reads_since_fetch >= self.access_k):
            return False
        return True

    def _fill(self, artifact_id: str) -> CacheEntry:
        content, version = self.coordinator.read_request(
            self.agent_id, artifact_id)
        entry = CacheEntry(content, version, S,
                           last_validate_action=self.actions)
        self.cache[artifact_id] = entry
        return entry

    def _ensure_valid(self, artifact_id: str, ledger: TokenLedger
                      ) -> CacheEntry:
        entry = self.cache.get(artifact_id)
        if self._fresh(entry) and self.max_stale_steps > 0:
            staleness = self.actions - entry.last_validate_action
            if staleness > self.max_stale_steps:
                if self.coordinator.validate(self.agent_id, artifact_id,
                                             entry.version):
                    entry.last_validate_action = self.actions
                else:
                    entry.state = I
        if not self._fresh(self.cache.get(artifact_id)):
            return self._fill(artifact_id)
        ledger.n_hits += 1
        return self.cache[artifact_id]

    # -- public API (what the framework adapters call) --------------------
    def read(self, artifact_id: str) -> Sequence[int]:
        """Consume the artifact; zero tokens when the cache is coherent."""
        if self.crashed:
            raise RuntimeError(f"agent {self.agent_id} crashed")
        self.actions += 1
        ledger = self.coordinator.ledger if not isinstance(
            self.coordinator, ShardedCoordinator) else \
            self.coordinator.shard_of(artifact_id).ledger
        entry = self._ensure_valid(artifact_id, ledger)
        entry.reads_since_fetch += 1
        ledger.n_reads += 1
        return entry.content

    def write(self, artifact_id: str,
              new_content: Sequence[int],
              crash_before_commit: bool = False) -> Optional[int]:
        """Read-modify-write: access -> upgrade -> local write -> commit."""
        if self.crashed:
            raise RuntimeError(f"agent {self.agent_id} crashed")
        self.actions += 1
        coord = (self.coordinator.shard_of(artifact_id)
                 if isinstance(self.coordinator, ShardedCoordinator)
                 else self.coordinator)
        entry = self._ensure_valid(artifact_id, coord.ledger)
        granted, invalidated = coord.upgrade_request(
            self.agent_id, artifact_id)
        if not granted:
            return None  # write lease contention; caller retries
        entry.state = E
        # local write: E -> M, zero tokens (SS5.3 Write)
        entry.state = M
        if crash_before_commit:
            self.crashed = True  # AS3 violation: lease TTL must recover
            return None
        version = coord.commit(
            self.agent_id, artifact_id, new_content,
            push_targets=invalidated if self.strategy == "eager" else None)
        entry.content = new_content
        entry.version = version
        entry.state = S
        entry.reads_since_fetch = 0
        entry.last_validate_action = self.actions
        return version

    def state_of(self, artifact_id: str) -> MESIState:
        entry = self.cache.get(artifact_id)
        return entry.state if entry is not None else I
