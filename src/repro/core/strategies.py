"""Synchronization strategy registry (paper SS5.5).

Strategy semantics live in ``repro.core.acs`` (vectorized) and
``repro.core.protocol`` (message-level); this module is the shared
config surface the launcher / adapters expose.
"""

from __future__ import annotations

import dataclasses

from repro.core import acs


@dataclasses.dataclass(frozen=True)
class SyncStrategy:
    name: str
    code: int
    description: str
    enforces_staleness_bound: bool = True


REGISTRY: dict[str, SyncStrategy] = {
    "broadcast": SyncStrategy(
        "broadcast", acs.BROADCAST,
        "Full-state rebroadcast every step (the naive baseline)."),
    "eager": SyncStrategy(
        "eager", acs.EAGER,
        "Invalidate on upgrade grant; push fresh content to active "
        "sharers at commit (update-style; minimizes staleness window).",
        enforces_staleness_bound=False),  # paper SS8.2: eager does not
    "lazy": SyncStrategy(
        "lazy", acs.LAZY,
        "Invalidate on commit only; fetch-on-demand. Recommended default."),
    "ttl": SyncStrategy(
        "ttl", acs.TTL,
        "Epoch lease refresh decoupled from write activity."),
    "access_count": SyncStrategy(
        "access_count", acs.ACCESS_COUNT,
        "Lazy + entries expire after k reads (OpenID execution-count "
        "credential analogue)."),
}


def get(name: str) -> SyncStrategy:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; one of {sorted(REGISTRY)}"
        ) from None
