"""Core of the paper: the Artifact Coherence System (ACS) and CCS protocol."""

from repro.core.states import MESIState, CoherenceEvent, TRANSITION_TABLE
from repro.core.acs import (
    ACSConfig, ACSArrays, ACSMetrics, RateMatrices, init_arrays,
    init_metrics, tick, run_episode, draw_actions, uniform_rates,
    BROADCAST, EAGER, LAZY, TTL, ACCESS_COUNT,
    STRATEGY_NAMES, STRATEGY_CODES, SIGNAL_TOKENS,
)
from repro.core import theorem, invariants, model_check, strategies
from repro.core.protocol import (
    Message, EventBus, ArtifactStore, CoordinatorService,
    ShardedCoordinator, AgentRuntime, TokenLedger,
)
from repro.core.lease import Lease, LeaseTable
from repro.core.clock import VectorClock, MonotonicVersioner

__all__ = [
    "MESIState", "CoherenceEvent", "TRANSITION_TABLE",
    "ACSConfig", "ACSArrays", "ACSMetrics", "RateMatrices", "init_arrays",
    "init_metrics", "tick", "run_episode", "draw_actions", "uniform_rates",
    "BROADCAST", "EAGER", "LAZY", "TTL",
    "ACCESS_COUNT", "STRATEGY_NAMES", "STRATEGY_CODES", "SIGNAL_TOKENS",
    "theorem", "invariants", "model_check", "strategies",
    "Message", "EventBus", "ArtifactStore", "CoordinatorService",
    "ShardedCoordinator", "AgentRuntime", "TokenLedger",
    "Lease", "LeaseTable", "VectorClock", "MonotonicVersioner",
]
