"""The three verified invariants of CCS (paper SS6.2), as predicates.

These run over both the vectorized ACS arrays (JAX/numpy) and the
model-checker states, so the same definitions back the simulator tests,
the protocol tests and the exhaustive state-space search.
"""

from __future__ import annotations

import numpy as np

from repro.core.states import MESIState

_M = int(MESIState.M)


def single_writer(state_matrix) -> bool:
    """Invariant 1 (SWMR): at most one agent in M per artifact.

    ``state_matrix``: (n_agents, n_artifacts) int array.
    """
    s = np.asarray(state_matrix)
    return bool(((s == _M).sum(axis=0) <= 1).all())


def monotonic_version(version_before, version_after) -> bool:
    """Invariant 2: artifactVersion'(d) >= artifactVersion(d), elementwise."""
    return bool(
        (np.asarray(version_after) >= np.asarray(version_before)).all())


def bounded_staleness(agent_steps, last_sync, k: int) -> bool:
    """Invariant 3: agentSteps[a] - lastSync[a] <= K for every agent.

    Follows the paper's TLA+ spec literally: ``agent_steps`` and
    ``last_sync`` are per-agent counters (steps executed vs version at
    last sync).
    """
    steps = np.asarray(agent_steps)
    sync = np.asarray(last_sync)
    return bool(((steps - sync) <= k).all())


def exclusive_means_alone(state_matrix) -> bool:
    """Auxiliary MESI sanity: if any agent holds E or M on d, every other
    agent holds I on d (strict exclusivity).  Stronger than SWMR; holds
    for the protocol as specified (upgrade invalidates all peers)."""
    s = np.asarray(state_matrix)
    excl = (s >= int(MESIState.E))
    valid = (s >= int(MESIState.S))
    n_excl = excl.sum(axis=0)
    n_valid = valid.sum(axis=0)
    # wherever someone is exclusive, exactly one valid copy exists
    return bool((np.where(n_excl > 0, n_valid == 1, True)).all())
