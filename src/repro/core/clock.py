"""Logical clocks for version ordering (paper SS7.3).

The authority assigns monotonically increasing integer versions at commit
time; a per-agent vector clock establishes the partial (happens-before)
order over writes across artifacts, following Lamport [10] / Mattern [13].
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class VectorClock:
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)

    def tick(self, agent_id: str) -> "VectorClock":
        c = dict(self.counters)
        c[agent_id] = c.get(agent_id, 0) + 1
        return VectorClock(c)

    def merge(self, other: "VectorClock") -> "VectorClock":
        keys = set(self.counters) | set(other.counters)
        return VectorClock({
            k: max(self.counters.get(k, 0), other.counters.get(k, 0))
            for k in keys})

    def happens_before(self, other: "VectorClock") -> bool:
        """self < other in the strict causal order."""
        keys = set(self.counters) | set(other.counters)
        le = all(self.counters.get(k, 0) <= other.counters.get(k, 0)
                 for k in keys)
        lt = any(self.counters.get(k, 0) < other.counters.get(k, 0)
                 for k in keys)
        return le and lt

    def concurrent(self, other: "VectorClock") -> bool:
        return (not self.happens_before(other)
                and not other.happens_before(self)
                and self.counters != other.counters)


class MonotonicVersioner:
    """Authority-side version assignment (Invariant 2 by construction)."""

    def __init__(self) -> None:
        self._versions: Dict[str, int] = {}

    def current(self, artifact_id: str) -> int:
        return self._versions.get(artifact_id, 1)

    def bump(self, artifact_id: str) -> int:
        v = self.current(artifact_id) + 1
        self._versions[artifact_id] = v
        return v
