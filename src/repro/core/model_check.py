"""Exhaustive state-space model checking of the CCS protocol (paper SS6).

TLC is not available offline, so this module re-implements the paper's
TLA+ specification (SS6.1) as an explicit-state BFS enumerator with the
same variables, actions, and invariants.  The companion TLA+ source is
shipped at ``docs/ccs.tla`` for readers with a TLC installation.

Spec variables (single shared artifact, per the paper):
    artifactVersion : Nat            - global canonical version
    artifactState   : Agent -> MESI  - per-agent coherence state
    agentSteps      : Agent -> Nat   - steps executed since start
    lastSync        : Agent -> Nat   - version at last sync

Actions: Read(a), Write(a), Fetch(a), Upgrade(a) exactly as in SS6.1;
the runtime enforces the K-staleness bound as a Read guard (that is the
protocol's "agents cannot reason on stale artifact state beyond K
steps").  State-space finiteness comes from the same bound TLC uses:
a version / step cap supplied as exploration constraints.

Also provided: the ``BrokenUpgrade`` mutant (no peer invalidation) and a
counterexample search that reproduces the paper's 3-step SWMR violation.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Iterable, Optional

from repro.core.states import MESIState

I, S, E, M = (int(MESIState.I), int(MESIState.S),
              int(MESIState.E), int(MESIState.M))


@dataclasses.dataclass(frozen=True)
class CheckConfig:
    n_agents: int = 3
    max_stale_steps: int = 3
    # exploration constraints (TLC CONSTRAINT equivalents).  With the
    # defaults below the reachable space is 3,136 states for 3 agents -
    # the same order as the paper's "approximately 2,400" (the paper does
    # not publish its exact TLC CONSTRAINT; the count is cap-dependent).
    max_version: int = 2
    max_steps: int = 3
    broken_upgrade: bool = False  # the SS6.3 mutant


State = tuple  # (version, states tuple, steps tuple, last_sync tuple)


def initial_state(cfg: CheckConfig) -> State:
    """Init: all agents Shared at version 1 (SS6.1)."""
    n = cfg.n_agents
    return (1, (S,) * n, (0,) * n, (1,) * n)


def successors(cfg: CheckConfig, st: State) -> Iterable[tuple[str, State]]:
    """Enabled actions -> next states (the Next relation)."""
    version, states, steps, sync = st
    n = cfg.n_agents
    for a in range(n):
        # Read(a): requires a valid copy; runtime refuses reads that
        # would breach the staleness budget.
        if states[a] != I and steps[a] < cfg.max_steps:
            if (steps[a] + 1) - sync[a] <= cfg.max_stale_steps:
                ns = list(steps)
                ns[a] += 1
                yield (f"Read({a})", (version, states, tuple(ns), sync))
        # Write(a): requires exclusivity; bumps version; invalidates peers.
        # The SS6.3 mutant removes *invalidation* wholesale, so peers keep
        # their states on write too - that is what lets two agents reach
        # M simultaneously (the paper's 4-step SWMR violation).
        if states[a] in (E, M) and version < cfg.max_version:
            if cfg.broken_upgrade:
                nst = tuple(M if x == a else states[x] for x in range(n))
            else:
                nst = tuple(M if x == a else I for x in range(n))
            nsync = list(sync)
            nsync[a] = version + 1
            yield (f"Write({a})",
                   (version + 1, nst, steps, tuple(nsync)))
        # Fetch(a): I -> S, syncs to current version.
        if states[a] == I:
            nst = tuple(S if x == a else states[x] for x in range(n))
            nsync = list(sync)
            nsync[a] = version
            yield (f"Fetch({a})", (version, nst, steps, tuple(nsync)))
        # Upgrade(a): S -> E; invalidates peers unless broken.
        if states[a] == S:
            if cfg.broken_upgrade:
                nst = tuple(E if x == a else states[x] for x in range(n))
            else:
                nst = tuple(E if x == a else I for x in range(n))
            yield (f"Upgrade({a})", (version, nst, steps, sync))


# ----------------------------- invariants -----------------------------

def inv_single_writer(cfg: CheckConfig, st: State) -> bool:
    return sum(1 for x in st[1] if x == M) <= 1


def inv_bounded_staleness(cfg: CheckConfig, st: State) -> bool:
    _, _, steps, sync = st
    return all(steps[a] - sync[a] <= cfg.max_stale_steps
               for a in range(cfg.n_agents))


def inv_exclusive_alone(cfg: CheckConfig, st: State) -> bool:
    states = st[1]
    if any(x in (E, M) for x in states):
        return sum(1 for x in states if x != I) == 1
    return True


# The paper verifies exactly three properties: SingleWriter,
# BoundedStaleness, and MonotonicVersion (the last is an action property
# checked on every transition in ``check``).  Note: ``ExclusiveAlone`` is
# deliberately NOT in this set - the paper's Fetch action does not
# downgrade an Exclusive owner to S, so E+S can legitimately coexist in
# the spec's reachable space (a known departure from hardware MESI that
# SWMR tolerates because writes still invalidate all peers).
INVARIANTS: dict[str, Callable[[CheckConfig, State], bool]] = {
    "SingleWriter": inv_single_writer,
    "BoundedStaleness": inv_bounded_staleness,
}
STRICT_INVARIANTS = dict(INVARIANTS)


@dataclasses.dataclass
class CheckResult:
    states_explored: int
    transitions: int
    deadlocks: int
    violation: Optional[dict] = None   # {invariant, state, trace}
    monotonic_ok: bool = True

    @property
    def ok(self) -> bool:
        return self.violation is None and self.monotonic_ok


def check(cfg: CheckConfig,
          invariants: Optional[dict] = None) -> CheckResult:
    """BFS over the reachable state space, checking invariants on every
    state and version-monotonicity on every transition."""
    if invariants is None:
        invariants = (INVARIANTS if cfg.broken_upgrade
                      else STRICT_INVARIANTS)
    init = initial_state(cfg)
    parent: dict[State, Optional[tuple[State, str]]] = {init: None}
    q = deque([init])
    n_trans = 0
    deadlocks = 0
    monotonic_ok = True

    def trace_of(st: State) -> list[str]:
        acts = []
        cur = st
        while parent[cur] is not None:
            prev, act = parent[cur]
            acts.append(act)
            cur = prev
        return list(reversed(acts))

    while q:
        st = q.popleft()
        for name, fn in invariants.items():
            if not fn(cfg, st):
                return CheckResult(
                    states_explored=len(parent), transitions=n_trans,
                    deadlocks=deadlocks, monotonic_ok=monotonic_ok,
                    violation={"invariant": name, "state": st,
                               "trace": trace_of(st)})
        succ = list(successors(cfg, st))
        # "deadlock" = no action enabled at all (ignoring the exploration
        # caps would make every state live; we count capped leaves
        # separately and never report them as protocol deadlocks).
        uncapped = list(successors(
            dataclasses.replace(cfg, max_version=1 << 30,
                                max_steps=1 << 30), st))
        if not uncapped:
            deadlocks += 1
        for act, nxt in succ:
            n_trans += 1
            if nxt[0] < st[0]:
                monotonic_ok = False
            if nxt not in parent:
                parent[nxt] = (st, act)
                q.append(nxt)
    return CheckResult(states_explored=len(parent), transitions=n_trans,
                       deadlocks=deadlocks, monotonic_ok=monotonic_ok)


def find_swmr_counterexample(n_agents: int = 3) -> CheckResult:
    """SS6.3: removing invalidation from Upgrade violates SWMR within a
    few steps (A1 upgrades, A2 upgrades, A1 writes, A2 writes)."""
    cfg = CheckConfig(n_agents=n_agents, broken_upgrade=True,
                      max_version=4, max_steps=4)
    return check(cfg, invariants={"SingleWriter": inv_single_writer})
