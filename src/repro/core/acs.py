"""Artifact Coherence System (ACS) - vectorized JAX state machine.

This is the executable form of the paper's six-tuple <A, D, Sigma, delta,
alpha, T> (Def. 1).  The coherence state function alpha is a dense
``(n_agents, n_artifacts)`` int32 array; one *tick* applies the serialized
authority semantics for a single orchestration step (paper SS8.1):

  * each agent acts with probability ``p_act``;
  * an acting agent picks an artifact uniformly and writes with
    probability ``V`` (else reads);
  * reads from Invalid state trigger a coherence fill (fetch, |d| tokens);
  * writes are read-modify-write: upgrade (peers invalidated), local
    write, commit (version++, writer -> S per protocol SS5.3);
  * token cost = full fetches x artifact size + 12-token signals.

Strategies (paper SS5.5) differ in *when* entries become Invalid and
whether content is pushed:

  BROADCAST     every agent receives every artifact every step (baseline)
  EAGER         invalidate-on-upgrade + push-on-commit to active sharers
  LAZY          invalidate-on-commit; fetch-on-demand (recommended)
  TTL           epoch lease refresh, decoupled from writes
  ACCESS_COUNT  lazy + entries expire after k reads

The same semantics are implemented as a Pallas TPU kernel in
``repro.kernels.mesi_transition`` (batched over simulations) and as a
message-level protocol in ``repro.core.protocol``; tests assert all three
agree.

With ``chunk_tokens > 0`` the chunk-granular content plane
(``repro.content``) rides alongside: per-chunk version counters at the
authority, a per-(agent, artifact) chunk sync vector that survives MESI
invalidation, writes dirtying only a sampled locality span, and fills
shipping only stale chunks.  It is a bytes-on-wire *accounting overlay*
- no token counter moves - mirrored bit-exactly by
``repro.kernels.chunk_diff`` and pinned by the byte-exact oracle leg
(``repro.sim.oracle.check_content_trace``).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.content.chunks import BYTES_PER_TOKEN, chunk_sizes, n_chunks
from repro.core.states import MESIState

# Strategy codes (static Python ints baked into jitted closures).
BROADCAST = 0
EAGER = 1
LAZY = 2
TTL = 3
ACCESS_COUNT = 4

STRATEGY_NAMES = {
    BROADCAST: "broadcast",
    EAGER: "eager",
    LAZY: "lazy",
    TTL: "ttl",
    ACCESS_COUNT: "access_count",
}
STRATEGY_CODES = {v: k for k, v in STRATEGY_NAMES.items()}

#: per-signal overhead (tokens) for invalidation / envelope messages (SS8.1)
SIGNAL_TOKENS = 12

_I = int(MESIState.I)
_S = int(MESIState.S)
_E = int(MESIState.E)
_M = int(MESIState.M)


@dataclasses.dataclass(frozen=True)
class ACSConfig:
    """Static scenario parameters (baked into the jitted tick)."""

    n_agents: int
    n_artifacts: int
    artifact_tokens: int
    n_steps: int
    p_act: float = 0.75
    volatility: float = 0.1          # per-action write probability V
    strategy: int = LAZY
    ttl_events: int = 10             # TTL lease, in logical action-events
    access_k: int = 8                # access-count expiry threshold
    max_stale_steps: int = 0         # 0 disables K-staleness enforcement
    #: chunk-granular content plane (``repro.content``): artifacts are
    #: arrays of ``chunk_tokens``-token chunks with per-chunk version
    #: counters, misses fetch only stale chunks (delta coherence), and
    #: the metrics grow a bytes-on-wire ledger.  0 disables the plane -
    #: the disabled program is byte-identical to the pre-content code.
    chunk_tokens: int = 0
    #: fraction of an artifact's chunks one write dirties (a circular
    #: chunk span; sampled per write).  Default 1.0 = whole-artifact
    #: writes.  A *traced* sweep axis of the fused engine, like
    #: ``volatility`` - this field is only the default.
    write_locality: float = 1.0


class RateMatrices(NamedTuple):
    """Heterogeneous workload rates - the traced generalization of the
    scalar ``(p_act, volatility)`` pair (paper SS8.1 uses scalars only).

    All three are *traced* tensor inputs of the fused sweep path, so one
    compiled grid program serves every workload family that shares a
    static shape.  Rows of ``exp(log_pick)`` sum to 1.
    """

    p_act: jax.Array       # (n,)   per-agent act probability
    log_pick: jax.Array    # (n, m) log artifact-selection probabilities
    write_rate: jax.Array  # (n, m) P(write | agent a picked artifact d)


def uniform_rates(cfg: ACSConfig) -> RateMatrices:
    """The scalar scenario expressed as rate matrices (for tests that
    cross-check the heterogeneous path against the homogeneous one)."""
    n, m = cfg.n_agents, cfg.n_artifacts
    return RateMatrices(
        p_act=jnp.full((n,), cfg.p_act, jnp.float32),
        log_pick=jnp.full((n, m), -jnp.log(float(m)), jnp.float32),
        write_rate=jnp.full((n, m), cfg.volatility, jnp.float32),
    )


def run_keys(base_key: jax.Array, run_ids: jax.Array) -> jax.Array:
    """Per-run episode keys: ``fold_in(base_key, run_ids[i])``.

    The single source of truth for the sweep engine's per-run key
    schedule.  ``run_ids`` carries **global** run indices, so a
    device-sharded grid (``repro.sim.engine`` under ``shard_map``)
    derives exactly the keys the single-device path derives for the
    same cells - device-local position never enters the schedule, and
    ledgers stay bit-identical across any device count.  The
    differential oracle (``repro.sim.oracle.episode_key``) replays
    single cells of this same schedule.
    """
    return jax.vmap(lambda r: jax.random.fold_in(base_key, r))(run_ids)


def draw_actions(key: jax.Array, n_agents: int, n_artifacts: int,
                 volatility, p_act, rates: RateMatrices | None = None):
    """Sample one step's (acts, arts, writes) for every agent.

    The single source of truth for action sampling: the scan tick, the
    Pallas episode route and the differential-conformance trace sampler
    (``repro.sim.oracle``) all call this, so a fixed key yields the same
    action stream everywhere - the property the four-way conformance
    harness rests on.

    Scalar path (``rates is None``): Bernoulli(p_act) activity, uniform
    artifact choice, Bernoulli(volatility) writes - bit-identical to the
    original homogeneous sampler.  Heterogeneous path: per-agent
    Bernoulli activity, per-agent categorical artifact choice, and a
    write probability looked up at the chosen (agent, artifact) cell.
    """
    k_act, k_art, k_wr = jax.random.split(key, 3)
    if rates is None:
        acts = jax.random.bernoulli(k_act, p_act, (n_agents,))
        arts = jax.random.randint(k_art, (n_agents,), 0, n_artifacts)
        writes = jax.random.bernoulli(k_wr, volatility, (n_agents,))
    else:
        acts = jax.random.bernoulli(k_act, rates.p_act, (n_agents,))
        arts = jax.random.categorical(k_art, rates.log_pick, axis=-1)
        w_p = rates.write_rate[jnp.arange(n_agents), arts]
        writes = jax.random.bernoulli(k_wr, w_p, (n_agents,))
    return acts, arts.astype(jnp.int32), writes


#: strategies the chunk content plane is defined for: write-invalidate,
#: fetch-on-demand.  Eager push and TTL/broadcast bulk injection ship
#: whole artifacts by construction; delta coherence is the lazy-fetch
#: optimization (paper SS5.5 recommends lazy).
CONTENT_STRATEGIES = (LAZY, ACCESS_COUNT)

#: ``fold_in`` constant deriving the write-span key from a step key.
#: Folding (instead of widening the existing 3-way split) leaves the
#: act/artifact/write streams - and every committed golden ledger -
#: untouched when the content plane is enabled.
_SPAN_FOLD = 0x5EED


def content_enabled(cfg: ACSConfig) -> bool:
    return cfg.chunk_tokens > 0


def content_chunks(cfg: ACSConfig) -> int:
    """Chunks per artifact under this config's chunk geometry."""
    return n_chunks(cfg.artifact_tokens, cfg.chunk_tokens)


def _chunk_sizes(cfg: ACSConfig) -> jax.Array:
    return jnp.asarray(chunk_sizes(cfg.artifact_tokens,
                                   cfg.chunk_tokens), jnp.int32)


def draw_write_chunks(key: jax.Array, n_agents: int, n_chunks_: int,
                      locality) -> jax.Array:
    """Sample one step's per-agent write span as a (n, C) bool mask.

    The single sampling source of truth for write locality: the scan
    tick, the Pallas episode route and the oracle trace sampler all
    call this with the same per-step key.  A span is *circular* -
    chunk ``i`` is dirtied iff ``(i - start) mod C < L`` with
    ``start ~ U[0, C)`` and ``L = clip(round(locality * C), 1, C)`` -
    so locality is a pure span-length knob with no edge effects, and
    ``locality`` may be a traced sweep scalar.  The key is derived by
    ``fold_in(key, _SPAN_FOLD)``, leaving the action streams of the
    same step key bit-identical to the pre-content sampler.
    """
    k = jax.random.fold_in(key, _SPAN_FOLD)
    start = jax.random.randint(k, (n_agents,), 0, n_chunks_)
    span = jnp.clip(jnp.round(
        jnp.asarray(locality, jnp.float32) * n_chunks_).astype(jnp.int32),
        1, n_chunks_)
    idx = jnp.arange(n_chunks_, dtype=jnp.int32)
    return ((idx[None, :] - start[:, None]) % n_chunks_) < span


class ACSArrays(NamedTuple):
    """alpha and the bookkeeping the strategies need (all int32).

    The three ``chunk_*`` leaves are the content plane
    (``repro.content``); they are ``None`` when ``cfg.chunk_tokens ==
    0`` (None leaves are empty pytree nodes, so the disabled carry is
    structurally identical to the pre-content one).
    """

    state: jax.Array            # (n, m) MESI state
    version: jax.Array          # (m,)   canonical version at authority
    last_sync: jax.Array        # (n, m) version at agent's last fill
    reads_since_fetch: jax.Array  # (n, m) for ACCESS_COUNT
    agent_actions: jax.Array    # (n,)   logical action clock per agent
    last_validate: jax.Array    # (n, m) agent_actions value at last validate
    chunk_version: jax.Array | None = None  # (m, C) per-chunk authority ver
    chunk_sync: jax.Array | None = None     # (n, m, C) reader chunk vector
    chunk_dirty: jax.Array | None = None    # (m, C) ever-written bitmap


class ACSMetrics(NamedTuple):
    fetch_tokens: jax.Array
    push_tokens: jax.Array
    signal_tokens: jax.Array
    broadcast_tokens: jax.Array
    n_fetches: jax.Array
    n_hits: jax.Array
    n_reads: jax.Array
    n_writes: jax.Array
    n_invalidation_signals: jax.Array
    max_staleness: jax.Array
    max_version_lag: jax.Array
    #: largest action-clock staleness a *served* cache hit carried, i.e.
    #: after any forced revalidation (Invariant 3 enforcement surface:
    #: with ``max_stale_steps = K > 0`` this never exceeds K).
    max_consumed_staleness: jax.Array
    #: bytes-on-wire ledger of the chunk content plane (all zero when
    #: ``chunk_tokens == 0``).  ``delta_bytes`` is what delta coherence
    #: actually shipped (stale chunks + signal envelope per fill);
    #: ``full_bytes`` is what whole-artifact lazy would have shipped
    #: for the *same* miss sequence - so ``delta <= full`` everywhere
    #: and strict dominance means at least one partial re-fetch.
    delta_bytes: jax.Array
    full_bytes: jax.Array
    n_chunks_fetched: jax.Array

    @property
    def total_tokens(self) -> jax.Array:
        return (
            self.fetch_tokens + self.push_tokens
            + self.signal_tokens + self.broadcast_tokens
        )

    @property
    def sync_tokens(self) -> jax.Array:
        """Synchronous (critical-path) traffic only: demand fetches +
        signals + broadcast sweeps.  Eager's push-on-commit is
        asynchronous background traffic that overlaps agent think-time
        (SS8.8 pointer-semantics accounting), so it is excluded here and
        reported separately as ``push_tokens``."""
        return self.fetch_tokens + self.signal_tokens + self.broadcast_tokens

    @property
    def cache_hit_rate(self) -> jax.Array:
        denom = jnp.maximum(self.n_hits + self.n_fetches, 1)
        return self.n_hits.astype(jnp.float32) / denom


def init_arrays(cfg: ACSConfig) -> ACSArrays:
    """Cold start: all caches Invalid, canonical version 1 (SS8.1).

    With the content plane enabled, chunk versions start at 1 and
    reader chunk vectors at 0, mirroring the whole-artifact convention:
    a cold fill ships every chunk."""
    n, m = cfg.n_agents, cfg.n_artifacts
    z = jnp.zeros((n, m), jnp.int32)
    chunk_version = chunk_sync = chunk_dirty = None
    if content_enabled(cfg):
        if cfg.strategy not in CONTENT_STRATEGIES:
            raise ValueError(
                f"chunk content plane covers "
                f"{[STRATEGY_NAMES[s] for s in CONTENT_STRATEGIES]} "
                f"(write-invalidate, fetch-on-demand); got "
                f"{STRATEGY_NAMES[cfg.strategy]}")
        C = content_chunks(cfg)
        chunk_version = jnp.ones((m, C), jnp.int32)
        chunk_sync = jnp.zeros((n, m, C), jnp.int32)
        chunk_dirty = jnp.zeros((m, C), jnp.int32)
    return ACSArrays(
        state=jnp.full((n, m), _I, jnp.int32),
        version=jnp.ones((m,), jnp.int32),
        last_sync=z,
        reads_since_fetch=z,
        agent_actions=jnp.zeros((n,), jnp.int32),
        last_validate=z,
        chunk_version=chunk_version,
        chunk_sync=chunk_sync,
        chunk_dirty=chunk_dirty,
    )


def init_metrics() -> ACSMetrics:
    z = jnp.zeros((), jnp.int32)
    return ACSMetrics(*([z] * len(ACSMetrics._fields)))


def _entry_expired(cfg: ACSConfig, arrays: ACSArrays, a, d) -> jax.Array:
    """Strategy-specific freshness overrides on a *valid* entry."""
    if cfg.strategy == ACCESS_COUNT:
        return arrays.reads_since_fetch[a, d] >= cfg.access_k
    return jnp.zeros((), jnp.bool_)


def _fill(cfg, arrays: ACSArrays, met: ACSMetrics, a, d):
    """Coherence fill: FETCH_REQUEST -> content + version, I -> S.

    With the content plane on, the payload is a *delta*: only chunks
    whose authority version exceeds the reader's chunk vector ship
    (the reader's vector survives MESI invalidation - stale local
    chunks are still valid bases for patching).  The token ledger is
    untouched (it stays the paper's whole-artifact cost model); the
    byte ledger records both what delta coherence shipped and what
    whole-artifact lazy would have shipped for this same fill.
    """
    arrays = arrays._replace(
        state=arrays.state.at[a, d].set(_S),
        last_sync=arrays.last_sync.at[a, d].set(arrays.version[d]),
        reads_since_fetch=arrays.reads_since_fetch.at[a, d].set(0),
        last_validate=arrays.last_validate.at[a, d].set(
            arrays.agent_actions[a]),
    )
    met = met._replace(
        fetch_tokens=met.fetch_tokens + cfg.artifact_tokens + SIGNAL_TOKENS,
        n_fetches=met.n_fetches + 1,
    )
    if content_enabled(cfg):
        stale = arrays.chunk_version[d] > arrays.chunk_sync[a, d]  # (C,)
        delta_tokens = jnp.sum(jnp.where(stale, _chunk_sizes(cfg), 0))
        met = met._replace(
            delta_bytes=met.delta_bytes
            + (delta_tokens + SIGNAL_TOKENS) * BYTES_PER_TOKEN,
            full_bytes=met.full_bytes
            + (cfg.artifact_tokens + SIGNAL_TOKENS) * BYTES_PER_TOKEN,
            n_chunks_fetched=met.n_chunks_fetched
            + jnp.sum(stale.astype(jnp.int32)),
        )
        arrays = arrays._replace(chunk_sync=arrays.chunk_sync.at[a, d].set(
            arrays.chunk_version[d]))
    return arrays, met


def _access(cfg: ACSConfig, arrays: ACSArrays, met: ACSMetrics, a, d):
    """Shared read/write prologue: ensure a valid, fresh local copy.

    Returns updated (arrays, metrics).  Counts hit/miss and enforces
    K-bounded staleness when enabled (Invariant 3, SS6.2).
    """
    staleness = arrays.agent_actions[a] - arrays.last_validate[a, d]
    entry_valid = arrays.state[a, d] != _I
    # Content staleness a coherent read may observe: canonical version
    # minus the version this valid entry was filled at.  Zero for
    # lazy/eager/access-count (writes invalidate readers); bounded by
    # the lease for TTL.
    version_lag = arrays.version[d] - arrays.last_sync[a, d]
    met = met._replace(
        max_staleness=jnp.maximum(
            met.max_staleness, jnp.where(entry_valid, staleness, 0)),
        max_version_lag=jnp.maximum(
            met.max_version_lag, jnp.where(entry_valid, version_lag, 0)))

    invalid = arrays.state[a, d] == _I
    expired = jnp.logical_and(~invalid, _entry_expired(cfg, arrays, a, d))

    if cfg.max_stale_steps > 0:
        # forced revalidation: version check (12 tokens); full fetch only
        # if the canonical version moved on.
        needs_check = jnp.logical_and(
            ~invalid, staleness > cfg.max_stale_steps)
        version_moved = arrays.last_sync[a, d] != arrays.version[d]
        met = met._replace(signal_tokens=met.signal_tokens + jnp.where(
            needs_check, SIGNAL_TOKENS, 0))
        arrays = arrays._replace(last_validate=jnp.where(
            jnp.logical_and(needs_check, ~version_moved),
            arrays.last_validate.at[a, d].set(arrays.agent_actions[a]),
            arrays.last_validate))
        expired = jnp.logical_or(
            expired, jnp.logical_and(needs_check, version_moved))

    miss = jnp.logical_or(invalid, expired)

    def on_miss(args):
        arrays, met = args
        return _fill(cfg, arrays, met, a, d)

    def on_hit(args):
        arrays, met = args
        # Staleness the consumer actually sees: re-read last_validate
        # AFTER any forced revalidation above reset it.
        consumed = arrays.agent_actions[a] - arrays.last_validate[a, d]
        met = met._replace(
            n_hits=met.n_hits + 1,
            max_consumed_staleness=jnp.maximum(
                met.max_consumed_staleness, consumed))
        return arrays, met

    return jax.lax.cond(miss, on_miss, on_hit, (arrays, met))


def _do_read(cfg, arrays: ACSArrays, met: ACSMetrics, a, d):
    arrays, met = _access(cfg, arrays, met, a, d)
    arrays = arrays._replace(
        reads_since_fetch=arrays.reads_since_fetch.at[a, d].add(1))
    met = met._replace(n_reads=met.n_reads + 1)
    return arrays, met


def _do_write(cfg, arrays: ACSArrays, met: ACSMetrics, a, d,
              wchunks=None):
    """Upgrade -> local write -> commit (SS5.3), serialized via authority.

    ``wchunks`` is the (C,) bool chunk mask this write dirties (content
    plane only): the simulator samples it as a locality span
    (``draw_write_chunks``), the live service measures it from actual
    content diffs.  Required when ``cfg.chunk_tokens > 0``.
    """
    # Read-modify-write: the writer needs a valid base copy.
    arrays, met = _access(cfg, arrays, met, a, d)

    if cfg.strategy != TTL:
        # UPGRADE: authority invalidates peers; one signal per peer whose
        # copy was actually valid (idempotent re-invalidation is free).
        peer_valid = arrays.state[:, d] != _I
        peer_valid = peer_valid.at[a].set(False)
        n_signals = jnp.sum(peer_valid.astype(jnp.int32))
        new_col = jnp.where(peer_valid, _I, arrays.state[:, d])
        arrays = arrays._replace(state=arrays.state.at[:, d].set(new_col))
        met = met._replace(
            signal_tokens=met.signal_tokens + SIGNAL_TOKENS * n_signals,
            n_invalidation_signals=met.n_invalidation_signals + n_signals,
        )
    else:
        peer_valid = jnp.zeros((cfg.n_agents,), jnp.bool_)

    # Local write (E -> M) then COMMIT: version++, writer downgrades to S.
    new_version = arrays.version[d] + 1
    arrays = arrays._replace(
        version=arrays.version.at[d].set(new_version),
        state=arrays.state.at[a, d].set(_S),
        last_sync=arrays.last_sync.at[a, d].set(new_version),
        reads_since_fetch=arrays.reads_since_fetch.at[a, d].set(0),
        last_validate=arrays.last_validate.at[a, d].set(
            arrays.agent_actions[a]),
    )
    met = met._replace(n_writes=met.n_writes + 1)

    if content_enabled(cfg):
        # Chunk-granular commit: bump only the dirtied span's versions,
        # mark the dirty bitmap (monotone), and sync the writer's chunk
        # vector to the post-commit state (its base copy was fresh via
        # the RMW prologue and it authored the span itself).
        if wchunks is None:
            raise ValueError("content plane enabled but no write chunk "
                             "mask was supplied to _do_write")
        span = jnp.asarray(wchunks, bool)
        new_cv = jnp.where(span, arrays.chunk_version[d] + 1,
                           arrays.chunk_version[d])
        arrays = arrays._replace(
            chunk_version=arrays.chunk_version.at[d].set(new_cv),
            chunk_dirty=arrays.chunk_dirty.at[d].set(jnp.where(
                span, 1, arrays.chunk_dirty[d])),
            chunk_sync=arrays.chunk_sync.at[a, d].set(new_cv),
        )

    if cfg.strategy == EAGER:
        # Push-on-commit: pre-populate the caches of active sharers
        # (peers that held a valid copy at upgrade time), SS8.8.
        n_push = jnp.sum(peer_valid.astype(jnp.int32))
        col_state = jnp.where(peer_valid, _S, arrays.state[:, d])
        col_sync = jnp.where(peer_valid, new_version, arrays.last_sync[:, d])
        col_reads = jnp.where(peer_valid, 0, arrays.reads_since_fetch[:, d])
        col_val = jnp.where(peer_valid, arrays.agent_actions,
                            arrays.last_validate[:, d])
        arrays = arrays._replace(
            state=arrays.state.at[:, d].set(col_state),
            last_sync=arrays.last_sync.at[:, d].set(col_sync),
            reads_since_fetch=arrays.reads_since_fetch.at[:, d].set(col_reads),
            last_validate=arrays.last_validate.at[:, d].set(col_val),
        )
        met = met._replace(push_tokens=met.push_tokens + n_push * (
            cfg.artifact_tokens + SIGNAL_TOKENS))
    return arrays, met


class DecisionOutcome(NamedTuple):
    """Per-agent result of one serialized authority pass.

    The simulation discards this (only the aggregate ledger matters);
    the live coherence service (``repro.service``) uses it to answer
    each client's request: did the action trigger a coherence fill
    (content must be shipped) and which canonical version is the agent
    synced to after its serialization slot.
    """

    miss: jax.Array     # (n,) bool: action triggered a coherence fill
    version: jax.Array  # (n,) int32: last_sync[a, d] right after a's slot
    #: (n, C) bool: chunks shipped to each agent's fill this pass
    #: (content plane only; ``None`` when ``chunk_tokens == 0``).  The
    #: live broker assembles the actual delta payload from these.
    fetched_chunks: jax.Array | None = None


def apply_actions(cfg: ACSConfig, arrays: ACSArrays, met: ACSMetrics,
                  acts: jax.Array, arts: jax.Array, writes: jax.Array,
                  write_chunks=None):
    """Apply one serialized authority pass for a fixed action vector.

    ``acts``/``writes`` are (n,) bools, ``arts`` (n,) int32 - at most
    one action per agent, processed in ascending agent order (the
    authority's serialization order, same as the Pallas kernel).  This
    is the single source of the per-action semantics: ``tick`` samples
    actions and delegates here, and the coherence service's
    micro-batching layer (``repro.service.batching``) calls it with
    *real* client requests, so live decisions and simulated episodes
    execute literally the same code.

    ``write_chunks`` is the (n, C) bool per-agent dirty chunk mask
    (content plane only; ignored for reads).

    Returns ``(arrays, metrics, DecisionOutcome)``.
    """
    content = content_enabled(cfg)

    def agent_body(a, carry):
        arrays, met, out_miss, out_ver, out_chunks = carry
        act = acts[a]
        d = arts[a]
        is_write = writes[a]

        def do_act(args):
            arrays, met, out_miss, out_ver, out_chunks = args
            arrays = arrays._replace(
                agent_actions=arrays.agent_actions.at[a].add(1))
            fetches_before = met.n_fetches
            if content:
                # Snapshot at slot start: a fill (if any) ships exactly
                # the chunks stale *now* - the agent's own commit bumps
                # versions only after its prologue fill.
                stale_before = (arrays.chunk_version[d]
                                > arrays.chunk_sync[a, d])
            if cfg.strategy == BROADCAST:
                # Everything is already injected; actions are free.
                met = met._replace(
                    n_reads=met.n_reads + jnp.where(is_write, 0, 1),
                    n_writes=met.n_writes + jnp.where(is_write, 1, 0),
                    n_hits=met.n_hits + 1,
                )
                # Writes still bump the canonical version.
                arrays = arrays._replace(version=jnp.where(
                    is_write, arrays.version.at[d].add(1), arrays.version))
            else:
                wchunks = write_chunks[a] if content else None
                arrays, met = jax.lax.cond(
                    is_write,
                    lambda args: _do_write(cfg, *args, a, d,
                                           wchunks=wchunks),
                    lambda args: _do_read(cfg, *args, a, d),
                    (arrays, met))
            missed = met.n_fetches > fetches_before
            out_miss = out_miss.at[a].set(missed)
            out_ver = out_ver.at[a].set(arrays.last_sync[a, d])
            if content:
                out_chunks = out_chunks.at[a].set(
                    jnp.logical_and(missed, stale_before))
            return arrays, met, out_miss, out_ver, out_chunks

        return jax.lax.cond(act, do_act, lambda x: x,
                            (arrays, met, out_miss, out_ver, out_chunks))

    out_chunks0 = (jnp.zeros((cfg.n_agents, content_chunks(cfg)),
                             jnp.bool_) if content else None)
    arrays, met, miss, ver, fetched = jax.lax.fori_loop(
        0, cfg.n_agents, agent_body,
        (arrays, met, jnp.zeros((cfg.n_agents,), jnp.bool_),
         jnp.zeros((cfg.n_agents,), jnp.int32), out_chunks0))
    return arrays, met, DecisionOutcome(miss, ver, fetched)


def tick(cfg: ACSConfig, arrays: ACSArrays, met: ACSMetrics,
         key: jax.Array, step: jax.Array,
         volatility=None, p_act=None, rates: RateMatrices | None = None,
         locality=None):
    """One orchestration step for every agent (serialized authority).

    ``volatility`` and ``p_act`` default to the static config values but
    may be passed as *traced* scalars, so one compiled program can serve
    a whole ``(volatility x run)`` sweep grid (the fleet-scale path in
    ``repro.sim.engine``).  ``rates`` generalizes both to traced
    per-agent x per-artifact matrices (heterogeneous workloads,
    ``repro.sim.workloads``) and takes precedence when given.
    ``locality`` (content plane only) is the traced write-locality
    scalar, defaulting to ``cfg.write_locality``.  Strategy and the
    shape-determining fields stay static - they select code, not data.
    """
    volatility = cfg.volatility if volatility is None else volatility
    p_act = cfg.p_act if p_act is None else p_act
    acts, arts, writes = draw_actions(
        key, cfg.n_agents, cfg.n_artifacts, volatility, p_act, rates)
    wchunks = None
    if content_enabled(cfg):
        locality = cfg.write_locality if locality is None else locality
        wchunks = draw_write_chunks(key, cfg.n_agents,
                                    content_chunks(cfg), locality)

    if cfg.strategy == BROADCAST:
        # Full-state rebroadcast: every agent receives every artifact.
        inject = cfg.n_agents * cfg.n_artifacts * (
            cfg.artifact_tokens + SIGNAL_TOKENS)
        met = met._replace(broadcast_tokens=met.broadcast_tokens + inject)
        arrays = arrays._replace(
            state=jnp.full_like(arrays.state, _S),
            last_sync=jnp.broadcast_to(
                arrays.version[None, :], arrays.last_sync.shape),
            last_validate=jnp.broadcast_to(
                arrays.agent_actions[:, None], arrays.last_validate.shape),
        )

    if cfg.strategy == TTL:
        # Epoch lease refresh, driven by the orchestrator's logical event
        # clock (expected n*p_act action events per step).  All resident
        # subscriptions are refreshed each epoch; entries never expire
        # mid-epoch, so write activity is irrelevant (SS5.5 TTL).
        rate = (jnp.sum(rates.p_act) if rates is not None
                else cfg.n_agents * p_act)
        epoch_now = jnp.floor(rate * step.astype(jnp.float32)
                              / cfg.ttl_events).astype(jnp.int32)
        epoch_prev = jnp.where(
            step > 0,
            jnp.floor(rate * (step.astype(jnp.float32) - 1.0)
                      / cfg.ttl_events).astype(jnp.int32),
            -1)
        do_refresh = epoch_now > epoch_prev

        def refresh(args):
            arrays, met = args
            n_fill = cfg.n_agents * cfg.n_artifacts
            arrays = arrays._replace(
                state=jnp.full_like(arrays.state, _S),
                last_sync=jnp.broadcast_to(
                    arrays.version[None, :], arrays.last_sync.shape),
                reads_since_fetch=jnp.zeros_like(arrays.reads_since_fetch),
                last_validate=jnp.broadcast_to(
                    arrays.agent_actions[:, None],
                    arrays.last_validate.shape),
            )
            met = met._replace(
                fetch_tokens=met.fetch_tokens
                + n_fill * cfg.artifact_tokens,
                n_fetches=met.n_fetches + n_fill)
            return arrays, met

        arrays, met = jax.lax.cond(
            do_refresh, refresh, lambda x: x, (arrays, met))

    arrays, met, _ = apply_actions(cfg, arrays, met, acts, arts, writes,
                                   write_chunks=wchunks)
    return arrays, met


def run_episode(cfg: ACSConfig, key: jax.Array,
                volatility=None, p_act=None,
                rates: RateMatrices | None = None,
                locality=None) -> ACSMetrics:
    """Run a full S-step episode; returns final metrics.

    ``volatility`` / ``p_act`` / ``locality`` may be traced scalars and
    ``rates`` a traced heterogeneous rate-matrix triple (see ``tick``).
    """
    arrays = init_arrays(cfg)
    met = init_metrics()
    keys = jax.random.split(key, cfg.n_steps)

    def body(carry, inp):
        arrays, met = carry
        step, k = inp
        arrays, met = tick(cfg, arrays, met, k, step,
                           volatility=volatility, p_act=p_act,
                           rates=rates, locality=locality)
        return (arrays, met), None

    steps = jnp.arange(cfg.n_steps, dtype=jnp.int32)
    (arrays, met), _ = jax.lax.scan(body, (arrays, met), (steps, keys))
    return met
