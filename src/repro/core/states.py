"""MESI coherence states for the Artifact Coherence System (ACS).

The paper (Def. 1/2) maps hardware MESI states onto artifact authorization
states with the identity mapping phi.  We encode the four stable states as
small integers so the whole (agents x artifacts) state matrix is a dense
int32 array that JAX / Pallas can transition in bulk.

State encoding (order chosen so that ``state >= S`` is the validity
predicate T from Def. 1):

    I = 0   Invalid   - cached copy stale; coherence fill required
    S = 1   Shared    - valid here and possibly elsewhere
    E = 2   Exclusive - only copy, identical to authority; silent write ok
    M = 3   Modified  - only valid copy; authority stale
"""

from __future__ import annotations

import enum

import numpy as np


class MESIState(enum.IntEnum):
    """Stable coherence states, Sigma = {M, E, S, I} (paper Def. 1)."""

    I = 0  # noqa: E741 - paper notation
    S = 1
    E = 2
    M = 3


# Event alphabet E for the transition function delta (paper Def. 1).
class CoherenceEvent(enum.IntEnum):
    LOCAL_READ = 0      # agent reads its own cached copy
    LOCAL_WRITE = 1     # agent writes (requires E; produces M)
    UPGRADE = 2         # S -> E ownership acquisition (invalidates peers)
    FETCH = 3           # I -> S coherence fill from authority
    REMOTE_WRITE = 4    # peer acquired ownership -> our copy invalidated
    COMMIT = 5          # writer publishes: M -> S, version++


#: delta: Sigma x Event -> Sigma, dense table (rows = state, cols = event).
#: -1 marks transitions that are illegal in the protocol (guarded by the
#: caller; the model checker asserts they are never taken).
TRANSITION_TABLE = np.full((4, 6), -1, dtype=np.int32)
# LOCAL_READ: any valid state self-loops; reading from I is illegal
TRANSITION_TABLE[MESIState.S, CoherenceEvent.LOCAL_READ] = MESIState.S
TRANSITION_TABLE[MESIState.E, CoherenceEvent.LOCAL_READ] = MESIState.E
TRANSITION_TABLE[MESIState.M, CoherenceEvent.LOCAL_READ] = MESIState.M
# LOCAL_WRITE: requires exclusivity
TRANSITION_TABLE[MESIState.E, CoherenceEvent.LOCAL_WRITE] = MESIState.M
TRANSITION_TABLE[MESIState.M, CoherenceEvent.LOCAL_WRITE] = MESIState.M
# UPGRADE: S -> E (authority invalidates peers as a side effect)
TRANSITION_TABLE[MESIState.S, CoherenceEvent.UPGRADE] = MESIState.E
TRANSITION_TABLE[MESIState.E, CoherenceEvent.UPGRADE] = MESIState.E
# FETCH: I -> S
TRANSITION_TABLE[MESIState.I, CoherenceEvent.FETCH] = MESIState.S
# REMOTE_WRITE: every state collapses to I (the invalidation rule)
TRANSITION_TABLE[MESIState.I, CoherenceEvent.REMOTE_WRITE] = MESIState.I
TRANSITION_TABLE[MESIState.S, CoherenceEvent.REMOTE_WRITE] = MESIState.I
TRANSITION_TABLE[MESIState.E, CoherenceEvent.REMOTE_WRITE] = MESIState.I
TRANSITION_TABLE[MESIState.M, CoherenceEvent.REMOTE_WRITE] = MESIState.I
# COMMIT: M -> S (writer publishes and downgrades)
TRANSITION_TABLE[MESIState.M, CoherenceEvent.COMMIT] = MESIState.S


def is_valid(state: int) -> bool:
    """Validity predicate T (Def. 1): T(I)=0, T(S)=T(E)=T(M)=1."""
    return int(state) >= MESIState.S


def transition(state: int, event: int) -> int:
    """Scalar delta; raises on illegal transitions (protocol bug)."""
    nxt = int(TRANSITION_TABLE[int(state), int(event)])
    if nxt < 0:
        raise ValueError(
            f"illegal transition: delta({MESIState(state).name}, "
            f"{CoherenceEvent(event).name})"
        )
    return nxt


STATE_NAMES = {s.value: s.name for s in MESIState}
