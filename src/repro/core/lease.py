"""Lease-TTL recovery for orphaned exclusive locks (paper SS5.2 / AS3).

When the authority grants an Exclusive write lock it starts a lease timer
tau.  If COMMIT does not arrive within tau, the lock is treated as
orphaned: the authority reverts to the last committed version, invalidates
everyone, and releases the grant.  Liveness under agent crash at the cost
of losing in-progress writes.

Time here is a logical clock supplied by the caller (the orchestrator's
tick counter in simulation, wall-clock seconds in a deployment).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Lease:
    agent_id: str
    artifact_id: str
    granted_at: float
    ttl: float

    def expired(self, now: float) -> bool:
        return now - self.granted_at >= self.ttl


class LeaseTable:
    DEFAULT_TTL = 30.0  # paper default: 30 s

    def __init__(self, default_ttl: float = DEFAULT_TTL) -> None:
        self.default_ttl = default_ttl
        self._leases: Dict[str, Lease] = {}  # artifact_id -> lease

    def grant(self, agent_id: str, artifact_id: str, now: float,
              ttl: Optional[float] = None) -> Lease:
        if artifact_id in self._leases:
            raise RuntimeError(
                f"artifact {artifact_id!r} already leased to "
                f"{self._leases[artifact_id].agent_id!r}")
        lease = Lease(agent_id, artifact_id, now,
                      self.default_ttl if ttl is None else ttl)
        self._leases[artifact_id] = lease
        return lease

    def holder(self, artifact_id: str) -> Optional[str]:
        lease = self._leases.get(artifact_id)
        return lease.agent_id if lease else None

    def release(self, agent_id: str, artifact_id: str) -> None:
        lease = self._leases.get(artifact_id)
        if lease is None or lease.agent_id != agent_id:
            raise RuntimeError(
                f"{agent_id!r} does not hold a lease on {artifact_id!r}")
        del self._leases[artifact_id]

    def collect_expired(self, now: float) -> List[Lease]:
        """Remove and return all expired leases (authority recovery)."""
        expired = [l for l in self._leases.values() if l.expired(now)]
        for lease in expired:
            del self._leases[lease.artifact_id]
        return expired
