"""Config schema for the model zoo.

One frozen dataclass tree describes every assigned architecture; the
model assembly (``repro.models``) is entirely config-driven, so adding an
architecture is a config file, not code.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # always-on shared experts
    layer_stride: int = 1         # MoE every k-th layer (1 = all)
    layer_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_layer_dense: bool = False
    dense_d_ff: int = 0           # FFN dim for dense (non-MoE) layers
    # EP dispatch: >1 partitions tokens into per-data-shard dispatch
    # slices so the (E, C, d) buffer is built locally per shard instead
    # of being partial-summed across the whole data axis (the
    # dispatch-buffer all-reduce is the dominant MoE collective
    # otherwise).  Set to the mesh's DP degree by the launcher.
    dispatch_slices: int = 1
    dispatch_axes: tuple = ()     # mesh axes the slice dim maps onto


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0          # 0 = full-rank q projection (V2-lite)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)
    chunk: int = 128              # scan checkpointing chunk


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64          # low-rank dim of data-dependent decay
    mix_lora: int = 32            # low-rank dim of ddlerp token-shift
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """Modality frontend is a STUB per the assignment: input_specs()
    provides precomputed, already-projected patch embeddings."""
    n_image_tokens: int = 1024
    n_images: int = 1


@dataclasses.dataclass(frozen=True)
class AudioStubConfig:
    """Whisper conv frontend stub: precomputed frame embeddings."""
    frame_ratio: int = 1          # encoder frames per "seq_len" unit
    dec_ratio: int = 4            # decoder tokens = seq_len // dec_ratio


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    hidden_act: str = "silu"      # silu -> SwiGLU, gelu -> GeGLU
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    use_qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: embeddings * sqrt(d_model)
    max_seq_len: int = 8192
    dtype: str = "bfloat16"

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    vision: Optional[VisionStubConfig] = None
    audio: Optional[AudioStubConfig] = None

    # hybrid (jamba): one attention layer per `attn_period`, rest mamba
    attn_period: int = 0
    attn_offset: int = 0
    # vlm: cross-attention layer every `cross_attn_period` (llama-vision)
    cross_attn_period: int = 0
    cross_attn_offset: int = 3
    # enc-dec (whisper)
    encoder_layers: int = 0

    sub_quadratic: bool = False   # eligible for long_500k
    # SSPerf knob: pin the residual stream's batch dim to these mesh
    # axes at superblock boundaries (empty = let XLA choose layouts)
    residual_axes: tuple = ()

    def kv_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' for mixer at layer i."""
        if self.attn_period:
            return ("attn" if i % self.attn_period == self.attn_offset
                    else "mamba")
        if self.rwkv is not None:
            return "rwkv"
        return "attn"

    def is_cross_layer(self, i: int) -> bool:
        return (self.cross_attn_period > 0
                and i % self.cross_attn_period == self.cross_attn_offset)

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.first_layer_dense and i == 0:
            return False
        return (i % self.moe.layer_stride) == self.moe.layer_offset


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
