"""Layered coherence configuration: ONE config surface for the
protocol core, the service plane, and the shard topology.

Before this module, ``repro.core.acs.ACSConfig`` and
``repro.service.BrokerConfig`` had drifted into duplicated fields
(``chunk_tokens``, the staleness bound, strategy knobs) that had to be
kept in sync by hand.  :class:`CoherenceConfig` is now the single
source of truth, layered the way the system is layered:

  ``core``      protocol knobs every layer shares (strategy, artifact
                slot size, access-count K, staleness bound, chunk
                granularity) - projects onto ``ACSConfig``;
  ``service``   broker-plane knobs (batching window, decision backend,
                invariant checks, trace capture) - only the live
                service reads these;
  ``topology``  shard/host placement (K authority shards, per-host L1
                directories) - only the sharded authority plane reads
                these.

``BrokerConfig`` survives as a *thin frozen view* over the first two
layers (``CoherenceConfig.broker_view()``); constructing it directly
still works but warns once per process (deprecation shim - golden
ledgers stay byte-identical either way).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Tuple

from repro.core import acs


def shard_of_artifact(name: str, n_shards: int) -> int:
    """Stable hash-of-artifact shard routing (crc32, never Python's
    randomized ``hash``): the same artifact maps to the same authority
    shard in every process, so captured traces replay anywhere."""
    if n_shards <= 1:
        return 0
    return zlib.crc32(str(name).encode("utf-8")) % n_shards


@dataclasses.dataclass(frozen=True)
class CoherenceCore:
    """Protocol-core layer (projects onto ``acs.ACSConfig``)."""

    artifact_tokens: int = 4096
    strategy: str = "lazy"
    access_k: int = 8
    max_stale_steps: int = 0     # 0 disables K-staleness enforcement
    chunk_tokens: int = 0        # 0 = whole-artifact payloads

    def __post_init__(self):
        if self.strategy not in acs.STRATEGY_CODES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; known: "
                f"{sorted(acs.STRATEGY_CODES)}")
        if self.artifact_tokens <= 0:
            raise ValueError("artifact_tokens must be positive")


@dataclasses.dataclass(frozen=True)
class ServiceLayer:
    """Service-plane layer (the asyncio broker's own knobs)."""

    batch_window: float = 0.0    # extra coalescing wait (s)
    max_batch: int = 0           # 0 = up to n_agents requests
    backend: str = "auto"        # decision route: auto | scan | pallas
    check_invariants: bool = True
    capture_trace: bool = True
    latency_window: int = 1 << 20
    #: the telemetry plane (``repro.obs``): MESI perf counters, span
    #: tracing and the metrics-conformance oracle leg.  Off = the
    #: broker records nothing beyond the ledger/trace it always kept
    #: (the overhead bench measures the difference).
    telemetry: bool = True


@dataclasses.dataclass(frozen=True)
class ShardTopology:
    """Authority-plane topology: K directory shards + per-host L1s.

    ``n_shards``  partition the directory by artifact across K broker
                  shards (``shard_of_artifact``; SWMR survives sharding
                  because exclusivity is per-artifact).
    ``n_hosts``   L1 placement domains: agents map onto hosts
                  (round-robin unless ``placement`` pins them) and each
                  host keeps an L1 directory caching (version, chunk
                  versions, content) in front of the L2 authority, so
                  same-host agents exchange deltas without a
                  cross-shard hop.  1 = no L1 plane.
    ``placement``       optional explicit agent -> host map.
    ``assignment``      optional explicit artifact-index -> shard map
                        (defaults to hash routing).
    ``l1_max_version_lag``  invariant bound: a *valid* L1 entry may
                  never be observed more than this many versions behind
                  the authority (the L1-invalidation path keeps it at
                  0); a violation raises ``InvariantViolation``.
    """

    n_shards: int = 1
    n_hosts: int = 1
    placement: Tuple[int, ...] = ()
    assignment: Tuple[int, ...] = ()
    l1_max_version_lag: int = 0

    def __post_init__(self):
        if self.n_shards < 1 or self.n_hosts < 1:
            raise ValueError("n_shards and n_hosts must be >= 1")
        if self.l1_max_version_lag < 0:
            raise ValueError("l1_max_version_lag must be >= 0")
        if any(s < 0 or s >= self.n_shards for s in self.assignment):
            raise ValueError(
                f"assignment entries must be in [0, {self.n_shards})")
        if any(h < 0 or h >= self.n_hosts for h in self.placement):
            raise ValueError(
                f"placement entries must be in [0, {self.n_hosts})")

    @property
    def trivial(self) -> bool:
        """True when the topology collapses to the single-broker,
        no-L1 deployment (the pre-sharding behavior)."""
        return self.n_shards == 1 and self.n_hosts == 1

    def shard_of(self, artifact_index: int, artifact_name: str) -> int:
        if self.assignment:
            return int(self.assignment[artifact_index])
        return shard_of_artifact(artifact_name, self.n_shards)

    def host_of(self, agent: int) -> int:
        if self.placement:
            return int(self.placement[agent])
        return int(agent) % self.n_hosts


_CORE_FIELDS = {f.name for f in dataclasses.fields(CoherenceCore)}
_SERVICE_FIELDS = {f.name for f in dataclasses.fields(ServiceLayer)}
_TOPOLOGY_FIELDS = {f.name for f in dataclasses.fields(ShardTopology)}
#: flat-kwarg aliases accepted by :meth:`CoherenceConfig.make`
_ALIASES = {"shards": "n_shards", "hosts": "n_hosts"}


@dataclasses.dataclass(frozen=True)
class CoherenceConfig:
    """The layered config: core -> service -> shard topology."""

    n_agents: int
    artifacts: Tuple[str, ...]
    core: CoherenceCore = CoherenceCore()
    service: ServiceLayer = ServiceLayer()
    topology: ShardTopology = ShardTopology()

    def __post_init__(self):
        object.__setattr__(self, "artifacts", tuple(self.artifacts))
        if self.n_agents < 1:
            raise ValueError("n_agents must be >= 1")
        if len(set(self.artifacts)) != len(self.artifacts):
            raise ValueError("duplicate artifact ids")
        if self.topology.assignment and len(
                self.topology.assignment) != len(self.artifacts):
            raise ValueError(
                f"assignment covers {len(self.topology.assignment)} "
                f"artifacts but {len(self.artifacts)} are registered")
        if self.topology.placement and len(
                self.topology.placement) != self.n_agents:
            raise ValueError(
                f"placement covers {len(self.topology.placement)} "
                f"agents but n_agents={self.n_agents}")
        if self.topology.n_shards > 1 and self.core.max_stale_steps > 0:
            # per-shard action clocks diverge from the global clock, so
            # simulator-style K-staleness is not well-defined across
            # shards; the L1 plane carries its own version-lag bound.
            raise ValueError(
                "sharded authority does not support simulator "
                "K-staleness (max_stale_steps > 0); bound L1 staleness "
                "with topology.l1_max_version_lag instead")

    # ------------------------------------------------------ construction
    @classmethod
    def make(cls, n_agents: int, artifacts, **knobs) -> "CoherenceConfig":
        """Build a layered config from flat kwargs, routing each knob
        to its layer by field name (``shards``/``hosts`` are accepted
        as aliases for ``n_shards``/``n_hosts``)."""
        core_kw, svc_kw, topo_kw = {}, {}, {}
        for key, value in knobs.items():
            name = _ALIASES.get(key, key)
            if name in _CORE_FIELDS:
                core_kw[name] = value
            elif name in _SERVICE_FIELDS:
                svc_kw[name] = value
            elif name in _TOPOLOGY_FIELDS:
                topo_kw[name] = value
            else:
                raise TypeError(
                    f"unknown coherence knob {key!r}; core fields: "
                    f"{sorted(_CORE_FIELDS)}, service: "
                    f"{sorted(_SERVICE_FIELDS)}, topology: "
                    f"{sorted(_TOPOLOGY_FIELDS)}")
        return cls(n_agents=n_agents, artifacts=tuple(artifacts),
                   core=CoherenceCore(**core_kw),
                   service=ServiceLayer(**svc_kw),
                   topology=ShardTopology(**topo_kw))

    # ----------------------------------------------------- flat core view
    # Read-only pass-throughs so code holding a broker handle can read
    # the cost-model knobs without caring which config flavor (flat
    # BrokerConfig vs layered) the topology handed it.

    @property
    def artifact_tokens(self) -> int:
        return self.core.artifact_tokens

    @property
    def strategy(self) -> str:
        return self.core.strategy

    @property
    def access_k(self) -> int:
        return self.core.access_k

    @property
    def max_stale_steps(self) -> int:
        return self.core.max_stale_steps

    @property
    def chunk_tokens(self) -> int:
        return self.core.chunk_tokens

    # ------------------------------------------------------- projections
    def acs_config(self, n_steps: int = 1) -> acs.ACSConfig:
        """Project the core layer onto the simulator's static config."""
        return acs.ACSConfig(
            n_agents=self.n_agents, n_artifacts=len(self.artifacts),
            artifact_tokens=self.core.artifact_tokens, n_steps=n_steps,
            strategy=acs.STRATEGY_CODES[self.core.strategy],
            access_k=self.core.access_k,
            max_stale_steps=self.core.max_stale_steps,
            chunk_tokens=self.core.chunk_tokens)

    def broker_view(self):
        """The flat frozen ``BrokerConfig`` view of the core + service
        layers (what a single broker shard consumes).  Constructed
        through the blessed path, so no deprecation warning fires."""
        from repro.service.broker import BrokerConfig
        return BrokerConfig._from_layers(self)

    # ---------------------------------------------------------- topology
    def shard_of(self, artifact_index: int) -> int:
        return self.topology.shard_of(
            artifact_index, self.artifacts[artifact_index])

    def artifact_shards(self) -> Tuple[int, ...]:
        """Per-artifact shard id, in artifact-index order."""
        return tuple(self.shard_of(d) for d in range(len(self.artifacts)))

    def shard_artifact_indices(self) -> Tuple[Tuple[int, ...], ...]:
        """Global artifact indices owned by each shard (len n_shards;
        shards with no artifacts get an empty tuple)."""
        owned = [[] for _ in range(self.topology.n_shards)]
        for d, s in enumerate(self.artifact_shards()):
            owned[s].append(d)
        return tuple(tuple(o) for o in owned)

    def shard_view(self, shard: int) -> "CoherenceConfig":
        """The single-shard CoherenceConfig a sub-broker runs with
        (that shard's artifacts only, trivial topology)."""
        cols = self.shard_artifact_indices()[shard]
        return dataclasses.replace(
            self, artifacts=tuple(self.artifacts[d] for d in cols),
            topology=ShardTopology())


def from_broker_fields(n_agents: int, artifacts, *, artifact_tokens,
                       strategy, access_k, max_stale_steps, batch_window,
                       max_batch, backend, check_invariants,
                       capture_trace, latency_window, chunk_tokens,
                       telemetry: bool = True,
                       topology: Optional[ShardTopology] = None,
                       ) -> CoherenceConfig:
    """Lift legacy flat ``BrokerConfig`` fields into the layered config
    (the deprecation shim's upgrade path)."""
    return CoherenceConfig(
        n_agents=n_agents, artifacts=tuple(artifacts),
        core=CoherenceCore(
            artifact_tokens=artifact_tokens, strategy=strategy,
            access_k=access_k, max_stale_steps=max_stale_steps,
            chunk_tokens=chunk_tokens),
        service=ServiceLayer(
            batch_window=batch_window, max_batch=max_batch,
            backend=backend, check_invariants=check_invariants,
            capture_trace=capture_trace, latency_window=latency_window,
            telemetry=telemetry),
        topology=topology or ShardTopology())
