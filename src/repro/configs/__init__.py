"""Architecture configs: assignment table entries + registry."""

from repro.configs.base import (ModelConfig, MoEConfig, MLAConfig,
                                MambaConfig, RWKVConfig, ShapeConfig,
                                SHAPES, VisionStubConfig, AudioStubConfig)
from repro.configs.registry import (ARCHS, get, register, smoke_config,
                                    input_specs, shapes_for,
                                    n_params_analytic, n_active_params)

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "MambaConfig", "RWKVConfig",
    "ShapeConfig", "SHAPES", "VisionStubConfig", "AudioStubConfig",
    "ARCHS", "get", "register", "smoke_config", "input_specs",
    "shapes_for", "n_params_analytic", "n_active_params",
]
