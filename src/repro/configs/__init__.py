"""Architecture configs (assignment table entries + registry) and the
layered coherence-config surface (core -> service -> shard topology)."""

from repro.configs.base import (ModelConfig, MoEConfig, MLAConfig,
                                MambaConfig, RWKVConfig, ShapeConfig,
                                SHAPES, VisionStubConfig, AudioStubConfig)
from repro.configs.registry import (ARCHS, get, register, smoke_config,
                                    input_specs, shapes_for,
                                    n_params_analytic, n_active_params)
from repro.configs.coherence import (CoherenceConfig, CoherenceCore,
                                     ServiceLayer, ShardTopology,
                                     shard_of_artifact)

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "MambaConfig", "RWKVConfig",
    "ShapeConfig", "SHAPES", "VisionStubConfig", "AudioStubConfig",
    "ARCHS", "get", "register", "smoke_config", "input_specs",
    "shapes_for", "n_params_analytic", "n_active_params",
    "CoherenceConfig", "CoherenceCore", "ServiceLayer", "ShardTopology",
    "shard_of_artifact",
]
