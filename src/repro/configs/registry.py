"""Architecture registry: ``--arch <id>`` lookup, reduced smoke configs,
and ShapeDtypeStruct input_specs for the dry-run (no allocation).

Every config matches the assignment table verbatim; per-arch notes (and
any interpretation of ambiguous entries) are inline.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (AudioStubConfig, MambaConfig, MLAConfig,
                                ModelConfig, MoEConfig, RWKVConfig,
                                ShapeConfig, SHAPES, VisionStubConfig)

ARCHS: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; one of {sorted(ARCHS)}"
                       ) from None


# ------------------------- assigned architectures ---------------------

COMMAND_R_35B = register(ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    hidden_act="silu", norm="layernorm", use_bias=False,
    rope_theta=8e6, tie_embeddings=True,
))

GEMMA_2B = register(ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=256000, head_dim=256,
    hidden_act="gelu",  # GeGLU
    tie_embeddings=True, embed_scale=True,
))

QWEN3_1_7B = register(ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab_size=151936, head_dim=128,
    use_qk_norm=True, rope_theta=1e6, tie_embeddings=True,
))

YI_9B = register(ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab_size=64000,
))

OLMOE_1B_7B = register(ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
))

# Assignment says "MoE 64e top-6 ... 2 shared+160 routed top-6"; the two
# clauses conflict.  We follow the published V2-Lite config (arXiv:
# 2405.04434): 64 routed experts top-6 + 2 shared, first layer dense.
DEEPSEEK_V2_LITE = register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  first_layer_dense=True, dense_d_ff=10944),
))

JAMBA_1_5_LARGE = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    attn_period=8, attn_offset=4,   # 1 attn : 7 mamba per 8-block
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576,
                  layer_stride=2, layer_offset=1, dense_d_ff=24576),
    sub_quadratic=True,
))

RWKV6_1_6B = register(ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    rwkv=RWKVConfig(head_size=64),
    sub_quadratic=True,
))

LLAMA32_VISION_90B = register(ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    rope_theta=5e5,
    cross_attn_period=5, cross_attn_offset=3,  # 20 cross layers
    vision=VisionStubConfig(n_image_tokens=1024, n_images=1),
))

# Published vocab is 51,865; padded to 51,968 (= 16 x 3,248) so the
# embedding/lm-head rows shard evenly over the model axis - standard
# Megatron-style vocab padding (pad logits are never selected).
WHISPER_MEDIUM = register(ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51968,
    norm="layernorm", use_bias=True, hidden_act="gelu",
    encoder_layers=24,
    audio=AudioStubConfig(dec_ratio=4),
))


# ------------------------- reduced smoke configs ----------------------

def smoke_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: few layers, narrow
    width, small vocab/experts - but the SAME structural pattern."""
    full = get(name)
    overrides: dict = dict(
        n_layers=min(full.n_layers, 4),
        d_model=128, n_heads=4,
        n_kv_heads=min(full.n_kv_heads, 2),
        d_ff=256, vocab_size=512, head_dim=32, max_seq_len=128,
        dtype="float32",
    )
    if full.family == "vlm":
        overrides.update(n_layers=5, cross_attn_period=5,
                         cross_attn_offset=3,
                         vision=VisionStubConfig(n_image_tokens=16))
    if full.moe is not None:
        # capacity_factor = n_experts -> no token drops, so smoke tests
        # can assert exact prefill+decode == full-forward consistency
        # (capacity dropping is batch-dependent by design at 1.25).
        overrides["moe"] = dataclasses.replace(
            full.moe, n_experts=8,
            top_k=min(full.moe.top_k, 4), d_expert=64,
            dense_d_ff=256 if full.moe.dense_d_ff else 0,
            capacity_factor=8.0)
    if full.mla is not None:
        overrides["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=32,
                                     qk_rope_head_dim=16, v_head_dim=32)
    if full.mamba is not None:
        overrides.update(n_layers=8,
                         mamba=MambaConfig(d_state=8, d_conv=4, expand=2,
                                           chunk=16))
    if full.rwkv is not None:
        overrides["rwkv"] = RWKVConfig(head_size=32, decay_lora=16,
                                       mix_lora=8, chunk=16)
        overrides["n_heads"] = 4
    if full.encoder_layers:
        overrides["encoder_layers"] = 2
        overrides["n_layers"] = 2
    return dataclasses.replace(full, **overrides,
                               name=f"{full.name}-smoke")


# ----------------------------- input specs ----------------------------

def token_dtype():
    return jnp.int32


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this
    (arch x shape) cell - weak-type-correct, shardable, no allocation.

    train:   {tokens, labels [, vision_embeds | frames]}
    prefill: {tokens [, vision_embeds | frames]}
    decode:  {token, cache} built via jax.eval_shape of init_cache
    """
    from repro.models import transformer as tf

    b, s = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.bfloat16
    d = cfg.d_model

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    if shape.kind == "train":
        specs = {"tokens": tok(b, _dec_len(cfg, s)),
                 "labels": tok(b, _dec_len(cfg, s))}
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision.n_image_tokens, d), f32)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, d), f32)
        return specs

    if shape.kind == "prefill":
        specs = {"tokens": tok(b, _dec_len(cfg, s))}
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision.n_image_tokens, d), f32)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, d), f32)
        return specs

    # decode: one new token against a seq_len-deep cache
    ctx = _ctx_len(cfg, s)
    cache_spec = jax.eval_shape(
        lambda: tf.init_cache(cfg, b, s, ctx_len=ctx))
    return {"token": tok(b, 1), "cache": cache_spec}


def _dec_len(cfg: ModelConfig, s: int) -> int:
    """Decoder-token length for a nominal seq_len (enc-dec split)."""
    if cfg.family == "audio":
        return max(128, s // cfg.audio.dec_ratio)
    return s


def _ctx_len(cfg: ModelConfig, s: int) -> int:
    """Cross-attention context length at decode time."""
    if cfg.family == "vlm":
        return cfg.vision.n_image_tokens
    if cfg.family == "audio":
        return min(s, 4096)
    return 0


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The assigned shape set for this arch, with documented skips:
    long_500k only for sub-quadratic archs (SSM/hybrid)."""
    out = []
    for shp in SHAPES.values():
        if shp.name == "long_500k" and not cfg.sub_quadratic:
            continue  # full-attention arch: documented skip
        out.append(shp)
    return out


def n_params_analytic(cfg: ModelConfig) -> int:
    """Total parameter count (computed from shapes, no allocation)."""
    from repro.models import transformer as tf
    shapes = jax.eval_shape(
        lambda k: tf.init_params(cfg, k), jax.random.PRNGKey(0))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def n_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k + shared experts only)."""
    total = n_params_analytic(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    # subtract the inactive routed experts' weights
    n_moe_layers = sum(1 for i in range(cfg.n_layers)
                       if cfg.is_moe_layer(i))
    per_expert = 3 * cfg.d_model * m.d_expert
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive
