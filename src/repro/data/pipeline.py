"""Deterministic sharded synthetic data pipeline.

Design mirrors a production loader:
  * a *global* sample space indexed by (step, position-in-global-batch);
  * each data-parallel host materializes only its shard (host_id,
    n_hosts), so 1000-node runs never duplicate IO;
  * background prefetch thread keeps ``prefetch`` batches ready (overlap
    host-side generation with device compute);
  * restart-safe: the stream is a pure function of (seed, step), so
    resuming from checkpoint step k reproduces the exact remaining
    stream - no loader state to checkpoint.

Synthetic distribution: Zipf-ish token draw (heavy-tailed like real
corpora) from a deterministic counter-based generator (numpy
Philox), with labels = inputs (standard next-token LM objective uses the
shifted view inside the loss).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 20260305
    zipf_a: float = 1.2
    prefetch: int = 2


class SyntheticLMStream:
    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 n_hosts: int = 1) -> None:
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step, host): the elastic-restart
        contract."""
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[step, self.host_id, 0, 0]))
        z = rng.zipf(cfg.zipf_a, size=(self.local_batch, cfg.seq_len))
        tokens = (z % (cfg.vocab_size - 1)).astype(np.int32) + 1
        return {"tokens": tokens, "labels": tokens.copy()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch wrapper (host-side pipelining)."""

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0,
                 prefetch: Optional[int] = None) -> None:
        self.stream = stream
        self.start_step = start_step
        depth = prefetch or stream.cfg.prefetch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self.start_step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> tuple[int, dict]:
        # bounded wait: a dead worker must surface as an error, not a
        # silent hang of the train loop
        return self._q.get(timeout=60.0)

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
