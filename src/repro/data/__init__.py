from repro.data.pipeline import (DataConfig, SyntheticLMStream,
                                 PrefetchLoader)

__all__ = ["DataConfig", "SyntheticLMStream", "PrefetchLoader"]
