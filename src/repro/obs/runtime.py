"""jit / Pallas instrumentation: process-global compile-event log.

The decision deciders are cached per static config at module level
(``repro.service.batching._scan_decider``'s ``lru_cache`` + jax's own
jit cache), so compile accounting is inherently *process*-scoped, not
per-broker - the same pattern as the sweep engine's trace counter
(``repro.sim.engine.trace_count``): a Python side effect placed inside
the traced function body runs exactly once per (re)trace and never
during compiled execution.

``note_compile`` is that side effect for the service plane;
``note_warmup`` records the measured first-call wall time of a decision
route (the closest portable proxy for Pallas route compilation, whose
lowering happens inside ``pallas_call`` where we own no Python body).
Telemetry snapshots read the log; the conformance leg excludes it
(compiles are process-global and timing-dependent by nature).
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional

_LOCK = threading.Lock()
_EVENTS: List[dict] = []
#: perf_counter epoch for event timestamps (Chrome trace alignment)
_T0 = time.perf_counter()


def epoch() -> float:
    """perf_counter value this module's event timestamps are relative
    to (for aligning compile events onto a span recorder's axis)."""
    return _T0


def note_compile(route: str, label: str = "") -> None:
    """Record one decision-program (re)trace.  Call from *inside* the
    traced function body so it fires at trace time only."""
    with _LOCK:
        _EVENTS.append({"kind": "trace", "route": route, "label": label,
                        "t_s": time.perf_counter() - _T0,
                        "dur_s": 0.0})


def note_warmup(route: str, dur_s: float, label: str = "") -> None:
    """Record a decision route's measured first-call wall time (compile
    + first dispatch)."""
    with _LOCK:
        _EVENTS.append({"kind": "warmup", "route": route, "label": label,
                        "t_s": time.perf_counter() - _T0 - dur_s,
                        "dur_s": dur_s})


def compile_events() -> List[dict]:
    with _LOCK:
        return [dict(e) for e in _EVENTS]


def compile_count(route: Optional[str] = None,
                  kind: str = "trace") -> int:
    with _LOCK:
        return sum(1 for e in _EVENTS
                   if e["kind"] == kind
                   and (route is None or e["route"] == route))


def reset_compile_log() -> None:
    with _LOCK:
        _EVENTS.clear()
