"""MetricsConformance: the telemetry plane's own oracle leg.

Production metrics pipelines are trusted, never *checked*.  This repo
can do better: every replayable counter in the live registry is
recomputed from the broker's captured ``ServiceTrace`` - the committed
decision history, replayed step by step through a **fresh**
``BatchDecider`` + ``Telemetry`` - and asserted **bit-identical**,
label set by label set, to what the live async path recorded.

What this catches: any scheduling, attribution or accounting bug in
the async layer (double-counted batch, dropped increment, wrong shard
label, detector state corrupted by interleaving) shows up as a counter
mismatch.  What it deliberately shares: the counter *derivation* code
(``Telemetry.record_batch``) is the same on both sides - semantic
correctness of the decisions themselves is the four-way differential
oracle's job (``trace.verify_broker``), which the service test tier
already runs on every family.  The two legs compose: the oracle proves
the history is right; this leg proves the registry reflects exactly
that history.

Wall-clock metrics (decide seconds, latency, queue depth), spans and
compile events are live-only by construction and excluded.
"""

from __future__ import annotations

import numpy as np

#: counters compared bit-identically, every label set.
CONFORMANCE_COUNTERS = (
    "coh_batches_total",
    "coh_requests_total",
    "coh_reads_total",
    "coh_writes_total",
    "coh_fetch_tokens_total",
    "coh_signal_tokens_total",
    "coh_push_tokens_total",
    "coh_fills_total",
    "coh_hits_total",
    "coh_invalidation_signals_total",
    "coh_invalidation_events_total",
    "coh_invalidation_storms_total",
    "coh_writer_flips_total",
    "coh_pingpong_alternations_total",
    "coh_state_entries_total",
    "coh_state_occupancy_total",
    "coh_wire_delta_bytes_total",
    "coh_wire_full_bytes_total",
    "coh_chunks_fetched_total",
)
#: histograms whose exact (count, sum) integers are compared.
CONFORMANCE_HISTOGRAMS = ("coh_batch_size", "coh_staleness_at_serve")


class MetricsConformanceError(AssertionError):
    """A live registry counter diverged from its trace replay."""


def _replay_steps(tel, steps, cfg, names, n_agents: int,
                  shard_label: int) -> None:
    """Drive one authority's step sub-stream through a fresh decider
    into ``tel`` (shard-local artifact index space)."""
    from repro.content.chunks import n_chunks as _n_chunks
    from repro.obs.telemetry import BatchObservation
    from repro.service.batching import BatchDecider

    decider = BatchDecider(cfg, "scan")
    C = (_n_chunks(cfg.artifact_tokens, cfg.chunk_tokens)
         if cfg.chunk_tokens > 0 else 0)
    for rec in steps:
        acts = np.zeros(n_agents, bool)
        arts = np.zeros(n_agents, np.int32)
        writes = np.zeros(n_agents, bool)
        mask = np.zeros((n_agents, C), bool) if C else None
        chunks = rec.chunks or ((),) * len(rec.agents)
        for agent, d, w, ch in zip(rec.agents, rec.arts, rec.writes,
                                   chunks):
            acts[agent] = True
            arts[agent] = d
            writes[agent] = w
            if mask is not None and w:
                mask[agent, list(ch)] = True
        state_before = np.asarray(decider.arrays.state, np.int32).copy()
        decision = decider.decide(acts, arts, writes,
                                  write_chunks=mask)
        tel.record_batch(BatchObservation(
            names=names, acts=acts, arts=arts, writes=writes,
            miss=decision.miss, version=decision.version,
            ledger_delta=decision.ledger_delta,
            state_before=state_before,
            state_after=np.asarray(decider.arrays.state, np.int32),
            ver_after=np.asarray(decider.arrays.version, np.int64),
            wire_delta=decision.wire_delta,
            shard=shard_label, live=False))


def replay_telemetry(trace: ServiceTrace, names,
                     storm_threshold=None):
    """Rebuild a Telemetry registry purely from a captured trace.

    ``names`` is the global artifact-name tuple (the trace stores only
    indices; labels need names).  Sharded traces replay shard by shard
    - per-artifact serialization order is preserved because every
    artifact's history lives entirely inside one shard's sub-stream.
    Returns the fresh :class:`repro.obs.telemetry.Telemetry`.
    """
    from repro.core import acs
    from repro.obs.telemetry import Telemetry

    names = tuple(names)
    if len(names) != trace.n_artifacts:
        raise ValueError(
            f"{len(names)} artifact names for a {trace.n_artifacts}"
            f"-artifact trace")
    tel = Telemetry(trace.n_agents, strategy=trace.strategy,
                    backend="scan", n_shards=trace.n_shards,
                    storm_threshold=storm_threshold)

    def cfg_for(m: int) -> acs.ACSConfig:
        return acs.ACSConfig(
            n_agents=trace.n_agents, n_artifacts=m,
            artifact_tokens=trace.artifact_tokens, n_steps=1,
            strategy=acs.STRATEGY_CODES[trace.strategy],
            access_k=trace.access_k,
            max_stale_steps=trace.max_stale_steps,
            chunk_tokens=trace.chunk_tokens)

    if trace.n_shards <= 1:
        _replay_steps(tel, trace.steps, cfg_for(trace.n_artifacts),
                      names, trace.n_agents, shard_label=0)
        return tel

    for shard in range(trace.n_shards):
        cols = [d for d, s in enumerate(trace.artifact_shards)
                if s == shard]
        if not cols:
            continue
        local = {d: i for i, d in enumerate(cols)}
        sub_steps = []
        for rec in trace.steps:
            if rec.shard != shard:
                continue
            sub_steps.append(rec.__class__(
                agents=rec.agents,
                arts=tuple(local[d] for d in rec.arts),
                writes=rec.writes, miss=rec.miss, version=rec.version,
                latency_s=rec.latency_s, chunks=rec.chunks,
                shard=shard, decide_s=rec.decide_s,
                batch_size=rec.batch_size))
        _replay_steps(tel, sub_steps, cfg_for(len(cols)),
                      tuple(names[d] for d in cols), trace.n_agents,
                      shard_label=shard)
    return tel


def _compare(live_reg, replay_reg, name: str) -> int:
    """Bit-compare every label set of one counter; return cells seen."""
    live = live_reg.counter_cells(name)
    rep = replay_reg.counter_cells(name)
    if live != rep:
        only_live = {k: v for k, v in live.items()
                     if rep.get(k) != v}
        only_rep = {k: v for k, v in rep.items()
                    if live.get(k) != v}
        raise MetricsConformanceError(
            f"registry counter {name} diverged from trace replay:\n"
            f"  live   : {only_live}\n  replay : {only_rep}")
    return len(live)


def check_metrics_conformance(broker, name: str = "metrics") -> dict:
    """Replay the broker's captured trace through a fresh telemetry
    plane and assert every replayable counter (and exact histogram
    count/sum) bit-identical to the live registry.

    Works for both broker flavors; sharded brokers additionally get
    the L1/L2 attribution-conservation check (L1 counters depend on
    live content equality, so they are conservation-checked against
    the trace's read misses rather than replayed).  Returns a report
    dict; raises :class:`MetricsConformanceError` on any divergence.
    """
    tel = getattr(broker, "telemetry", None)
    if tel is None:
        raise ValueError(
            "broker runs with telemetry disabled; metrics conformance "
            "needs the live registry (telemetry=True)")
    capture = (broker.config.service.capture_trace
               if getattr(broker, "is_sharded", False)
               else broker.config.capture_trace)
    if not capture:
        raise ValueError(
            "broker was started with capture_trace=False; metrics "
            "conformance replays the captured ServiceTrace")
    trace = broker.trace
    if broker.n_batches != trace.n_steps:
        raise ValueError(
            f"trace has {trace.n_steps} steps but the broker committed "
            f"{broker.n_batches} batches - partial capture cannot be "
            f"verified")

    replayed = replay_telemetry(trace, broker.names,
                                storm_threshold=tel.storm_threshold)
    cells = 0
    for counter in CONFORMANCE_COUNTERS:
        cells += _compare(tel.registry, replayed.registry, counter)
    for hist in CONFORMANCE_HISTOGRAMS:
        live = tel.registry.histogram_totals(hist)
        rep = replayed.registry.histogram_totals(hist)
        if live != rep:
            raise MetricsConformanceError(
                f"registry histogram {hist} (count, sum) diverged "
                f"from trace replay:\n  live   : {live}\n"
                f"  replay : {rep}")
        cells += len(live)

    report = {
        "name": name,
        "bit_exact": True,
        "counters_compared": len(CONFORMANCE_COUNTERS),
        "histograms_compared": len(CONFORMANCE_HISTOGRAMS),
        "label_cells_compared": cells,
        "n_steps": trace.n_steps,
        "n_actions": trace.n_actions,
    }
    if getattr(broker, "is_sharded", False):
        read_misses = sum(
            sum(1 for w, miss in zip(s.writes, s.miss)
                if miss and not w) for s in trace.steps)
        reg = tel.registry
        attributed = (reg.counter_total("coh_l1_fills_total")
                      + reg.counter_total("coh_l2_fills_total"))
        if attributed != read_misses:
            raise MetricsConformanceError(
                f"L1/L2 fill counters lost fills: {attributed} "
                f"attributed vs {read_misses} read misses in the trace")
        if (reg.counter_total("coh_l1_fills_total")
                != broker.l1_wire["l1_fills"]
                or reg.counter_total("coh_l2_fills_total")
                != broker.l1_wire["l2_fills"]):
            raise MetricsConformanceError(
                f"L1 registry counters diverged from the broker's "
                f"l1_wire ledger: registry "
                f"({reg.counter_total('coh_l1_fills_total')}, "
                f"{reg.counter_total('coh_l2_fills_total')}) vs "
                f"{broker.l1_wire}")
        report["l1_fills_conserved"] = True
    return report
