"""``repro.obs`` - the coherence telemetry plane.

MESI perf counters, per-request span tracing and oracle-verified
metrics for the live coherence service:

  * :mod:`repro.obs.registry` - exact counters / gauges / ring-buffer
    histograms with Prometheus text + JSON snapshot exposition;
  * :mod:`repro.obs.telemetry` - the per-authority ``Telemetry``
    facade: one ``record_batch`` hook per committed micro-batch feeds
    the MESI detectors (invalidation events/storms, ping-pong,
    staleness-at-serve, state occupancy) and the span recorder;
  * :mod:`repro.obs.spans` - Chrome trace-event export
    (``chrome://tracing`` / Perfetto flame graphs);
  * :mod:`repro.obs.runtime` - process-global jit/Pallas compile-event
    log (trace-time side-effect accounting, engine-style);
  * :mod:`repro.obs.stats` - the unified ``stats()`` schema both
    broker flavors serve (with the legacy flat-key deprecation shim);
  * :mod:`repro.obs.conformance` - the ``MetricsConformance`` oracle
    leg: every replayable counter recomputed from the captured
    ``ServiceTrace`` and asserted bit-identical to the live registry.

See ``docs/observability.md`` for the metric catalog and the
MESI-analogue rationale behind each counter.
"""

from repro.obs.conformance import (CONFORMANCE_COUNTERS,
                                   CONFORMANCE_HISTOGRAMS,
                                   MetricsConformanceError,
                                   check_metrics_conformance,
                                   replay_telemetry)
from repro.obs.registry import (Counter, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.runtime import (compile_count, compile_events,
                               note_compile, note_warmup,
                               reset_compile_log)
from repro.obs.spans import Span, SpanRecorder
from repro.obs.stats import LEGACY_KEYS, StatsView, unified_stats
from repro.obs.telemetry import BatchObservation, Telemetry

__all__ = [
    "BatchObservation",
    "CONFORMANCE_COUNTERS",
    "CONFORMANCE_HISTOGRAMS",
    "Counter",
    "Gauge",
    "Histogram",
    "LEGACY_KEYS",
    "MetricsConformanceError",
    "MetricsRegistry",
    "Span",
    "SpanRecorder",
    "StatsView",
    "Telemetry",
    "check_metrics_conformance",
    "compile_count",
    "compile_events",
    "note_compile",
    "note_warmup",
    "replay_telemetry",
    "reset_compile_log",
    "unified_stats",
]
