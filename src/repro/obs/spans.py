"""Per-request span tracing with Chrome trace-event export.

Every request the broker resolves becomes one *complete* span
(``ph: "X"``) covering submit -> respond, with the phase breakdown
(queue wait, decide, apply) attached as args; every committed
micro-batch becomes one ``decide`` span on the authority lane.  Spans
are recorded *at resolve time* from timestamps the broker already
holds, so there is no open-span bookkeeping on the hot path - one
append into a bounded ring per request.

``chrome_trace()`` dumps the ring in the Chrome trace-event JSON format
(load in ``chrome://tracing`` / Perfetto): ``pid`` is the authority
shard, ``tid`` the agent (or ``authority`` for batch spans), ``ts`` /
``dur`` are microseconds relative to the recorder's epoch.
"""

from __future__ import annotations

import collections
import json
import time
from typing import NamedTuple


class Span(NamedTuple):
    name: str        # e.g. "read artifact-3" / "decide"
    cat: str         # "request" | "batch" | "compile"
    ts_s: float      # start, seconds on the recorder's perf_counter axis
    dur_s: float
    pid: int         # authority shard
    tid: object      # agent id, or "authority"
    args: dict


class SpanRecorder:
    """Bounded ring of completed spans.

    ``n_recorded`` counts every span ever added (exact, survives ring
    wrap) - the span-lifecycle tests assert it equals the number of
    resolved requests plus committed batches.
    """

    def __init__(self, capacity: int = 1 << 14) -> None:
        self.capacity = capacity
        self.spans = collections.deque(maxlen=capacity)
        self.n_recorded = 0
        self.epoch = time.perf_counter()

    def add(self, name: str, cat: str, ts_s: float, dur_s: float,
            pid: int = 0, tid: object = 0, **args) -> None:
        self.spans.append(Span(name, cat, ts_s, max(0.0, dur_s),
                               int(pid), tid, args))
        self.n_recorded += 1

    # ------------------------------------------------------ exposition
    def chrome_trace(self) -> dict:
        """The ring as a Chrome trace-event JSON object."""
        events = []
        for s in self.spans:
            events.append({
                "name": s.name,
                "cat": s.cat,
                "ph": "X",
                "ts": (s.ts_s - self.epoch) * 1e6,
                "dur": s.dur_s * 1e6,
                "pid": s.pid,
                "tid": (s.tid if isinstance(s.tid, int)
                        else str(s.tid)),
                "args": dict(s.args),
            })
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"n_recorded": self.n_recorded,
                              "capacity": self.capacity}}

    def to_chrome_json(self) -> str:
        return json.dumps(self.chrome_trace(), indent=2, default=float)
