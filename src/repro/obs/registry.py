"""Metrics registry: exact counters, gauges and ring-buffer histograms.

Hardware MESI controllers are debuggable because every coherence event
increments a perf counter; this registry is that surface for the live
coherence service.  Design constraints, in order:

  1. **Exactness.**  Counters are plain Python ints (no float drift,
     no sampling), because the ``MetricsConformance`` oracle leg
     (``repro.obs.conformance``) asserts them *bit-identical* to a
     ``ServiceTrace`` replay.  Histograms keep an exact ``count`` and
     ``sum`` even after the ring buffer wraps, so conformance can
     compare those two integers while percentiles stay bounded-memory.
  2. **Low overhead.**  One dict lookup + int add per increment; label
     sets are sorted key/value tuples interned per call site.
  3. **Two exposition formats** from one store: Prometheus text
     (``to_prometheus``) and a JSON-able snapshot (``snapshot``), the
     schema both ``stats()`` surfaces and the TCP ``metrics`` verb
     serve.

Nothing here imports jax or the service layer - the registry is a leaf
module the whole system can depend on.
"""

from __future__ import annotations

import collections
import math
from typing import Dict, Iterable, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _labelkey(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _prom_labels(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    """Monotone exact counter, one cell per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.cells: Dict[LabelKey, int] = {}

    def inc(self, value: int = 1, **labels) -> None:
        key = _labelkey(labels)
        self.cells[key] = self.cells.get(key, 0) + value

    def inc_key(self, key: LabelKey, value: int = 1) -> None:
        """Hot-path increment with a pre-built label key (see
        ``_labelkey``) - skips per-call key construction."""
        self.cells[key] = self.cells.get(key, 0) + value

    def value(self, **labels) -> int:
        return self.cells.get(_labelkey(labels), 0)

    def total(self):
        return sum(self.cells.values())

    def items(self):
        return sorted(self.cells.items())


class Gauge:
    """Last-observation-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.cells: Dict[LabelKey, float] = {}

    def set(self, value, **labels) -> None:
        self.cells[_labelkey(labels)] = value

    def value(self, **labels):
        return self.cells.get(_labelkey(labels), 0)

    def items(self):
        return sorted(self.cells.items())


class _HistCell:
    """One label set's histogram state: exact count/sum/min/max plus a
    bounded ring buffer of recent samples for percentiles."""

    __slots__ = ("count", "sum", "min", "max", "ring")

    def __init__(self, window: int) -> None:
        self.count = 0
        self.sum = 0
        self.min = math.inf
        self.max = -math.inf
        self.ring = collections.deque(maxlen=window)

    def observe(self, value) -> None:
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        self.ring.append(value)

    def percentile(self, q: float):
        if not self.ring:
            return 0.0
        data = sorted(self.ring)
        idx = min(len(data) - 1, max(0, round(q / 100 * (len(data) - 1))))
        return data[idx]


class Histogram:
    """Ring-buffer histogram: exact count/sum forever, percentiles over
    the last ``window`` samples (bounds memory under open-ended load,
    same rationale as the broker's latency deque)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 window: int = 4096) -> None:
        self.name = name
        self.help = help
        self.window = window
        self.cells: Dict[LabelKey, _HistCell] = {}

    def observe(self, value, **labels) -> None:
        key = _labelkey(labels)
        cell = self.cells.get(key)
        if cell is None:
            cell = self.cells[key] = _HistCell(self.window)
        cell.observe(value)

    def cell(self, **labels) -> Optional[_HistCell]:
        return self.cells.get(_labelkey(labels))

    def cell_key(self, key: LabelKey) -> _HistCell:
        """Hot-path get-or-create with a pre-built label key."""
        cell = self.cells.get(key)
        if cell is None:
            cell = self.cells[key] = _HistCell(self.window)
        return cell

    def items(self):
        return sorted(self.cells.items())


class MetricsRegistry:
    """Named metric store with on-first-use creation.

    ``counter`` / ``gauge`` / ``histogram`` return the live metric
    object (creating it if needed); re-registration with the same name
    returns the existing instance, so every layer of the service can
    hold its own handle to the same cell.
    """

    def __init__(self) -> None:
        self.metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        metric = self.metrics.get(name)
        if metric is None:
            metric = self.metrics[name] = cls(name, help, **kw)
        elif not isinstance(metric, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(metric).__name__}, not {cls.__name__}")
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  window: int = 4096) -> Histogram:
        return self._get(Histogram, name, help, window=window)

    # ------------------------------------------------------ inspection
    def counter_value(self, name: str, **labels) -> int:
        metric = self.metrics.get(name)
        return metric.value(**labels) if metric is not None else 0

    def counter_total(self, name: str):
        metric = self.metrics.get(name)
        return metric.total() if metric is not None else 0

    def counter_cells(self, name: str) -> Dict[LabelKey, int]:
        """Label-key -> value mapping for one counter (empty if the
        counter was never touched) - the conformance comparison unit."""
        metric = self.metrics.get(name)
        return dict(metric.cells) if metric is not None else {}

    def histogram_totals(self, name: str):
        """Label-key -> (count, sum) for one histogram; exact even
        after the ring wraps."""
        metric = self.metrics.get(name)
        if metric is None:
            return {}
        return {key: (cell.count, cell.sum)
                for key, cell in metric.cells.items()}

    # ------------------------------------------------------ exposition
    def snapshot(self) -> dict:
        """JSON-able registry dump (the one schema both ``stats()``
        surfaces and the TCP ``metrics`` verb are built on)."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self.metrics):
            metric = self.metrics[name]
            if metric.kind == "counter":
                out["counters"][name] = {
                    "help": metric.help,
                    "values": [{"labels": dict(k), "value": v}
                               for k, v in metric.items()]}
            elif metric.kind == "gauge":
                out["gauges"][name] = {
                    "help": metric.help,
                    "values": [{"labels": dict(k), "value": v}
                               for k, v in metric.items()]}
            else:
                out["histograms"][name] = {
                    "help": metric.help,
                    "values": [{"labels": dict(k), "count": c.count,
                                "sum": c.sum,
                                "min": (c.min if c.count else 0),
                                "max": (c.max if c.count else 0),
                                "p50": c.percentile(50),
                                "p99": c.percentile(99)}
                               for k, c in metric.items()]}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4).  Histograms are
        exported as summaries (quantiles over the ring window plus the
        exact ``_count`` / ``_sum`` series)."""
        lines = []
        for name in sorted(self.metrics):
            metric = self.metrics[name]
            if metric.help:
                lines.append(f"# HELP {name} {metric.help}")
            if metric.kind in ("counter", "gauge"):
                lines.append(f"# TYPE {name} {metric.kind}")
                for key, value in metric.items():
                    lines.append(f"{name}{_prom_labels(key)} {value}")
            else:
                lines.append(f"# TYPE {name} summary")
                for key, cell in metric.items():
                    for q in (0.5, 0.99):
                        qkey = key + (("quantile", str(q)),)
                        lines.append(
                            f"{name}{_prom_labels(qkey)} "
                            f"{cell.percentile(q * 100)}")
                    lines.append(
                        f"{name}_count{_prom_labels(key)} {cell.count}")
                    lines.append(
                        f"{name}_sum{_prom_labels(key)} {cell.sum}")
        return "\n".join(lines) + "\n"


def merge_label_cells(cells: Dict[LabelKey, int],
                      drop: Iterable[str] = ()) -> Dict[LabelKey, int]:
    """Sum counter cells over the ``drop`` label dimensions (e.g. sum a
    per-shard counter across shards for a global comparison)."""
    drop = set(drop)
    out: Dict[LabelKey, int] = {}
    for key, value in cells.items():
        merged = tuple((k, v) for k, v in key if k not in drop)
        out[merged] = out.get(merged, 0) + value
    return out
