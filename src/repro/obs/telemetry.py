"""The coherence telemetry plane: MESI perf counters as first-class
metrics, fed from one hook per committed micro-batch.

A hardware MESI controller exports invalidations, upgrade misses and
sharer counts per cache controller; :class:`Telemetry` is that surface
for the artifact-coherence service.  One instance is shared by an
entire authority plane (the sharded broker hands the same object to
every shard, labeled ``shard=k``), and every committed micro-batch
calls :meth:`record_batch` with a :class:`BatchObservation`.

Two classes of metric, split deliberately:

  **Replayable** (counters + the exact count/sum of two histograms):
  derivable purely from the committed decision history - token
  ledger deltas, fills/hits, invalidation *signals* (charged) and
  invalidation *events* (observed M/E/S -> I transitions), storm and
  ping-pong detections, state-occupancy integrals, staleness-at-serve.
  The ``MetricsConformance`` leg (``repro.obs.conformance``) replays
  the captured ``ServiceTrace`` through a fresh Telemetry and asserts
  these **bit-identical** to the live registry.

  **Live-only** (wall-clock histograms, queue depth, spans, compile
  events): meaningful only on the live timeline; excluded from
  conformance by construction (``BatchObservation.live``).

Metric catalog and MESI-analogue rationale: ``docs/observability.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.states import MESIState
from repro.obs import runtime
from repro.obs.registry import MetricsRegistry, _labelkey
from repro.obs.spans import SpanRecorder

_I = int(MESIState.I)
_STATE_NAMES = {int(s): s.name for s in MESIState}

#: ACSMetrics/ledger fields forwarded as coh_* counters.
LEDGER_COUNTERS = {
    "fetch_tokens": "coh_fetch_tokens_total",
    "signal_tokens": "coh_signal_tokens_total",
    "push_tokens": "coh_push_tokens_total",
    "n_fetches": "coh_fills_total",
    "n_hits": "coh_hits_total",
    "n_reads": "coh_reads_total",
    "n_writes": "coh_writes_total",
    "n_invalidation_signals": "coh_invalidation_signals_total",
}
WIRE_COUNTERS = {
    "delta_bytes": "coh_wire_delta_bytes_total",
    "full_bytes": "coh_wire_full_bytes_total",
    "n_chunks_fetched": "coh_chunks_fetched_total",
}


@dataclasses.dataclass
class BatchObservation:
    """Everything one committed micro-batch exposes to telemetry.

    The replay path (``obs.conformance``) constructs these from a
    ``ServiceTrace`` with ``live=False`` and no timing fields; the
    derivation below must therefore never mix timing into a replayable
    counter.
    """

    names: Tuple[str, ...]          # artifact names, local index order
    acts: np.ndarray                # (n,) bool
    arts: np.ndarray                # (n,) int, local artifact indices
    writes: np.ndarray              # (n,) bool
    miss: np.ndarray                # (n,) bool
    version: np.ndarray             # (n,) served version per agent slot
    ledger_delta: dict
    state_before: np.ndarray        # (n, m) MESI codes before decide
    state_after: np.ndarray         # (n, m) after
    ver_after: np.ndarray           # (m,) authority versions after
    wire_delta: Optional[dict] = None
    shard: int = 0
    live: bool = True
    # ---- live-only (wall clock / queue state) ----
    busy_s: float = 0.0
    route: str = ""
    queue_depth: int = 0
    t_decide: float = 0.0
    t_respond: float = 0.0
    t_submits: Optional[dict] = None    # agent -> t_submit
    latencies: Optional[dict] = None    # agent -> latency_s


class _ShardCells:
    """Pre-resolved (metric, label-key) handles for one shard label.

    ``record_batch`` runs inside the broker's single-writer event loop
    on every committed micro-batch, so it must not rebuild label keys
    or re-resolve metric names per call - that alone costs ~3x the
    bookkeeping itself and would blow the perf gate's 10% telemetry
    bound.  Everything here produces *identical* registry cells to the
    kwargs path (same ``_labelkey``), so conformance is unaffected.
    """

    __slots__ = ("skey", "batches", "req_read", "req_write",
                 "batch_size", "ledger", "wire", "inv", "flips",
                 "pingpong", "ent", "occ", "storms", "stale",
                 "decide_busy", "decide_secs", "queue_depth", "latency")

    def __init__(self, reg: MetricsRegistry, shard: int,
                 storm_threshold: int) -> None:
        skey = _labelkey({"shard": shard})
        self.skey = skey
        self.batches = (reg.counter("coh_batches_total",
                                    "committed micro-batches"), skey)
        req = reg.counter("coh_requests_total",
                          "requests resolved, by operation")
        self.req_read = (req, _labelkey({"shard": shard, "op": "read"}))
        self.req_write = (req, _labelkey({"shard": shard,
                                          "op": "write"}))
        self.batch_size = reg.histogram(
            "coh_batch_size",
            "requests per committed micro-batch").cell_key(skey)
        self.ledger = tuple(
            (field, reg.counter(name))
            for field, name in LEDGER_COUNTERS.items())
        self.wire = tuple(
            (field, reg.counter(name))
            for field, name in WIRE_COUNTERS.items())
        inv = reg.counter(
            "coh_invalidation_events_total",
            "observed valid->I transitions, per artifact")
        flips = reg.counter(
            "coh_writer_flips_total",
            "consecutive commits by different writers")
        ping = reg.counter(
            "coh_pingpong_alternations_total",
            "A->B->A writer alternations")
        # artifact-labeled keys resolve lazily (shard-local name sets)
        self.inv = (inv, {})
        self.flips = (flips, {})
        self.pingpong = (ping, {})
        ent = reg.counter(
            "coh_state_entries_total",
            "MESI state entries: M per commit, S per fill, I per "
            "invalidation event")
        self.ent = {
            s: (ent, _labelkey({"state": s, "shard": shard}))
            for s in ("M", "S", "I")}
        occ = reg.counter(
            "coh_state_occupancy_total",
            "post-batch state occupancy integral "
            "(agent-artifact cells x batches)")
        self.occ = {
            code: (sname, occ,
                   _labelkey({"state": sname, "shard": shard}))
            for code, sname in _STATE_NAMES.items()}
        self.storms = (reg.counter(
            "coh_invalidation_storms_total",
            f"batches charging >= {storm_threshold} "
            f"invalidation signals"), skey)
        self.stale = reg.histogram(
            "coh_staleness_at_serve",
            "versions the served copy lags the post-batch authority"
            ).cell_key(skey)
        self.decide_busy = (reg.counter(
            "coh_decide_busy_seconds_total",
            "wall time inside the decision route"), skey)
        # route label resolves lazily (constant per decider)
        self.decide_secs = (reg.histogram(
            "coh_decide_seconds",
            "decision-kernel wall time per micro-batch"), {})
        self.queue_depth = reg.histogram(
            "coh_queue_depth",
            "pending requests at batch cut").cell_key(skey)
        self.latency = reg.histogram(
            "coh_latency_seconds",
            "submit->respond request latency").cell_key(skey)

    def artifact_key(self, cache: dict, name: str):
        key = cache.get(name)
        if key is None:
            key = cache[name] = self.skey + (("artifact", name),)
        return key


class Telemetry:
    """Registry + spans + MESI detectors for one authority plane."""

    def __init__(self, n_agents: int, *, strategy: str = "",
                 backend: str = "", n_shards: int = 1, n_hosts: int = 1,
                 storm_threshold: Optional[int] = None,
                 span_capacity: int = 1 << 14) -> None:
        self.n_agents = n_agents
        self.strategy = strategy
        self.backend = backend
        self.n_shards = n_shards
        self.n_hosts = n_hosts
        #: a batch whose charged invalidation signals reach this count
        #: is an invalidation storm (half the fleet got blasted).
        self.storm_threshold = (storm_threshold if storm_threshold
                                else max(2, n_agents // 2))
        self.registry = MetricsRegistry()
        self.spans = SpanRecorder(span_capacity)
        #: ping-pong detector state: artifact -> (prev writer, writer)
        self._writers: Dict[str, Tuple[int, int]] = {}
        #: per-shard pre-resolved metric handles (hot-path cache)
        self._shard_cells: Dict[int, _ShardCells] = {}
        self.registry.gauge(
            "coh_build_info",
            "deployment labels of this authority plane").set(
                1, strategy=strategy, backend=backend,
                n_shards=n_shards, n_hosts=n_hosts)

    # ----------------------------------------------------------- hooks
    def record_batch(self, obs: BatchObservation) -> None:
        shard = obs.shard
        cells = self._shard_cells.get(shard)
        if cells is None:
            cells = self._shard_cells[shard] = _ShardCells(
                self.registry, shard, self.storm_threshold)
        acts = np.asarray(obs.acts, bool)
        writes = np.asarray(obs.writes, bool) & acts
        reads = acts & ~writes
        batch_size = int(acts.sum())

        cells.batches[0].inc_key(cells.batches[1], 1)
        cells.req_read[0].inc_key(cells.req_read[1], int(reads.sum()))
        cells.req_write[0].inc_key(cells.req_write[1],
                                   int(writes.sum()))
        cells.batch_size.observe(batch_size)

        delta = obs.ledger_delta
        skey = cells.skey
        for field, counter in cells.ledger:
            counter.inc_key(skey, int(delta[field]))
        if obs.wire_delta is not None:
            wire = obs.wire_delta
            for field, counter in cells.wire:
                counter.inc_key(skey, int(wire[field]))

        self._record_mesi(obs, cells, reads, writes)
        if obs.live:
            self._record_live(obs, cells, shard, batch_size, writes)

    # ------------------------------------------------- MESI detectors
    def _record_mesi(self, obs, cells, reads, writes):
        before = np.asarray(obs.state_before)
        after = np.asarray(obs.state_after)
        names = obs.names
        skey = cells.skey

        # Invalidation *events*: observed M/E/S -> I transitions, the
        # analogue of a hardware controller's invalidation counter
        # (distinct from the charged invalidation *signals*, which
        # depend on the strategy's signaling model).
        became_i = (before != _I) & (after == _I)
        inv_per_artifact = became_i.sum(axis=0)
        inv, inv_keys = cells.inv
        for d in np.flatnonzero(inv_per_artifact):
            inv.inc_key(cells.artifact_key(inv_keys, names[int(d)]),
                        int(inv_per_artifact[d]))
        ent_m, ent_s, ent_i = (cells.ent[s] for s in ("M", "S", "I"))
        ent_m[0].inc_key(ent_m[1], int(writes.sum()))
        ent_s[0].inc_key(ent_s[1], int(obs.ledger_delta["n_fetches"]))
        ent_i[0].inc_key(ent_i[1], int(inv_per_artifact.sum()))
        occupancy = np.bincount(
            after.ravel(), minlength=max(cells.occ) + 1)
        for code, (sname, occ, key) in cells.occ.items():
            count = int(occupancy[code])
            if count or sname in ("S", "I"):
                occ.inc_key(key, count)

        # Invalidation-storm detector: one batch blasted at least
        # storm_threshold invalidation signals across the fleet.
        if (int(obs.ledger_delta["n_invalidation_signals"])
                >= self.storm_threshold):
            cells.storms[0].inc_key(cells.storms[1], 1)

        # Ping-pong detector: consecutive commits to one artifact by
        # different writers (flip), and A->B->A alternation (the
        # cache-line ping-pong pathology proper).
        arts = np.asarray(obs.arts)
        flips, flip_keys = cells.flips
        ping, ping_keys = cells.pingpong
        for agent in np.flatnonzero(writes):
            name = names[int(arts[agent])]
            prev = self._writers.get(name)
            if prev is not None and prev[1] != int(agent):
                flips.inc_key(
                    cells.artifact_key(flip_keys, name), 1)
                if prev[0] == int(agent):
                    ping.inc_key(
                        cells.artifact_key(ping_keys, name), 1)
            self._writers[name] = ((prev[1] if prev else -1),
                                   int(agent))

        # Staleness-at-serve: for every served read, how many versions
        # the returned copy already lags the post-batch authority
        # (>0 = a same-batch commit superseded what you just read).
        read_idx = np.flatnonzero(reads)
        if read_idx.size:
            ver_after = np.asarray(obs.ver_after)
            version = np.asarray(obs.version)
            stale = cells.stale
            lags = ver_after[arts[read_idx]] - version[read_idx]
            for lag in lags.tolist():
                stale.observe(int(lag))

    # ---------------------------------------------------- live timing
    def _record_live(self, obs, cells, shard, batch_size, writes):
        cells.decide_busy[0].inc_key(cells.decide_busy[1], obs.busy_s)
        decide_h, route_cells = cells.decide_secs
        route_cell = route_cells.get(obs.route)
        if route_cell is None:
            route_cell = route_cells[obs.route] = decide_h.cell_key(
                cells.skey + (("route", obs.route),))
        route_cell.observe(obs.busy_s)
        cells.queue_depth.observe(obs.queue_depth)
        lat = cells.latency
        for latency in (obs.latencies or {}).values():
            lat.observe(latency)

        # one complete span per request + one per batch, recorded at
        # resolve time (no open-span state on the hot path)
        t_apply_end = obs.t_respond
        decide_end = obs.t_decide + obs.busy_s
        self.spans.add("decide", "batch", obs.t_decide, obs.busy_s,
                       pid=shard, tid="authority",
                       batch_size=batch_size, route=obs.route,
                       queue_depth=obs.queue_depth)
        arts = np.asarray(obs.arts)
        for agent, t_submit in (obs.t_submits or {}).items():
            name = obs.names[int(arts[agent])]
            op = "write" if writes[agent] else "read"
            self.spans.add(
                f"{op} {name}", "request", t_submit,
                t_apply_end - t_submit, pid=shard, tid=int(agent),
                queue_s=max(0.0, obs.t_decide - t_submit),
                decide_s=obs.busy_s,
                apply_s=max(0.0, t_apply_end - decide_end))

    # --------------------------------------------------------- L1 plane
    def record_l1_fill(self, host: int, level: str, nbytes: int) -> None:
        """Attribute one coherence fill to the L1 or L2 plane."""
        reg = self.registry
        reg.counter("coh_l1_fills_total" if level == "l1"
                    else "coh_l2_fills_total",
                    f"fills served by the {level.upper()} plane").inc(
                        1, host=host)
        reg.counter("coh_l1_bytes_total" if level == "l1"
                    else "coh_l2_bytes_total",
                    f"fill bytes served by the {level.upper()} plane"
                    ).inc(int(nbytes), host=host)

    def record_l1_invalidation(self, host: int) -> None:
        self.registry.counter(
            "coh_l1_invalidations_total",
            "host-L1 entries dropped by the commit invalidation path"
            ).inc(1, host=host)

    # ------------------------------------------------------ exposition
    def snapshot(self) -> dict:
        """Registry snapshot plus runtime (compile/span) accounting."""
        out = self.registry.snapshot()
        out["runtime"] = {
            "compile_events": runtime.compile_events(),
            "spans_recorded": self.spans.n_recorded,
            "span_capacity": self.spans.capacity,
        }
        return out

    def prometheus(self) -> str:
        return self.registry.to_prometheus()

    def chrome_trace(self) -> dict:
        trace = self.spans.chrome_trace()
        shift = runtime.epoch() - self.spans.epoch
        for e in runtime.compile_events():
            trace["traceEvents"].append({
                "name": f"{e['kind']}:{e['route']}", "cat": "compile",
                "ph": "X", "ts": (e["t_s"] + shift) * 1e6,
                "dur": e["dur_s"] * 1e6, "pid": -1, "tid": "jit",
                "args": {"label": e["label"]}})
        return trace
