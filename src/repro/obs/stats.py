"""One ``stats()`` schema for every broker flavor.

``CoherenceBroker.stats()`` and ``ShardedCoherenceBroker.stats()`` had
drifted into two ad-hoc flat dicts (the sharded one omitted latency
percentiles, the plain one omitted capacity metrics).  Both now
delegate here: :func:`unified_stats` builds one **nested canonical
schema** (identical key set for both flavors, superset of everything
either reported) and attaches the old flat key names as a deprecation
shim - reading a legacy key still works everywhere it used to, but
warns once per process per key.  Serialization keeps the flat keys
(TCP ``stats`` consumers parse them), so the shim is wire-compatible.

Canonical schema (``schema_version`` 1)::

    strategy, backend                      # deployment
    topology:  n_shards, n_hosts, shard_artifacts
    decision:  n_actions, n_batches, mean_batch, decide_busy_s,
               decide_busy_max_s, decisions_per_s
    ledger:    total/fetch/signal/push tokens, fills, hits, reads,
               writes, invalidation signals, cache_hit_rate
    latency:   p50_ms, p99_ms, n_samples
    telemetry: enabled, spans_recorded, compile_traces
    mesi:      invalidation events/storms, writer flips, ping-pong
               alternations, staleness-at-serve mean  (telemetry on)
    wire:      delta/full bytes, chunks fetched, savings, unique
               chunks                                  (content plane)
    l1:        l1/l2 fills + bytes, fill rate, invalidations
                                                       (sharded plane)
"""

from __future__ import annotations

import warnings

import numpy as np

#: flat keys kept as the deprecation shim (the union of the two
#: pre-unification stats() dicts).
LEGACY_KEYS = frozenset({
    "n_actions", "n_batches", "mean_batch",
    "total_tokens", "fetch_tokens", "signal_tokens", "push_tokens",
    "n_fetches", "n_hits", "cache_hit_rate",
    "p50_ms", "p99_ms", "decide_busy_s",
    "n_shards", "n_hosts", "shard_artifacts",
    "decide_busy_max_s", "decisions_per_s",
    "l1_fills", "l2_fills", "l1_bytes", "l2_bytes", "l1_fill_rate",
    "delta_bytes", "full_bytes", "n_chunks_fetched",
    "bytes_savings_vs_full", "unique_chunks",
})

_warned: set = set()


class StatsView(dict):
    """The stats mapping: canonical nested keys plus legacy flat
    aliases that warn (once per process per key) on access."""

    def __getitem__(self, key):
        if key in LEGACY_KEYS and key not in _warned:
            _warned.add(key)
            warnings.warn(
                f"stats()[{key!r}] is a deprecated flat alias; read "
                f"the nested schema (see repro.obs.stats docstring / "
                f"docs/observability.md)",
                DeprecationWarning, stacklevel=2)
        return super().__getitem__(key)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default


def _percentiles(latencies) -> dict:
    lat = (np.asarray(latencies, float) if len(latencies)
           else np.zeros(1))
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "n_samples": int(len(latencies))}


def _mesi_section(tel) -> dict:
    reg = tel.registry
    stale = reg.histogram_totals("coh_staleness_at_serve")
    count = sum(c for c, _ in stale.values())
    total = sum(s for _, s in stale.values())
    occ = {}
    for key, value in reg.counter_cells(
            "coh_state_occupancy_total").items():
        state = dict(key).get("state", "?")
        occ[state] = occ.get(state, 0) + value
    return {
        "invalidation_events": reg.counter_total(
            "coh_invalidation_events_total"),
        "invalidation_storms": reg.counter_total(
            "coh_invalidation_storms_total"),
        "writer_flips": reg.counter_total("coh_writer_flips_total"),
        "pingpong_alternations": reg.counter_total(
            "coh_pingpong_alternations_total"),
        "staleness_served_mean": total / max(count, 1),
        "occupancy": occ,
    }


def unified_stats(broker) -> StatsView:
    """Build the canonical nested stats mapping (+ legacy flat aliases)
    for a plain or sharded broker."""
    sharded = getattr(broker, "is_sharded", False)
    led = broker.ledger
    tel = getattr(broker, "telemetry", None)

    if sharded:
        strategy = broker.config.core.strategy
        backend = broker.brokers[0].decider.backend
        n_shards = broker.n_shards
        n_hosts = broker.config.topology.n_hosts
        shard_artifacts = [len(c) for c in broker._shard_cols]
        busy = broker.decision_busy()
        latencies = [x for b in broker.brokers for x in b.latencies]
        chunked = broker.chunked
        unique_chunks = (sum(b.chunks.n_unique_chunks
                             for b in broker.brokers)
                         if chunked else 0)
    else:
        strategy = broker.config.strategy
        backend = broker.decider.backend
        n_shards, n_hosts = 1, 1
        shard_artifacts = [len(broker.names)]
        busy = (broker.decide_busy_s,)
        latencies = list(broker.latencies)
        chunked = broker.chunks is not None
        unique_chunks = (broker.chunks.n_unique_chunks
                         if chunked else 0)

    n_actions = led.n_reads + led.n_writes
    out = StatsView({
        "schema_version": 1,
        "strategy": strategy,
        "backend": backend,
        "topology": {"n_shards": n_shards, "n_hosts": n_hosts,
                     "shard_artifacts": shard_artifacts},
        "decision": {
            "n_actions": n_actions,
            "n_batches": broker.n_batches,
            "mean_batch": n_actions / max(broker.n_batches, 1),
            "decide_busy_s": sum(busy),
            "decide_busy_max_s": max(busy),
            "decisions_per_s": n_actions / max(max(busy), 1e-12),
        },
        "ledger": {
            "total_tokens": led.total_tokens,
            "fetch_tokens": led.fetch_tokens,
            "signal_tokens": led.signal_tokens,
            "push_tokens": led.push_tokens,
            "n_fetches": led.n_fetches,
            "n_hits": led.n_hits,
            "n_reads": led.n_reads,
            "n_writes": led.n_writes,
            "n_invalidation_signals": led.n_invalidation_signals,
            "cache_hit_rate": led.n_hits / max(led.n_hits
                                               + led.n_fetches, 1),
        },
        "latency": _percentiles(latencies),
        "telemetry": {
            "enabled": tel is not None,
            "spans_recorded": (tel.spans.n_recorded if tel else 0),
            "compile_traces": 0,
        },
    })
    if tel is not None:
        from repro.obs import runtime
        out["telemetry"]["compile_traces"] = runtime.compile_count()
        out["mesi"] = _mesi_section(tel)
    if chunked:
        wire = dict(broker.wire)
        wire["bytes_savings_vs_full"] = 1.0 - (
            wire["delta_bytes"] / max(wire["full_bytes"], 1))
        wire["unique_chunks"] = unique_chunks
        out["wire"] = wire
    if sharded:
        l1 = dict(broker.l1_wire)
        fills = l1["l1_fills"] + l1["l2_fills"]
        l1["l1_fill_rate"] = l1["l1_fills"] / max(fills, 1)
        l1["l1_invalidations"] = sum(h.n_invalidations
                                     for h in broker.l1)
        out["l1"] = l1

    # ---- legacy flat aliases (deprecation shim; warn on access)
    flat = {}
    flat.update({k: out["decision"][k] for k in (
        "n_actions", "n_batches", "mean_batch", "decide_busy_s")})
    flat.update({k: out["ledger"][k] for k in (
        "total_tokens", "fetch_tokens", "signal_tokens", "push_tokens",
        "n_fetches", "n_hits", "cache_hit_rate")})
    flat.update({k: out["latency"][k] for k in ("p50_ms", "p99_ms")})
    if chunked:
        flat.update({k: out["wire"][k] for k in (
            "delta_bytes", "full_bytes", "n_chunks_fetched",
            "bytes_savings_vs_full", "unique_chunks")})
    if sharded:
        flat.update({
            "n_shards": n_shards, "n_hosts": n_hosts,
            "shard_artifacts": tuple(shard_artifacts),
            "decide_busy_max_s": out["decision"]["decide_busy_max_s"],
            "decisions_per_s": out["decision"]["decisions_per_s"],
        })
        flat.update({k: out["l1"][k] for k in (
            "l1_fills", "l2_fills", "l1_bytes", "l2_bytes",
            "l1_fill_rate")})
    dict.update(out, flat)
    return out
