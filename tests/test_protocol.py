"""Message-level CCS protocol tests: safety, recovery, idempotency, and
exact token-ledger equivalence with the vectorized ACS simulator."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import acs
from repro.core.protocol import (AgentRuntime, ArtifactStore,
                                 CoordinatorService, EventBus,
                                 ShardedCoordinator)
from repro.core.states import MESIState
from repro.core import invariants


def make_system(n_agents=4, n_artifacts=3, tokens=64, strategy="lazy",
                lease_ttl=30.0, n_shards=0, **agent_kw):
    bus = EventBus()
    store = ArtifactStore()
    if n_shards:
        coord = ShardedCoordinator(n_shards, bus, store, strategy=strategy)
    else:
        coord = CoordinatorService(bus, store, strategy=strategy,
                                   lease_ttl=lease_ttl)
    for d in range(n_artifacts):
        coord.register_artifact(f"artifact-{d}", list(range(tokens)))
    agents = [AgentRuntime(f"agent-{a}", coord, bus, strategy=strategy,
                           **agent_kw)
              for a in range(n_agents)]
    return coord, agents, bus


def state_matrix(coord, agents, n_artifacts):
    return np.array([[int(ag.state_of(f"artifact-{d}"))
                      for d in range(n_artifacts)] for ag in agents])


class TestProtocolBasics:
    def test_read_miss_then_hit(self):
        coord, agents, _ = make_system()
        a = agents[0]
        assert a.state_of("artifact-0") == MESIState.I
        content = a.read("artifact-0")
        assert list(content) == list(range(64))
        assert a.state_of("artifact-0") == MESIState.S
        before = coord.ledger.fetch_tokens
        a.read("artifact-0")  # coherent hit: zero tokens
        assert coord.ledger.fetch_tokens == before
        assert coord.ledger.n_hits == 1

    def test_write_invalidates_peers_and_bumps_version(self):
        coord, agents, _ = make_system()
        for ag in agents:
            ag.read("artifact-1")
        v = agents[0].write("artifact-1", [9] * 64)
        assert v == 2
        assert agents[0].state_of("artifact-1") == MESIState.S
        for ag in agents[1:]:
            assert ag.state_of("artifact-1") == MESIState.I
        # re-read fetches the fresh content
        assert agents[1].read("artifact-1") == [9] * 64

    def test_swmr_invariant_along_random_run(self):
        coord, agents, _ = make_system()
        rng = np.random.default_rng(0)
        for _ in range(200):
            a = int(rng.integers(4))
            d = f"artifact-{int(rng.integers(3))}"
            if rng.random() < 0.3:
                agents[a].write(d, list(rng.integers(0, 9, 64)))
            else:
                agents[a].read(d)
            m = state_matrix(coord, agents, 3)
            assert invariants.single_writer(m)

    def test_version_monotonic(self):
        coord, agents, _ = make_system()
        versions = [coord.directory["artifact-0"].version]
        for i in range(10):
            agents[i % 4].write("artifact-0", [i] * 64)
            versions.append(coord.directory["artifact-0"].version)
        assert invariants.monotonic_version(versions[:-1], versions[1:])


class TestLeaseRecovery:
    def test_crash_in_m_state_recovers_via_lease_ttl(self):
        """AS3 violation: writer crashes before commit; the lease TTL
        reverts the orphaned lock (SS5.2)."""
        coord, agents, _ = make_system(lease_ttl=30.0)
        agents[1].read("artifact-0")
        agents[0].write("artifact-0", [1] * 64, crash_before_commit=True)
        assert agents[0].crashed
        # lock is held: another writer is refused
        assert agents[1].write("artifact-0", [2] * 64) is None
        # ... until the lease expires
        coord.advance(31.0)
        assert coord.leases.holder("artifact-0") is None
        v = agents[1].write("artifact-0", [2] * 64)
        assert v is not None
        # the crashed agent's in-progress write was lost (revert)
        assert list(coord.store.get("artifact-0")) == [2] * 64

    def test_commit_after_lease_expiry_is_rejected(self):
        coord, agents, _ = make_system(lease_ttl=5.0)
        agents[0].read("artifact-0")
        granted, _ = coord.upgrade_request("agent-0", "artifact-0")
        assert granted
        coord.advance(10.0)  # lease expires
        with pytest.raises(RuntimeError, match="without lease"):
            coord.commit("agent-0", "artifact-0", [3] * 64)


class TestEventBus:
    def test_duplicate_delivery_is_idempotent(self):
        """AS2: at-least-once delivery; re-invalidation is a no-op."""
        bus = EventBus(duplicate_every=2)
        store = ArtifactStore()
        coord = CoordinatorService(bus, store)
        coord.register_artifact("artifact-0", [0] * 64)
        agents = [AgentRuntime(f"agent-{a}", coord, bus) for a in range(3)]
        for ag in agents:
            ag.read("artifact-0")
        for i in range(6):
            agents[i % 3].write("artifact-0", [i] * 64)
        for ag in agents:
            assert ag.read("artifact-0") == [5] * 64

    def test_queued_delivery_preserves_safety(self):
        """With a slow bus the authority's directory (not the agent's
        view) is what serializes writes - SWMR still holds."""
        bus = EventBus(deliver_immediately=False)
        store = ArtifactStore()
        coord = CoordinatorService(bus, store)
        coord.register_artifact("artifact-0", [0] * 8)
        a0 = AgentRuntime("agent-0", coord, bus)
        a1 = AgentRuntime("agent-1", coord, bus)
        a0.read("artifact-0")
        a1.read("artifact-0")
        a0.write("artifact-0", [1] * 8)
        # a1 has not seen the invalidation yet (bus lag) but the
        # authority directory already marks it Invalid.
        assert coord.agent_state("agent-1", "artifact-0") == MESIState.I
        bus.flush()
        assert a1.state_of("artifact-0") == MESIState.I


class TestShardedDirectory:
    def test_sharded_coordinator_routes_and_preserves_swmr(self):
        coord, agents, _ = make_system(n_shards=4)
        rng = np.random.default_rng(1)
        for _ in range(100):
            a = int(rng.integers(4))
            d = f"artifact-{int(rng.integers(3))}"
            if rng.random() < 0.4:
                agents[a].write(d, list(rng.integers(0, 9, 64)))
            else:
                agents[a].read(d)
        # per-artifact home shards serialized everything
        m = state_matrix(coord, agents, 3)
        assert invariants.single_writer(m)
        assert coord.ledger.n_writes > 0


# ---------------------------------------------------------------------
# Equivalence: message-level protocol vs vectorized JAX state machine.
# ---------------------------------------------------------------------

def replay_acs(cfg: acs.ACSConfig, script):
    """Replay a scripted action list through the eager-mode JAX ACS."""
    arrays = acs.init_arrays(cfg)
    met = acs.init_metrics()
    for (a, d, is_write) in script:
        arrays = arrays._replace(
            agent_actions=arrays.agent_actions.at[a].add(1))
        if is_write:
            arrays, met = acs._do_write(cfg, arrays, met, a, d)
        else:
            arrays, met = acs._do_read(cfg, arrays, met, a, d)
    return arrays, met


def replay_protocol(strategy, n_agents, n_artifacts, tokens, script,
                    **agent_kw):
    coord, agents, _ = make_system(n_agents, n_artifacts, tokens,
                                   strategy=strategy, **agent_kw)
    for (a, d, is_write) in script:
        if is_write:
            old = list(agents[a].read("artifact-%d" % d)) \
                if False else [7] * tokens
            agents[a].actions -= 0  # write() bumps the action clock itself
            agents[a].write(f"artifact-{d}", old)
        else:
            agents[a].read(f"artifact-{d}")
    return coord, agents


@given(data=st.data(),
       strategy=st.sampled_from(["lazy", "eager", "access_count"]))
@settings(max_examples=25, deadline=None)
def test_protocol_matches_vectorized_acs(data, strategy):
    """The paper's SS7 reference implementation and our vectorized SS4
    state machine produce identical token ledgers on identical traces."""
    n_agents, n_artifacts, tokens = 3, 2, 32
    script = data.draw(st.lists(
        st.tuples(st.integers(0, n_agents - 1),
                  st.integers(0, n_artifacts - 1),
                  st.booleans()),
        min_size=1, max_size=40))
    cfg = acs.ACSConfig(
        n_agents=n_agents, n_artifacts=n_artifacts,
        artifact_tokens=tokens, n_steps=1,
        strategy=acs.STRATEGY_CODES[strategy])
    arrays, met = replay_acs(cfg, script)
    coord, agents = replay_protocol(strategy, n_agents, n_artifacts,
                                    tokens, script)
    ledger = coord.ledger
    assert int(met.fetch_tokens) == ledger.fetch_tokens
    assert int(met.signal_tokens) == ledger.signal_tokens
    assert int(met.push_tokens) == ledger.push_tokens
    assert int(met.n_fetches) == ledger.n_fetches
    assert int(met.n_hits) == ledger.n_hits
    assert int(met.n_invalidation_signals) == ledger.n_invalidation_signals
    # final MESI state matrices agree
    m_proto = state_matrix(coord, agents, n_artifacts)
    m_acs = np.asarray(arrays.state)
    assert (m_proto == m_acs).all()
    assert invariants.single_writer(m_acs)
    assert invariants.exclusive_means_alone(m_acs)
