"""Device-sharded sweep engine: bit-exactness + one-compilation.

The tentpole contract of the sharded path (``repro.sim.engine`` on
``jax.shard_map`` over ``repro.launch.mesh.make_sweep_mesh``):

  1. a sweep sharded over N devices produces **bit-identical** token
     ledgers (per-run totals, cache-hit rates, savings) to the
     single-device path - the per-run key schedule is ``fold_in`` on
     the *global* run index, so device-local position never enters it;
  2. the sharded grid is still ONE compiled XLA program, and
     re-sweeping with new volatilities retraces nothing;
  3. every shard plan (runs axis, workloads-axis fallback, padded
     runs) preserves (1);
  4. a sharded grid cell replays bit-exactly through the differential
     oracle (``repro.sim.oracle``), closing the loop to MESI states
     and versions via the four-way conformance harness.

Multi-device cases need forced host devices (CI's ``sharded`` job)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_sharded_sweep.py -q

On a single-device host those cases skip; the plan-logic tests and a
subprocess end-to-end check (marked ``slow``) still run.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.sim import (ShardPlan, canonical, compare_workloads, engine,
                       oracle, run_scenario, shard_plan, sweep_volatility,
                       workloads)

N_DEV = jax.local_device_count()

multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 local devices (run under XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")

pytestmark = pytest.mark.sharded


def small(v=0.25, seed=777, n_runs=8, **kw):
    params = dict(n_steps=6, artifact_tokens=64)
    params.update(kw)
    return dataclasses.replace(
        canonical("sharded-test", v, seed, **params), n_runs=n_runs)


def _zoo(n_runs):
    return workloads.zoo(n_agents=4, n_artifacts=3, n_runs=n_runs,
                         artifact_tokens=64, n_steps=5)


class TestShardPlan:
    """Pure planning logic - runs at any device count."""

    def test_single_device_is_unsharded(self):
        assert shard_plan(4, 8, devices=1) == ShardPlan(1, None, 8)

    def test_devices_capped_at_local_count(self):
        plan = shard_plan(4, 8, devices=10_000)
        assert plan.devices <= N_DEV

    def test_env_override_disables_sharding(self, monkeypatch):
        monkeypatch.setenv("REPRO_SWEEP_DEVICES", "1")
        assert engine.resolve_sweep_devices() == 1

    @pytest.mark.skipif(N_DEV != 1, reason="axis rules need a fixed "
                        "device count; covered multi-device below")
    def test_all_plans_degenerate_on_one_device(self):
        for cells, runs in ((1, 3), (6, 7), (4, 8)):
            assert shard_plan(cells, runs).axis is None


@multi_device
class TestShardPlanMultiDevice:
    def test_runs_axis_preferred(self):
        plan = shard_plan(3, 2 * N_DEV, devices=N_DEV)
        assert plan == ShardPlan(N_DEV, "runs", 2 * N_DEV)

    def test_workloads_axis_fallback(self):
        plan = shard_plan(N_DEV, 2 * N_DEV + 1, devices=N_DEV)
        assert plan == ShardPlan(N_DEV, "workloads", 2 * N_DEV + 1)

    def test_padded_runs_last_resort(self):
        plan = shard_plan(N_DEV + 1, N_DEV + 1, devices=N_DEV)
        assert plan.axis == "runs"
        assert plan.pad_runs == 2 * N_DEV
        assert plan.pad_runs % N_DEV == 0


@multi_device
class TestBitExactness:
    """Sharded == single-device, bit for bit, on every ledger metric."""

    def _assert_same(self, a, b):
        assert a.broadcast.total_tokens_mean == b.broadcast.total_tokens_mean
        assert a.coherent.total_tokens_mean == b.coherent.total_tokens_mean
        assert a.coherent.sync_tokens_mean == b.coherent.sync_tokens_mean
        assert a.savings_mean == b.savings_mean
        assert a.savings_std == b.savings_std
        assert a.crr == b.crr
        assert a.chr_mean == b.chr_mean

    def test_sweep_runs_axis(self):
        base = small(n_runs=N_DEV)
        vols = (0.05, 0.25, 0.75, 1.0)
        for sh, ref in zip(sweep_volatility(base, vols, devices=N_DEV),
                           sweep_volatility(base, vols, devices=1)):
            self._assert_same(sh, ref)

    def test_run_scenario_per_run_ledgers(self):
        scn = small(n_runs=2 * N_DEV)
        sh = run_scenario(scn, devices=N_DEV)
        ref = run_scenario(scn, devices=1)
        np.testing.assert_array_equal(sh.per_run_total_tokens,
                                      ref.per_run_total_tokens)
        np.testing.assert_array_equal(sh.per_run_chr, ref.per_run_chr)

    def test_padded_runs_plan(self):
        # n_runs=3 divides nothing -> runs axis padded to a multiple of
        # the device count; padding must not perturb the real runs.
        scn = small(n_runs=3)
        assert shard_plan(1, 3, devices=N_DEV).pad_runs % N_DEV == 0
        sh = run_scenario(scn, devices=N_DEV)
        ref = run_scenario(scn, devices=1)
        np.testing.assert_array_equal(sh.per_run_total_tokens,
                                      ref.per_run_total_tokens)

    def test_workload_zoo_runs_axis(self):
        zoo = _zoo(n_runs=N_DEV)
        for sh, ref in zip(compare_workloads(zoo, devices=N_DEV),
                           compare_workloads(zoo, devices=1)):
            self._assert_same(sh, ref)

    @pytest.mark.content
    def test_content_grid_byte_ledgers_shard_exactly(self):
        """The chunked grid (extra traced locality operand + chunk
        state in the carry) stays bit-identical under sharding - byte
        ledgers included."""
        zoo = [w.with_overrides(chunk_tokens=16)
               for w in _zoo(n_runs=N_DEV)]
        for sh, ref in zip(compare_workloads(zoo, devices=N_DEV),
                           compare_workloads(zoo, devices=1)):
            self._assert_same(sh, ref)
            assert (sh.coherent.delta_bytes_mean
                    == ref.coherent.delta_bytes_mean)
            assert (sh.coherent.full_bytes_mean
                    == ref.coherent.full_bytes_mean)
            assert (sh.coherent.n_chunks_fetched_mean
                    == ref.coherent.n_chunks_fetched_mean)

    def test_workloads_axis_fallback_path(self):
        # 6 zoo families with a run count that does not divide: on 2,
        # 3 or 6 devices the planner shards the workload axis instead.
        for d in (2, 3, 6):
            if d > N_DEV or 6 % d:
                continue
            zoo = _zoo(n_runs=d + 1)
            assert shard_plan(6, d + 1, devices=d).axis == "workloads"
            for sh, ref in zip(compare_workloads(zoo, devices=d),
                               compare_workloads(zoo, devices=1)):
                self._assert_same(sh, ref)
            return
        pytest.skip(f"no divisor of 6 in 2..{N_DEV}")

    @pytest.mark.pallas
    def test_pallas_tick_route_per_device(self):
        """The kernel route under shard_map matches the single-device
        scan path - per-device Pallas routing changes nothing."""
        scn = small(n_runs=2 * N_DEV)
        sh = run_scenario(scn, tick_backend="pallas", devices=N_DEV)
        ref = run_scenario(scn, tick_backend="scan", devices=1)
        np.testing.assert_array_equal(sh.per_run_total_tokens,
                                      ref.per_run_total_tokens)
        np.testing.assert_array_equal(sh.per_run_chr, ref.per_run_chr)

    def test_oracle_replays_sharded_cells(self):
        """Global-run-index schedule: any sharded cell is the trace the
        differential oracle replays for (seed, run) - which ties the
        sharded ledgers to MESI states/versions via the four-way
        harness."""
        scn = small(n_runs=2 * N_DEV)
        sh = run_scenario(scn, devices=N_DEV)
        for r in (0, N_DEV - 1, 2 * N_DEV - 1):
            trace = oracle.sample_trace(
                scn.acs, oracle.episode_key(scn.seed, r))
            ledger, _, _, _ = oracle.replay_vectorized(scn.acs, trace)
            assert int(sh.per_run_total_tokens[r]) == ledger.total_tokens


@multi_device
class TestOneCompilationSharded:
    def test_sharded_sweep_is_one_program(self):
        base = small(seed=1357, n_runs=N_DEV)
        with engine.trace_counter() as tc:
            sweep_volatility(base, (0.05, 0.10, 0.25, 0.50),
                             devices=N_DEV)
            assert tc.count == 1
            sweep_volatility(base, (0.01, 0.33, 0.66, 0.99),
                             devices=N_DEV)
            assert tc.count == 1

    def test_sharded_zoo_is_one_program(self):
        zoo = _zoo(n_runs=N_DEV)
        with engine.trace_counter() as tc:
            compare_workloads(zoo, devices=N_DEV)
            assert tc.count == 1
            compare_workloads(zoo, devices=N_DEV)
            assert tc.count == 1


@pytest.mark.slow
def test_forced_host_devices_end_to_end():
    """Acceptance check runnable on any host: a subprocess with 8
    forced host CPU devices runs the sharded sweep bit-identical to
    the single-device path in one compilation."""
    script = textwrap.dedent("""
        import dataclasses, numpy as np, jax
        assert jax.local_device_count() == 8, jax.local_device_count()
        from repro.sim import canonical, engine, sweep_volatility
        base = dataclasses.replace(
            canonical("ci-sharded", 0.25, 4242, n_steps=6,
                      artifact_tokens=64), n_runs=8)
        vols = (0.05, 0.10, 0.25, 0.50)
        with engine.trace_counter() as tc:
            sh = sweep_volatility(base, vols, devices=8)
            assert tc.count == 1, tc.count
        ref = sweep_volatility(base, vols, devices=1)
        for a, b in zip(sh, ref):
            assert a.broadcast.total_tokens_mean == \\
                b.broadcast.total_tokens_mean
            assert a.coherent.total_tokens_mean == \\
                b.coherent.total_tokens_mean
            assert a.savings_mean == b.savings_mean
        print("SHARDED-OK")
    """)
    env = dict(os.environ)
    env.update({
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep)).rstrip(
                os.pathsep),
    })
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "SHARDED-OK" in proc.stdout
