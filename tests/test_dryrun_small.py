"""Dry-run machinery tests on the single-CPU host mesh.

The full 512-device dry-run is exercised by ``repro.launch.dryrun`` (see
EXPERIMENTS.md SSDry-run); here we validate the same lowering path - step
factories, sharding specs, ShapeDtypeStruct plumbing, collective parser,
analytic cost model - end to end on a 1x1 mesh so it runs in seconds.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (SHAPES, get, input_specs, n_active_params,
                           shapes_for, smoke_config)
from repro.launch import analytic as an
from repro.launch import roofline as rf
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime import steps as step_factories


def _flops(compiled) -> float:
    return rf.cost_analysis_dict(compiled).get("flops", 0)


def test_host_mesh_lowering_train_step():
    cfg = smoke_config("qwen3-1.7b")
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: tf.init_params(cfg, k), key)
    batch_shape = {
        "tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    opt_cfg = adamw.AdamWConfig()
    opt_shape = jax.eval_shape(
        lambda: adamw.init_state(opt_cfg, params_shape))
    with mesh:
        fn, in_sh, _ = step_factories.make_train_step(
            cfg, opt_cfg, mesh, params_shape, batch_shape)
        lowered = fn.lower(params_shape, opt_shape, batch_shape)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    assert _flops(compiled) > 0


@pytest.mark.parametrize("shape_name", ["decode_32k"])
def test_host_mesh_lowering_decode_step(shape_name):
    cfg = smoke_config("gemma-2b")
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: tf.init_params(cfg, k), key)
    cache_shape = jax.eval_shape(lambda: tf.init_cache(cfg, 2, 64))
    with mesh:
        fn, in_sh, _ = step_factories.make_decode_step(
            cfg, mesh, params_shape, cache_shape)
        lowered = fn.lower(
            params_shape,
            jax.ShapeDtypeStruct((2, 1), jnp.int32), cache_shape)
        compiled = lowered.compile()
    assert _flops(compiled) > 0


class TestCollectiveParser:
    def test_parses_result_shapes(self):
        hlo = """
  %ar = bf16[16,512]{1,0} all-reduce(bf16[16,512]{1,0} %x), replica_groups={}
  %ag.1 = f32[4,128]{1,0} all-gather(f32[1,128]{1,0} %y), dimensions={0}
  %nope = bf16[2,2]{1,0} add(bf16[2,2] %a, bf16[2,2] %b)
"""
        stats = rf.collective_bytes_from_hlo(hlo)
        assert stats.n_ops == 2
        assert stats.by_op["all-reduce"] == 16 * 512 * 2
        assert stats.by_op["all-gather"] == 4 * 128 * 4

    def test_async_pairs_counted_once(self):
        hlo = """
  %s = (bf16[8]{0}, bf16[8]{0}) all-reduce-start(bf16[8]{0} %x)
  %d = bf16[8]{0} all-reduce-done((bf16[8], bf16[8]) %s)
"""
        stats = rf.collective_bytes_from_hlo(hlo)
        assert stats.n_ops == 1

    def test_extrapolation(self):
        c1 = rf.CollectiveStats(total_bytes=100,
                                by_op={"all-reduce": 100}, n_ops=2)
        c2 = rf.CollectiveStats(total_bytes=160,
                                by_op={"all-reduce": 160}, n_ops=3)
        out = rf.extrapolate_body(c1, c2, n_super=10)
        assert out.total_bytes == 100 + 60 * 9


class TestAnalyticCost:
    def test_dense_train_close_to_6nd(self):
        """For a dense LM the analytic total ~ 6*N*D + attention."""
        cfg = get("yi-9b")
        shape = SHAPES["train_4k"]
        cost = an.analytic_cost(cfg, shape, 256)
        n = 8.83e9
        tokens = 256 * 4096
        six_nd = 6 * n * tokens
        assert 0.9 * six_nd < cost.flops_total < 1.6 * six_nd

    def test_moe_counts_active_params_only(self):
        cfg = get("olmoe-1b-7b")
        shape = SHAPES["train_4k"]
        cost = an.analytic_cost(cfg, shape, 256)
        total6nd = 6 * 6.92e9 * 256 * 4096       # all experts
        active6nd = 6 * n_active_params(cfg) * 256 * 4096
        assert cost.flops_total < 0.6 * total6nd
        assert cost.flops_total > 0.8 * active6nd

    def test_decode_is_memory_bound(self):
        cfg = get("command-r-35b")
        cost = an.analytic_cost(cfg, SHAPES["decode_32k"], 256)
        compute_s = cost.flops_total / 256 / rf.PEAK_FLOPS
        memory_s = cost.hbm_bytes_per_chip / rf.HBM_BW
        assert memory_s > compute_s  # decode streams weights + KV

    def test_long_context_shapes_only_for_sub_quadratic(self):
        names = {s.name for s in shapes_for(get("rwkv6-1.6b"))}
        assert "long_500k" in names
        names = {s.name for s in shapes_for(get("yi-9b"))}
        assert "long_500k" not in names

    def test_input_specs_no_allocation(self):
        """input_specs must return ShapeDtypeStructs (zero allocation)."""
        for arch in ("gemma-2b", "jamba-1.5-large-398b",
                     "whisper-medium", "llama-3.2-vision-90b"):
            cfg = get(arch)
            for shape in shapes_for(cfg):
                specs = input_specs(cfg, shape)
                for leaf in jax.tree.leaves(
                        specs, is_leaf=lambda x: isinstance(
                            x, jax.ShapeDtypeStruct)):
                    assert isinstance(leaf, jax.ShapeDtypeStruct), (
                        arch, shape.name)
