"""Unit tests for the MESI state encoding and transition table."""

import numpy as np
import pytest

from repro.core.states import (CoherenceEvent, MESIState, TRANSITION_TABLE,
                               is_valid, transition)


def test_state_encoding_orders_validity():
    # T(I)=0; T(S)=T(E)=T(M)=1 via the >= S trick used everywhere
    assert not is_valid(MESIState.I)
    for s in (MESIState.S, MESIState.E, MESIState.M):
        assert is_valid(s)


def test_mesi_transition_table_matches_protocol():
    # read causes I -> S only via fetch; reads from valid states self-loop
    assert transition(MESIState.S, CoherenceEvent.LOCAL_READ) == MESIState.S
    assert transition(MESIState.I, CoherenceEvent.FETCH) == MESIState.S
    # write causes S -> M via upgrade (S->E) then local write (E->M)
    e = transition(MESIState.S, CoherenceEvent.UPGRADE)
    assert e == MESIState.E
    assert transition(e, CoherenceEvent.LOCAL_WRITE) == MESIState.M
    # commit publishes: M -> S
    assert transition(MESIState.M, CoherenceEvent.COMMIT) == MESIState.S
    # remote write invalidates every state
    for s in MESIState:
        assert transition(s, CoherenceEvent.REMOTE_WRITE) == MESIState.I


def test_illegal_transitions_raise():
    with pytest.raises(ValueError):
        transition(MESIState.I, CoherenceEvent.LOCAL_READ)
    with pytest.raises(ValueError):
        transition(MESIState.I, CoherenceEvent.LOCAL_WRITE)
    with pytest.raises(ValueError):
        transition(MESIState.S, CoherenceEvent.LOCAL_WRITE)  # needs upgrade


def test_table_shape_and_legality_pattern():
    assert TRANSITION_TABLE.shape == (4, 6)
    legal = TRANSITION_TABLE >= 0
    # exactly the protocol's legal (state, event) pairs
    assert int(legal.sum()) == 13
    assert (TRANSITION_TABLE[legal] <= int(MESIState.M)).all()
    assert (np.diff(np.sort(np.unique(TRANSITION_TABLE))) > 0).all()
