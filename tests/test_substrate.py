"""Substrate tests: optimizer, data pipeline, checkpointing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, PrefetchLoader, SyntheticLMStream
from repro.optim import (AdamWConfig, apply_updates, clip_by_global_norm,
                         compress_grads, global_norm, init_state,
                         lr_schedule)


class TestAdamW:
    def setup_method(self):
        self.cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=100,
                               weight_decay=0.0)
        key = jax.random.PRNGKey(0)
        self.params = {"layer": {"w": jax.random.normal(key, (8, 8)),
                                 "norm": {"scale": jnp.ones((8,))}}}

    def test_minimizes_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        cfg = AdamWConfig(lr=0.2, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, grad_clip=100.0)
        state = init_state(cfg, params)
        loss = lambda p: jnp.sum(jnp.square(p["w"]))
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = apply_updates(cfg, params, g, state)
        assert float(loss(params)) < 1e-3

    def test_warmup_cosine_schedule(self):
        cfg = self.cfg
        assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(0.1)
        end = float(lr_schedule(cfg, jnp.asarray(100)))
        assert end == pytest.approx(0.1 * cfg.min_lr_ratio, rel=1e-3)

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
        assert float(norm) == pytest.approx(200.0)

    def test_weight_decay_skips_norm_params(self):
        cfg = dataclasses.replace(self.cfg, weight_decay=0.5,
                                  warmup_steps=0)
        state = init_state(cfg, self.params)
        zero_grads = jax.tree.map(jnp.zeros_like, self.params)
        new_params, _, _ = apply_updates(cfg, self.params, zero_grads,
                                         state)
        # weights decayed, norm scales untouched
        assert not np.allclose(new_params["layer"]["w"],
                               self.params["layer"]["w"])
        np.testing.assert_array_equal(
            new_params["layer"]["norm"]["scale"],
            self.params["layer"]["norm"]["scale"])

    def test_bf16_moments_option(self):
        cfg = dataclasses.replace(self.cfg, moment_dtype="bfloat16")
        state = init_state(cfg, self.params)
        assert state.mu["layer"]["w"].dtype == jnp.bfloat16

    @given(st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_error_feedback_is_lossless_in_expectation(self, seed):
        """compress + error feedback: sum of transmitted bf16 grads
        converges to sum of true grads (residual stays bounded)."""
        key = jax.random.PRNGKey(seed)
        g = {"w": jax.random.normal(key, (64,)) * 1e-3}
        err = {"w": jnp.zeros((64,), jnp.float32)}
        sent_total = jnp.zeros((64,), jnp.float32)
        true_total = jnp.zeros((64,), jnp.float32)
        for i in range(20):
            gi = jax.tree.map(lambda x: x * (1 + 0.1 * i), g)
            comp, err = compress_grads(gi, err)
            sent_total = sent_total + comp["w"].astype(jnp.float32)
            true_total = true_total + gi["w"]
        resid = float(jnp.max(jnp.abs(sent_total + err["w"] - true_total)))
        assert resid < 1e-5


class TestDataPipeline:
    def test_determinism_and_restart_contract(self):
        cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
        s = SyntheticLMStream(cfg)
        b1 = s.batch_at(7)
        b2 = s.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].shape == (8, 16)
        assert b1["tokens"].min() >= 1
        assert b1["tokens"].max() < 1000

    def test_host_sharding_partitions_global_batch(self):
        cfg = DataConfig(vocab_size=1000, seq_len=8, global_batch=8)
        shards = [SyntheticLMStream(cfg, host_id=h, n_hosts=4)
                  for h in range(4)]
        batches = [s.batch_at(3)["tokens"] for s in shards]
        assert all(b.shape == (2, 8) for b in batches)
        # different hosts draw different data
        assert not np.array_equal(batches[0], batches[1])

    def test_prefetch_loader_orders_steps(self):
        cfg = DataConfig(vocab_size=100, seq_len=4, global_batch=2,
                         prefetch=2)
        loader = PrefetchLoader(SyntheticLMStream(cfg), start_step=5)
        try:
            steps = [next(loader)[0] for _ in range(4)]
            assert steps == [5, 6, 7, 8]
        finally:
            loader.close()


class TestCheckpoint:
    def make_tree(self, x=1.0):
        return {"params": {"w": jnp.full((4, 4), x)},
                "opt": {"mu": jnp.zeros((4, 4)),
                        "step": jnp.asarray(3)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(10, self.make_tree(2.5), meta={"arch": "x"})
        step, tree = mgr.restore()
        assert step == 10
        np.testing.assert_array_equal(tree["params"]["w"],
                                      np.full((4, 4), 2.5))
        assert mgr.meta(10)["arch"] == "x"

    def test_latest_and_retention(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self.make_tree(float(s)))
        assert mgr.latest_step() == 4
        assert mgr.all_steps() == [3, 4]  # old ones GC'd

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save_async(7, self.make_tree())
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_torn_checkpoint_is_ignored(self, tmp_path):
        """Crash-mid-save leaves no visible checkpoint (atomicity)."""
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, self.make_tree())
        # simulate a crash: partial dir without manifest
        bad = tmp_path / "step_0000000009"
        bad.mkdir()
        (bad / "arrays.npz").write_bytes(b"garbage")
        assert mgr.latest_step() == 1

    def test_restore_with_resharding(self, tmp_path):
        """Elastic restart: restore onto explicit shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        mgr = CheckpointManager(tmp_path)
        mgr.save(2, self.make_tree(1.5))
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), self.make_tree())
        step, tree = mgr.restore(shardings=shardings)
        assert step == 2
        assert tree["params"]["w"].sharding == NamedSharding(mesh, P())
