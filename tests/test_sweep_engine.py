"""Fused sweep engine: equivalence + one-compilation guarantees.

The tentpole contract of the sweep engine (``repro.sim.engine``):

  1. the fused one-program ``sweep_volatility`` / ``compare_grid``
     reproduce the per-cell loop results **bit-for-bit** at fixed seed
     (volatility is traced, but traced-vs-static Bernoulli parameters
     draw identical bits);
  2. a whole (variant x volatility x run) sweep compiles exactly ONE
     XLA program, and re-sweeping with different volatility values (same
     static shape) compiles ZERO more;
  3. the Pallas MESI-tick backend agrees with the scan backend on every
     token-traffic metric.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import acs
from repro.sim import (SCENARIOS, canonical, compare, compare_grid,
                       run_scenario, sweep_volatility)
from repro.sim import engine


def small(name="sweep-test", v=0.25, seed=777, **kw):
    params = dict(n_steps=6, artifact_tokens=64, n_runs=4)
    params.update(kw)
    n_runs = params.pop("n_runs")
    return dataclasses.replace(
        canonical(name, v, seed, **params), n_runs=n_runs)


def _loop_reference(base_scn, volatilities, n_runs):
    """Per-cell loop path: one program per (volatility, variant), two
    separate launches per cell - the seed engine's behavior."""
    out = []
    for scn in engine.sweep_cells(base_scn, volatilities, n_runs):
        keys = engine._grid_keys([scn.seed], n_runs)[0]
        cells = {}
        for tag, strat in (("broadcast", acs.BROADCAST),
                           ("coherent", scn.acs.strategy)):
            cfg = dataclasses.replace(scn.acs, strategy=strat)
            fn = jax.jit(jax.vmap(
                lambda k, _cfg=cfg: engine._episode_metrics(_cfg, k)))
            cells[tag] = jax.device_get(fn(keys))
        out.append(cells)
    return out


class TestBitForBitEquivalence:
    def test_fused_sweep_matches_loop_reference(self):
        base = small()
        vols = (0.05, 0.25, 0.75, 1.0)
        n_runs = 4
        fused = sweep_volatility(base, vols, n_runs=n_runs)
        loop = _loop_reference(base, vols, n_runs)
        for cmp_, ref in zip(fused, loop):
            bc_total = np.asarray(ref["broadcast"]["total_tokens"],
                                  np.float64)
            co_total = np.asarray(ref["coherent"]["total_tokens"],
                                  np.float64)
            co_chr = np.asarray(ref["coherent"]["cache_hit_rate"],
                                np.float64)
            # exact (== not approx): the fused program must draw the
            # very same random bits as the loop path
            assert cmp_.broadcast.total_tokens_mean == float(
                bc_total.mean())
            assert cmp_.coherent.total_tokens_mean == float(
                co_total.mean())
            assert cmp_.chr_mean == float(co_chr.mean())
            savings = 1.0 - co_total / bc_total.mean()
            assert cmp_.savings_mean == float(savings.mean())
            assert cmp_.savings_std == float(savings.std())

    def test_run_scenario_per_run_tokens_match_loop(self):
        scn = small()
        res = run_scenario(scn)
        keys = engine._grid_keys([scn.seed], scn.n_runs)[0]
        fn = jax.jit(jax.vmap(
            lambda k: engine._episode_metrics(scn.acs, k)))
        ref = jax.device_get(fn(keys))
        np.testing.assert_array_equal(
            res.per_run_total_tokens,
            np.asarray(ref["total_tokens"], np.float64))

    def test_compare_is_sweep_point(self):
        """compare == the matching cell of a fused multi-point sweep."""
        base = small()
        vols = (0.1, 0.5)
        fused = sweep_volatility(base, vols, n_runs=4)
        for v, cell in zip(vols, fused):
            scn = dataclasses.replace(
                base,
                acs=dataclasses.replace(base.acs, volatility=v),
                n_runs=4, seed=base.seed + int(round(v * 1000)))
            single = compare(scn)
            assert single.coherent.total_tokens_mean == \
                cell.coherent.total_tokens_mean
            assert single.broadcast.total_tokens_mean == \
                cell.broadcast.total_tokens_mean
            assert single.savings_mean == cell.savings_mean


class TestOneCompilation:
    """All compile-count assertions go through ``engine.trace_counter``:
    the process-global counter leaks across test modules, so a bare
    ``reset_trace_count()`` here would make every other module's
    accounting (and ours) import-order dependent."""

    def test_sweep_compiles_one_program(self):
        """A 4-point V sweep (broadcast + coherent, vmapped runs) is ONE
        trace; the seed path paid >= 8."""
        base = small(seed=13579)
        with engine.trace_counter() as tc:
            sweep_volatility(base, (0.05, 0.10, 0.25, 0.50), n_runs=4)
            assert tc.count == 1

    def test_resweep_same_shape_does_not_retrace(self):
        base = small(seed=24680)
        with engine.trace_counter() as tc:
            sweep_volatility(base, (0.05, 0.10, 0.25, 0.50), n_runs=4)
            n0 = tc.count
            sweep_volatility(base, (0.01, 0.33, 0.66, 0.99), n_runs=4)
            sweep_volatility(base, (0.2, 0.4, 0.6, 0.8), n_runs=4)
            assert tc.count == n0 == 1

    def test_repeated_compare_hits_cache(self):
        scn = small(seed=112233)
        with engine.trace_counter() as tc:
            compare(scn)
            n0 = tc.count
            # different volatility/seed, same statics -> zero new traces
            compare(dataclasses.replace(
                scn, seed=445566,
                acs=dataclasses.replace(scn.acs, volatility=0.9)))
            assert tc.count == n0

    def test_compare_grid_groups_by_static_shape(self):
        """Heterogeneous scenario lists compile once per static group."""
        a = small(seed=1, n_steps=6)
        b = small(seed=2, v=0.9, n_steps=6)
        c = small(seed=3, n_steps=8)  # different scan length
        with engine.trace_counter() as tc:
            compare_grid([a, b, c])
            assert tc.count == 2

    def test_grid_cache_keys_on_full_shard_plan(self):
        """Regression: the grid cache must key on the FULL resolved
        ShardPlan.  Two plans over the same devices/axis that differ
        only in ``pad_runs`` are different programs (the padded run
        axis is baked into the grid shape); a key of just
        ``(devices, axis)`` would serve plan A's program to plan B."""
        cfg = small(seed=97531).acs
        plan_a = engine.ShardPlan(devices=1, axis=None, pad_runs=4)
        plan_b = engine.ShardPlan(devices=1, axis=None, pad_runs=8)
        fn_a = engine._grid_fn(cfg, False, "scan", plan_a)
        fn_b = engine._grid_fn(cfg, False, "scan", plan_b)
        assert fn_a is not fn_b
        # same full plan -> same cached program (no retrace)
        assert engine._grid_fn(cfg, False, "scan", plan_a) is fn_a
        het_a = engine._het_grid_fn(cfg, False, "scan", plan_a)
        het_b = engine._het_grid_fn(cfg, False, "scan", plan_b)
        assert het_a is not het_b
        assert engine._het_grid_fn(cfg, False, "scan", plan_a) is het_a

    def test_trace_counter_is_isolated(self):
        """Nested scopes see only their own compilations, and the
        legacy global counter still advances for old callers."""
        base = small(seed=86420)
        before = engine.trace_count()
        with engine.trace_counter() as outer:
            sweep_volatility(base, (0.1, 0.9), n_runs=4)
            with engine.trace_counter(clear_cache=False) as inner:
                # warm cache, same shape: nothing compiles in here
                sweep_volatility(base, (0.3, 0.7), n_runs=4)
                assert inner.count == 0
            assert outer.count == 1
        assert engine.trace_count() == before + 1


@pytest.mark.pallas
class TestPallasTickBackend:
    @pytest.mark.parametrize("code", [acs.LAZY, acs.EAGER,
                                      acs.ACCESS_COUNT])
    def test_token_metrics_match_scan(self, code):
        scn = small(seed=5150).with_strategy(code)
        a = run_scenario(scn, tick_backend="scan")
        b = run_scenario(scn, tick_backend="pallas")
        np.testing.assert_array_equal(a.per_run_total_tokens,
                                      b.per_run_total_tokens)
        np.testing.assert_array_equal(a.per_run_chr, b.per_run_chr)
        for f in ("fetch_tokens_mean", "signal_tokens_mean",
                  "push_tokens_mean", "n_fetches_mean", "n_reads_mean",
                  "n_writes_mean"):
            assert getattr(a.stats, f) == getattr(b.stats, f), f

    def test_unsupported_strategies_fall_back_to_scan(self):
        cfg = SCENARIOS["B"].with_strategy(acs.TTL).acs
        assert engine.resolve_tick_backend(cfg, 10_000) == "scan"
        cfg = SCENARIOS["B"].with_overrides(max_stale_steps=3).acs
        assert engine.resolve_tick_backend(cfg, 10_000) == "scan"

    def test_forced_pallas_on_ttl_still_computes_ttl_semantics(self):
        """An explicit tick_backend='pallas' on a kernel-unsupported
        strategy must fall back to scan, not silently run lazy."""
        scn = small(seed=8642).with_strategy(acs.TTL)
        a = run_scenario(scn, tick_backend="scan")
        b = run_scenario(scn, tick_backend="pallas")
        np.testing.assert_array_equal(a.per_run_total_tokens,
                                      b.per_run_total_tokens)
        # TTL epoch refreshes are real fetches; lazy-at-V=0.25 would
        # differ, so equality here means TTL semantics were preserved
        assert b.stats.max_version_lag_max == a.stats.max_version_lag_max

    def test_pallas_staleness_reports_not_tracked_sentinel(self):
        scn = small(seed=9753)
        b = run_scenario(scn, tick_backend="pallas")
        assert b.stats.max_staleness_max == -1
        assert b.stats.max_version_lag_max == -1
