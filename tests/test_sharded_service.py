"""Sharded-service tier: the K-shard authority plane + host L1s.

Covers the load-bearing properties of ``repro.service.sharding`` and
the layered config surface (see tests/README.md "Sharded-service
tier"):

  * hash-of-artifact routing is stable and partitions the directory;
  * K in {1, 2, 4} produce **bit-identical** token ledgers, MESI
    directories and versions on an adversarial cross-shard ping-pong
    workload, and the K=4 trace survives the full conformance closure
    (four-way oracle + cross-shard decomposition + L1/L2 legs);
  * the chunked content plane survives sharding byte-exactly;
  * L1 fill attribution and the explicit L1-invalidation path behave,
    and a stale L1 entry past the version-lag bound raises
    ``InvariantViolation`` (white-box);
  * ``connect(...)`` resolves topologies to the right implementation;
  * the layered ``CoherenceConfig`` and the legacy ``BrokerConfig``
    shim build byte-identical brokers, and the shim warns exactly once.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.configs import (CoherenceConfig, CoherenceCore, ServiceLayer,
                           ShardTopology, shard_of_artifact)
from repro.service import (BrokerConfig, CoherenceBroker,
                           HostL1Directory, InvariantViolation,
                           ServicePortal, ShardedCoherenceBroker,
                           connect, resolve_broker, verify_broker)
from repro.service import broker as broker_mod
from repro.service.trace import verify_sharded_broker
from repro.sim import oracle

pytestmark = [pytest.mark.service, pytest.mark.sharded]


def _names(m: int) -> tuple:
    return tuple(f"artifact-{d}" for d in range(m))


def _config(n: int = 4, m: int = 6, tokens: int = 32,
            **kw) -> CoherenceConfig:
    return CoherenceConfig.make(n, _names(m), artifact_tokens=tokens,
                                **kw)


def _ping_pong_schedule(n: int, m: int, rounds: int, seed: int = 7):
    """Adversarial cross-shard ping-pong: every agent alternates
    between writing its 'own' artifact and reading its neighbor's, so
    ownership bounces between shards every round and every read is a
    fresh invalidation miss."""
    rng = np.random.default_rng(seed)
    schedule = []
    for r in range(rounds):
        actions = []
        for a in range(n):
            if (r + a) % 2 == 0:
                actions.append((a, a % m, True))
            else:
                actions.append((a, (a + 1) % m, False))
        if rng.random() < 0.5:          # occasional contended artifact
            actions.append((n - 1, 0, bool(rng.random() < 0.5)))
        schedule.append(actions[:n])    # at most one action per agent
        # dedupe agents (the contended extra may collide)
        seen, uniq = set(), []
        for a, d, w in schedule[-1]:
            if a not in seen:
                seen.add(a)
                uniq.append((a, d, w))
        schedule[-1] = uniq
    return schedule


async def _drive(broker, schedule, names):
    for actions in schedule:
        await asyncio.gather(*(
            broker.write(a, names[d]) if w else broker.read(a, names[d])
            for a, d, w in actions))


def _run_topology(shards: int, hosts: int, rounds: int = 12,
                  verify: bool = False, **kw):
    async def go():
        cfg = _config(shards=shards, hosts=hosts, **kw)
        async with connect(cfg) as broker:
            schedule = _ping_pong_schedule(cfg.n_agents,
                                           len(cfg.artifacts), rounds)
            await _drive(broker, schedule, cfg.artifacts)
            led = dataclasses.astuple(broker.ledger)
            state = np.array(broker.directory_state)
            version = np.array(broker.versions)
            if verify:
                verify_broker(broker)
            return led, state, version, broker.stats()
    return asyncio.run(go())


# ---------------------------------------------------------------------------
# Routing.


def test_shard_routing_stable_and_partitioning():
    # crc32 routing is process-independent: pin the actual values so a
    # refactor to Python's randomized hash() can never slip through
    assert shard_of_artifact("artifact-0", 1) == 0
    for k in (2, 4, 8):
        vals = [shard_of_artifact(f"artifact-{d}", k) for d in range(16)]
        assert all(0 <= v < k for v in vals)
        assert vals == [shard_of_artifact(f"artifact-{d}", k)
                        for d in range(16)]
    cfg = _config(m=6, shards=4)
    owned = cfg.shard_artifact_indices()
    flat = sorted(d for cols in owned for d in cols)
    assert flat == list(range(6))
    for d, s in enumerate(cfg.artifact_shards()):
        assert d in owned[s]


def test_explicit_assignment_overrides_hash():
    cfg = _config(m=4, shards=2, assignment=(0, 0, 1, 1))
    assert cfg.artifact_shards() == (0, 0, 1, 1)
    with pytest.raises(ValueError):
        _config(m=4, shards=2, assignment=(0, 2, 1, 1))


def test_sharded_forbids_simulator_staleness():
    with pytest.raises(ValueError, match="K-staleness|staleness"):
        _config(shards=2, max_stale_steps=2)
    # trivial topology keeps supporting it
    cfg = _config(max_stale_steps=2)
    assert cfg.core.max_stale_steps == 2


# ---------------------------------------------------------------------------
# The tentpole property: sharding changes NOTHING observable.


def test_cross_shard_ping_pong_bit_exact():
    """K in {1, 2, 4} on the adversarial ping-pong: bit-identical
    ledgers, directories and versions; K=4 survives the full
    conformance closure (global four-way + cross-shard + L1/L2)."""
    led1, st1, ver1, _ = _run_topology(1, 1)
    led2, st2, ver2, _ = _run_topology(2, 2)
    led4, st4, ver4, stats4 = _run_topology(4, 2, verify=True)
    assert led1 == led2 == led4
    np.testing.assert_array_equal(st1, st2)
    np.testing.assert_array_equal(st1, st4)
    np.testing.assert_array_equal(ver1, ver2)
    np.testing.assert_array_equal(ver1, ver4)
    assert stats4["topology"]["n_shards"] == 4
    assert sum(stats4["topology"]["shard_artifacts"]) == 6
    assert stats4["l1"]["l1_fills"] + stats4["l1"]["l2_fills"] > 0


@pytest.mark.slow
def test_sharded_chunked_byte_exact():
    """The chunk-granular content plane survives sharding: summed wire
    ledgers equal the single broker's, and the K=2 run passes the
    byte-exact content leg of the sharded verifier."""
    async def go(shards, hosts):
        cfg = _config(n=4, m=6, tokens=64, chunk_tokens=16,
                      shards=shards, hosts=hosts)
        # writers edit ONE 16-token chunk per commit, so the measured
        # dirty set (and hence delta traffic) stays chunk-granular
        docs = {nm: list(range(64)) for nm in cfg.artifacts}
        contents = {nm: list(v) for nm, v in docs.items()}
        async with connect(cfg, contents=contents) as broker:
            for r in range(8):
                jobs = []
                for a in range(4):
                    name = cfg.artifacts[(a + r) % 6]
                    if (r + a) % 3 == 0:
                        lo = ((r + a) % 4) * 16
                        doc = list(docs[name])
                        doc[lo:lo + 16] = [1000 * r + a] * 16
                        docs[name] = doc
                        jobs.append(broker.write(a, name, doc))
                    else:
                        jobs.append(broker.read(a, name))
                await asyncio.gather(*jobs)
            wire = dict(broker.wire)
            led = dataclasses.astuple(broker.ledger)
            if shards > 1:
                verify_sharded_broker(broker)
            return wire, led

    wire1, led1 = asyncio.run(go(1, 1))
    wire2, led2 = asyncio.run(go(2, 2))
    assert led1 == led2
    assert wire1 == wire2
    assert wire2["delta_bytes"] < wire2["full_bytes"]


def test_sharded_trace_records_global_commit_order():
    async def go():
        cfg = _config(m=6, shards=2)
        async with connect(cfg) as broker:
            schedule = _ping_pong_schedule(4, 6, 6)
            await _drive(broker, schedule, cfg.artifacts)
            return broker
    broker = asyncio.run(go())
    trace = broker.trace
    assert trace.n_shards == 2
    assert trace.artifact_shards == broker.artifact_shards
    shards_seen = {s.shard for s in trace.steps}
    assert shards_seen <= {0, 1} and len(shards_seen) == 2
    # every step is homogeneous: one shard's artifacts only
    for step in trace.steps:
        owners = {trace.artifact_shards[d] for d in step.arts}
        assert owners == {step.shard}
    # the cross-shard oracle leg accepts the global order
    oracle.check_sharded_trace(trace.acs_config(),
                               trace.to_oracle_trace(),
                               trace.artifact_shards, name="unit")


def test_shard_subtrace_projection():
    acts = np.array([[1, 1], [1, 0], [0, 1]], bool)
    arts = np.array([[0, 1], [2, 0], [0, 3]], np.int32)
    writes = np.array([[1, 0], [0, 0], [0, 1]], bool)
    trace = oracle.Trace(acts=acts, arts=arts, writes=writes)
    sub, cols = oracle.shard_subtrace(trace, (0, 1, 0, 1), 1)
    np.testing.assert_array_equal(cols, [1, 3])
    # steps 0 (agent 1 -> artifact 1) and 2 (agent 1 -> artifact 3)
    np.testing.assert_array_equal(sub.acts,
                                  [[False, True], [False, True]])
    np.testing.assert_array_equal(sub.arts[sub.acts], [0, 1])
    np.testing.assert_array_equal(sub.writes[sub.acts], [False, True])


# ---------------------------------------------------------------------------
# L1 plane.


def test_l1_attribution_and_invalidation():
    """Same-host re-fills are L1-attributed; a commit invalidates every
    other host's entry, so their next fill crosses to L2 again."""
    async def go():
        # agents 0,1 -> host 0; agents 2,3 -> host 1; one shard so the
        # schedule below is exactly the serialization order
        cfg = _config(m=2, shards=1, hosts=2, placement=(0, 0, 1, 1))
        async with ShardedCoherenceBroker(cfg) as broker:
            name = cfg.artifacts[0]
            await broker.write(2, name)        # v2: host 1 holds a copy
            await broker.read(0, name)         # host 0 cold -> L2 fill
            assert broker.l1_wire["l2_fills"] == 1
            await broker.read(1, name)         # same host, same version
            assert broker.l1_wire["l1_fills"] == 1
            await broker.write(3, name)        # invalidates host 0's L1
            assert broker.l1[0].lookup(name) is None
            # writer's host adopted the committed copy...
            entry = broker.l1[1].lookup(name)
            assert entry is not None and entry.version == 3
            await broker.read(0, name)         # host 0 must go to L2
            assert broker.l1_wire["l2_fills"] == 2
            await broker.read(2, name)         # host 1 serves locally
            assert broker.l1_wire["l1_fills"] == 2
            return dict(broker.l1_wire)
    wire = asyncio.run(go())
    assert wire["l1_bytes"] + wire["l2_bytes"] > 0


def test_l1_staleness_whitebox():
    """A valid L1 entry past the version-lag bound is an invariant
    violation - both at fill-attribution time and in the sweep."""
    async def go():
        cfg = _config(m=2, shards=1, hosts=2, placement=(0, 0, 1, 1))
        async with ShardedCoherenceBroker(cfg) as broker:
            name = cfg.artifacts[0]
            await broker.write(0, name)            # v2, host 0 adopts
            # white-box corruption: resurrect a stale entry on host 1,
            # as if the invalidation signal had been lost
            broker.l1[1].fill(name, 1, tuple(broker.brokers[0]
                                             .store.get(name)))
            await broker.write(0, name)            # v3 -> lag now 2
            broker.l1[1].fill(name, 1, (0,) * 32)  # re-lose the signal
            with pytest.raises(InvariantViolation, match="L1 staleness"):
                broker.check_l1()
            # the read path catches it too, before attributing the fill
            with pytest.raises(InvariantViolation, match="L1 staleness"):
                await broker.read(2, name)
            broker.l1[1].invalidate(name)          # heal for clean stop
    asyncio.run(go())


def test_l1_directory_unit():
    l1 = HostL1Directory(0, max_version_lag=1)
    l1.fill("a", 3, (1, 2))
    assert l1.lookup("a").version == 3
    l1.check("a", 4)                     # lag 1 == bound: fine
    with pytest.raises(InvariantViolation):
        l1.check("a", 5)                 # lag 2 > bound
    l1.invalidate("a")
    assert l1.lookup("a") is None
    assert l1.n_invalidations == 1
    l1.check("a", 99)                    # no entry, nothing to violate


# ---------------------------------------------------------------------------
# connect() resolver + config layering.


def test_connect_resolves_topology():
    trivial = connect(n_agents=2, artifacts=("a",), artifact_tokens=16)
    assert type(trivial) is CoherenceBroker
    sharded = connect(n_agents=2, artifacts=_names(4),
                      artifact_tokens=16, shards=2)
    assert isinstance(sharded, ShardedCoherenceBroker)
    l1_only = connect(n_agents=4, artifacts=("a",), artifact_tokens=16,
                      hosts=2)
    assert isinstance(l1_only, ShardedCoherenceBroker)
    with pytest.raises(TypeError):
        connect()
    with pytest.raises(TypeError):
        connect(_config(), n_agents=3)
    with pytest.raises(TypeError):
        connect(n_agents=2, artifacts=("a",), no_such_knob=1)


def test_connect_sync_portal_roundtrip():
    with connect(n_agents=2, artifacts=_names(2), artifact_tokens=16,
                 shards=2, sync=True) as portal:
        assert isinstance(portal, ServicePortal)
        assert isinstance(portal.broker, ShardedCoherenceBroker)
        client = portal.client(0)
        r = client.read("artifact-0")
        assert not r.hit
        w = client.write("artifact-1")
        assert w.version == 2


def test_adapters_flat_config_reads_over_sharded_broker():
    # regression: CoherentTool reads broker.config.artifact_tokens,
    # which on the sharded plane is the layered CoherenceConfig - the
    # flat core pass-through properties must keep adapter-style reads
    # topology-neutral (examples/coherent_service_demo.py hit this).
    from repro.service import CoherentClient, CoherentTool

    async def go():
        async with connect(n_agents=2, artifacts=_names(4),
                           artifact_tokens=16, shards=2,
                           hosts=2) as broker:
            tool = CoherentTool(CoherentClient(broker, 0))
            assert tool._tokens == 16
            await tool.acall("write", "artifact-1", "v2")
            r = await tool.acall("read", "artifact-1")
            assert r.version == 2
            cfg = broker.config
            assert (cfg.artifact_tokens, cfg.strategy, cfg.access_k,
                    cfg.max_stale_steps, cfg.chunk_tokens) == (
                16, cfg.core.strategy, cfg.core.access_k,
                cfg.core.max_stale_steps, cfg.core.chunk_tokens)

    asyncio.run(go())


def test_connect_accepts_legacy_broker_config():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = BrokerConfig(n_agents=2, artifacts=("a",),
                              artifact_tokens=16)
    broker = connect(legacy)
    assert type(broker) is CoherenceBroker
    assert broker.config.artifact_tokens == 16


def test_config_layering_golden_ledger(monkeypatch):
    """Legacy direct BrokerConfig and the layered CoherenceConfig build
    byte-identical brokers - and the deprecation shim warns exactly
    once per process, never through the blessed view path."""
    monkeypatch.setattr(broker_mod, "_LEGACY_WARNED", False)
    with pytest.warns(DeprecationWarning, match="thin frozen view"):
        legacy = BrokerConfig(n_agents=4, artifacts=_names(3),
                              artifact_tokens=32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # a second warn would raise
        BrokerConfig(n_agents=4, artifacts=_names(3),
                     artifact_tokens=32)
    monkeypatch.setattr(broker_mod, "_LEGACY_WARNED", False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # blessed path never warns
        layered = _config(n=4, m=3).broker_view()
    assert layered == legacy               # frozen views compare equal
    # round-trip: flat -> layered -> flat
    assert legacy.coherence_config().broker_view() == legacy

    async def run(config):
        async with CoherenceBroker(config) as broker:
            for r in range(6):
                await asyncio.gather(
                    broker.write(0, "artifact-0"),
                    broker.read(1, "artifact-0"),
                    broker.read(2, "artifact-1"))
            return dataclasses.astuple(broker.ledger)

    assert asyncio.run(run(legacy)) == asyncio.run(run(_config(n=4, m=3)))


def test_make_routes_knobs_to_layers():
    cfg = CoherenceConfig.make(
        4, _names(2), artifact_tokens=64, strategy="eager",
        batch_window=0.01, shards=2, hosts=2, l1_max_version_lag=1)
    assert cfg.core == CoherenceCore(artifact_tokens=64,
                                     strategy="eager")
    assert cfg.service == ServiceLayer(batch_window=0.01)
    assert cfg.topology == ShardTopology(n_shards=2, n_hosts=2,
                                         l1_max_version_lag=1)
    with pytest.raises(TypeError, match="unknown coherence knob"):
        CoherenceConfig.make(4, _names(2), tokens=64)


# ---------------------------------------------------------------------------
# Trace schema: shard stamping (v3) + back-compat loads.


def test_trace_shard_roundtrip_and_back_compat():
    async def go():
        cfg = _config(m=6, shards=2)
        async with connect(cfg) as broker:
            await _drive(broker, _ping_pong_schedule(4, 4, 4),
                         cfg.artifacts)
            return broker.trace
    trace = asyncio.run(go())
    payload = json.loads(trace.to_json())
    # v4 adds decide_s/batch_size (tests/test_obs.py covers those);
    # the shard stamping introduced in v3 must still round-trip
    assert payload["schema_version"] == 4
    assert payload["n_shards"] == 2
    restored = type(trace).from_json(trace.to_json())
    assert restored == trace
    # a v2 payload (no shard or timing fields) still loads, unsharded
    for step in payload["steps"]:
        step.pop("shard")
        step.pop("decide_s")
        step.pop("batch_size")
    payload.pop("n_shards")
    payload.pop("artifact_shards")
    payload["schema_version"] = 2
    old = type(trace).from_json(json.dumps(payload))
    assert old.n_shards == 1 and old.artifact_shards == ()
    assert all(s.shard == -1 for s in old.steps)
