"""Workload generator + heterogeneous fused-engine tests.

Covers: family generators produce valid rate matrices; the whole zoo
fuses into ONE compiled program with the rate matrices as traced axes
(re-running with different families/rates retraces nothing); and the
Pallas tick route agrees with the scan route bit-for-bit on
heterogeneous rates.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import acs
from repro.sim import engine, workloads

SMALL = dict(n_agents=5, n_artifacts=3, n_runs=3,
             artifact_tokens=64, n_steps=8)


def small_zoo(**kw):
    params = dict(SMALL)
    params.update(kw)
    return workloads.zoo(**params)


class TestGenerators:
    @pytest.mark.parametrize("family", sorted(workloads.FAMILIES))
    def test_family_produces_valid_rates(self, family):
        w = workloads.make(family, **SMALL)
        n, m = w.acs.n_agents, w.acs.n_artifacts
        assert w.p_act.shape == (n,)
        assert w.pick.shape == (n, m)
        assert w.write_rate.shape == (n, m)
        assert np.allclose(w.pick.sum(axis=1), 1.0)
        assert ((w.p_act >= 0) & (w.p_act <= 1)).all()
        assert ((w.write_rate >= 0) & (w.write_rate <= 1)).all()
        assert 0.0 <= w.effective_volatility() <= 1.0
        assert w.family == family

    def test_unknown_family_raises(self):
        with pytest.raises(KeyError, match="unknown workload family"):
            workloads.make("nope")

    def test_invalid_rates_rejected(self):
        w = workloads.make("zipf", **SMALL)
        with pytest.raises(ValueError, match="sum to 1"):
            dataclasses.replace(w, pick=w.pick * 0.5)
        with pytest.raises(ValueError, match="outside"):
            dataclasses.replace(w, write_rate=w.write_rate + 2.0)
        with pytest.raises(ValueError, match="do not match"):
            dataclasses.replace(w, p_act=np.r_[w.p_act, 0.5])

    def test_random_workload_is_valid(self):
        for seed in (0, 1, 2):
            w = workloads.random_workload(seed, n_agents=3, n_artifacts=2)
            assert np.allclose(w.pick.sum(axis=1), 1.0)

    def test_zoo_shares_one_static_signature(self):
        ws = small_zoo()
        keys = {engine._static_key(w.acs) for w in ws}
        assert len(keys) == 1
        assert len(ws) == len(workloads.FAMILIES)

    def test_structure_is_actually_heterogeneous(self):
        """ping-pong concentrates writes; rag is read-heavy - the
        families must span a wide effective-volatility range or the zoo
        tests nothing the scalar sweep didn't."""
        effs = {w.family: w.effective_volatility() for w in small_zoo()}
        assert effs["rag"] < 0.05
        assert effs["ping_pong"] > 0.5
        assert effs["ping_pong"] > 5 * effs["rag"]

    def test_effective_volatility_of_uniform_matches_scalar(self):
        cfg = acs.ACSConfig(n_agents=4, n_artifacts=3,
                            artifact_tokens=64, n_steps=8,
                            volatility=0.37)
        r = acs.uniform_rates(cfg)
        w = workloads.Workload(
            name="u", family="uniform", acs=cfg,
            p_act=np.asarray(r.p_act),
            pick=np.asarray(np.exp(r.log_pick)),
            write_rate=np.asarray(r.write_rate), seed=0)
        assert w.effective_volatility() == pytest.approx(0.37)


class TestFusedHeterogeneousGrid:
    def test_zoo_compiles_one_program(self):
        """The acceptance criterion: an entire heterogeneous zoo
        (variant x workload x run) is ONE compilation."""
        with engine.trace_counter() as tc:
            cmps = engine.compare_workloads(small_zoo())
            assert tc.count == 1
        assert len(cmps) == len(workloads.FAMILIES)
        for c in cmps:
            assert c.broadcast.total_tokens_mean > 0
            assert c.coherent.total_tokens_mean > 0

    def test_rerun_with_new_rates_does_not_retrace(self):
        """Rate matrices are traced: same static shape + workload
        count, arbitrarily different families/skews -> zero retraces."""
        with engine.trace_counter() as tc:
            engine.compare_workloads(small_zoo())
            n0 = tc.count
            perturbed = small_zoo(families=("zipf",) * len(
                workloads.FAMILIES))
            engine.compare_workloads(perturbed)
            assert tc.count == n0 == 1

    def test_mixed_static_groups_compile_once_each(self):
        ws = small_zoo(families=("bursty", "zipf"))
        other = workloads.make("pipeline", **{**SMALL, "n_steps": 12})
        with engine.trace_counter() as tc:
            engine.compare_workloads(ws + [other])
            assert tc.count == 2

    def test_coherent_beats_broadcast_except_adversarial(self):
        """Structured workloads keep the paper's savings claim alive;
        the adversarial ping-pong intentionally erodes (but here, with
        spectators reading, does not fully destroy) it."""
        cmps = {c.scenario: c for c in engine.compare_workloads(
            small_zoo(n_steps=12))}
        for name, c in cmps.items():
            assert c.coherent.total_tokens_mean <= \
                c.broadcast.total_tokens_mean, name
        assert cmps["rag read-heavy"].savings_mean > \
            cmps["write ping-pong"].savings_mean

    def test_run_workload_matches_compare_cell(self):
        w = small_zoo()[0]
        single = engine.run_workload(w)
        cell = engine.compare_workloads([w])[0]
        assert single.stats.total_tokens_mean == \
            cell.coherent.total_tokens_mean


@pytest.mark.pallas
class TestHeterogeneousPallasRoute:
    @pytest.mark.parametrize("code", [acs.LAZY, acs.EAGER,
                                      acs.ACCESS_COUNT])
    def test_pallas_matches_scan_on_heterogeneous_rates(self, code):
        w = workloads.make("hierarchical", **SMALL).with_strategy(code)
        a = engine.run_workload(w, tick_backend="scan")
        b = engine.run_workload(w, tick_backend="pallas")
        np.testing.assert_array_equal(a.per_run_total_tokens,
                                      b.per_run_total_tokens)
        np.testing.assert_array_equal(a.per_run_chr, b.per_run_chr)
        for f in ("fetch_tokens_mean", "signal_tokens_mean",
                  "push_tokens_mean", "n_fetches_mean",
                  "n_reads_mean", "n_writes_mean"):
            assert getattr(a.stats, f) == getattr(b.stats, f), f

    def test_pallas_staleness_sentinel_on_het_route(self):
        w = workloads.make("zipf", **SMALL)
        b = engine.run_workload(w, tick_backend="pallas")
        assert b.stats.max_staleness_max == -1
        assert b.stats.max_consumed_staleness_max == -1
