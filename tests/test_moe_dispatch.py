"""MoE dispatch: slice-count invariance and drop semantics (the SSPerf
iteration-1 optimization must be a pure re-layout, not a math change)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.models import moe as moe_mod
from repro.models import transformer as tf


def setup(arch="olmoe-1b-7b", seed=0):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(seed)
    spec = tf.layer_specs(cfg)[0]
    p = tf.layer_init(key, cfg, spec, jnp.float32)["ffn"]
    return cfg, p


@given(n_slices=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 50))
@settings(max_examples=12, deadline=None)
def test_slice_count_invariance_without_drops(n_slices, seed):
    """With ample capacity, dispatch_slices is a pure re-layout."""
    cfg, p = setup(seed=seed % 3)
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 16, cfg.d_model),
                          jnp.float32)
    y_ref, aux_ref = moe_mod.moe_apply(p, cfg, x)
    cfg_n = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, dispatch_slices=n_slices))
    y, aux = moe_mod.moe_apply(p, cfg_n, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)


def test_non_divisible_slices_fall_back():
    cfg, p = setup()
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, cfg.d_model),
                          jnp.float32)  # 15 tokens, not divisible by 4
    cfg_n = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, dispatch_slices=4))
    y, _ = moe_mod.moe_apply(p, cfg_n, x)
    y_ref, _ = moe_mod.moe_apply(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6)


def test_capacity_drops_pass_through_residual():
    """Overflowed tokens contribute zero (residual passes them)."""
    cfg, p = setup()
    tight = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.05))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    y, _ = moe_mod.moe_apply(p, tight, x)
    assert bool(jnp.isfinite(y).all())
    # severely capacity-limited output is much smaller in norm than
    # the unconstrained one (most tokens dropped)
    y_full, _ = moe_mod.moe_apply(p, cfg, x)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))


def test_gradients_flow_through_sliced_dispatch():
    cfg, p = setup()
    cfg_n = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, dispatch_slices=4))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, cfg.d_model),
                          jnp.float32)

    def loss(p):
        y, aux = moe_mod.moe_apply(p, cfg_n, x)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert float(jnp.linalg.norm(g["expert_gate"])) > 0
