"""Observability tier: metrics registry, MESI perf counters, span
tracing and the metrics-conformance oracle leg.

Covers the load-bearing properties of ``repro.obs`` (see
tests/README.md "Observability tier"):

  * the registry is exact - counters are plain Python ints, label
    cells never alias, snapshots round-trip through JSON and the
    Prometheus rendering is parseable line-oriented text;
  * metrics conformance - replaying the captured ``ServiceTrace``
    through a fresh telemetry plane reproduces every replayable
    counter bit-identically, for the plain broker AND the K-shard
    plane, on every workload family; a white-box corruption of a
    single live counter cell makes the oracle go red;
  * span lifecycle under true concurrency - adversarial ping-pong
    clients produce request + decide spans whose Chrome-trace JSON
    round-trips with the documented schema;
  * the unified stats schema and its deprecation shim, trace schema
    v4 round-trips (v3 payloads load with defaults), the ``metrics``
    TCP verb, and the jit/warmup compile log.

Async tests run via ``asyncio.run`` inside plain pytest functions (no
pytest-asyncio dependency).
"""

from __future__ import annotations

import asyncio
import json
import warnings

import numpy as np
import pytest

from repro.obs import (MetricsConformanceError, MetricsRegistry,
                       SpanRecorder, check_metrics_conformance)
from repro.obs import runtime as obs_runtime
from repro.obs import stats as obs_stats
from repro.service import (BrokerConfig, CoherenceBroker, CoherenceConfig,
                           ServiceTrace, connect, drive_workload)
from repro.sim import workloads

pytestmark = pytest.mark.obs

FAMILIES = tuple(workloads.FAMILIES)


def _names(m: int) -> tuple:
    return tuple(f"artifact-{d}" for d in range(m))


def _config(n: int = 6, m: int = 4, tokens: int = 64, **kw) -> BrokerConfig:
    return BrokerConfig(n_agents=n, artifacts=_names(m),
                        artifact_tokens=tokens, **kw)


def _workload(family: str, n: int = 6, m: int = 4, tokens: int = 64,
              **kw):
    return workloads.make(family, n_agents=n, n_artifacts=m,
                          artifact_tokens=tokens, n_steps=8, **kw)


# ---------------------------------------------------------------------------
# Metrics registry.


def test_counter_exact_and_labeled():
    reg = MetricsRegistry()
    c = reg.counter("coh_test_total", "help text")
    c.inc(3, shard=0)
    c.inc(shard=0)
    c.inc(5, shard=1)
    assert reg.counter_value("coh_test_total", shard=0) == 4
    assert reg.counter_value("coh_test_total", shard=1) == 5
    assert reg.counter_total("coh_test_total") == 9
    assert isinstance(reg.counter_total("coh_test_total"), int)
    # label order must not mint a second cell
    c.inc(1, a=1, b=2)
    c.inc(1, b=2, a=1)
    assert reg.counter_value("coh_test_total", a=1, b=2) == 2
    # get-or-create returns the same object; a kind clash is an error
    assert reg.counter("coh_test_total") is c
    with pytest.raises(TypeError):
        reg.gauge("coh_test_total")


def test_histogram_window_and_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("coh_lat", window=8)
    for v in range(100):
        h.observe(float(v))
    cell = h.cell()
    assert cell.count == 100 and cell.sum == sum(range(100))
    assert len(cell.ring) == 8          # bounded memory
    assert cell.min == 0.0 and cell.max == 99.0
    assert cell.percentile(50) >= 92.0  # window keeps the newest values


def test_snapshot_and_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("coh_a_total", "a").inc(7, shard=0)
    reg.gauge("coh_g", "g").set(2.5)
    reg.histogram("coh_h", "h").observe(1.0)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["coh_a_total"]["values"][0]["value"] == 7
    assert snap["gauges"]["coh_g"]["values"][0]["value"] == 2.5
    assert snap["histograms"]["coh_h"]["values"][0]["count"] == 1
    prom = reg.to_prometheus()
    assert '# TYPE coh_a_total counter' in prom
    assert 'coh_a_total{shard="0"} 7' in prom
    for line in prom.splitlines():
        assert line.startswith("#") or len(line.rsplit(" ", 1)) == 2


def test_span_recorder_bounded():
    rec = SpanRecorder(capacity=4)
    for i in range(10):
        rec.add(f"s{i}", "request", ts_s=float(i), dur_s=0.1,
                pid=0, tid=i)
    assert rec.n_recorded == 10         # exact count survives eviction
    trace = rec.chrome_trace()
    assert len(trace["traceEvents"]) == 4
    ev = json.loads(rec.to_chrome_json())["traceEvents"][0]
    assert ev["ph"] == "X" and {"name", "cat", "ts", "dur", "pid",
                                "tid"} <= set(ev)


# ---------------------------------------------------------------------------
# Metrics conformance: live counters == trace replay, bit for bit.


def test_metrics_conformance_all_families_plain():
    async def run(family):
        w = _workload(family)
        async with CoherenceBroker(_config()) as broker:
            await drive_workload(broker, w, 8, seed=11)
            return check_metrics_conformance(broker, name=family)
    for family in FAMILIES:
        report = run_ = asyncio.run(run(family))
        assert report["bit_exact"], (family, run_)
        assert report["counters_compared"] >= 15
        assert report["histograms_compared"] == 2


@pytest.mark.sharded
def test_metrics_conformance_all_families_sharded():
    cfg = CoherenceConfig.make(6, _names(5), artifact_tokens=64,
                               shards=4, hosts=2)

    async def run(family):
        w = _workload(family, m=5)
        async with connect(cfg) as broker:
            await drive_workload(broker, w, 8, seed=11)
            return check_metrics_conformance(broker, name=family)
    for family in FAMILIES:
        report = asyncio.run(run(family))
        assert report["bit_exact"], (family, report)
        assert report["l1_fills_conserved"], (family, report)


def test_metrics_corruption_goes_red():
    """White-box: bump one live counter cell by one - the conformance
    oracle must refuse to call the registry bit-exact."""
    async def main():
        w = _workload("uniform" if "uniform" in FAMILIES else
                      FAMILIES[0])
        async with CoherenceBroker(_config()) as broker:
            await drive_workload(broker, w, 8, seed=3)
            broker.telemetry.registry.counter(
                "coh_fetch_tokens_total").inc(1, shard=0)
            with pytest.raises(MetricsConformanceError):
                check_metrics_conformance(broker)
    asyncio.run(main())


def test_conformance_requires_telemetry_and_capture():
    async def main():
        async with CoherenceBroker(_config(telemetry=False)) as broker:
            await broker.read(0, "artifact-0")
            assert broker.telemetry is None
            with pytest.raises(ValueError):
                check_metrics_conformance(broker)
    asyncio.run(main())


# ---------------------------------------------------------------------------
# MESI perf counters + spans under adversarial concurrency.


def test_pingpong_spans_and_detectors():
    """Two writers flip one artifact while readers hammer it: the
    ping-pong detector fires, every request gets a span, and the
    Chrome trace round-trips."""
    async def main():
        async with CoherenceBroker(_config(n=6, m=2)) as broker:
            for _ in range(6):
                await asyncio.gather(
                    broker.write(0, "artifact-0"),
                    broker.write(1, "artifact-0"),
                    *(broker.read(a, "artifact-0") for a in (2, 3, 4)))
            # sequential tail: agent 5's fill is invalidated by the
            # next write in a LATER batch, so the batch-granular
            # valid->I transition becomes observable
            await broker.read(5, "artifact-0")
            await broker.write(0, "artifact-0")
            return broker
    broker = asyncio.run(main())
    tel = broker.telemetry
    reg = tel.registry
    assert reg.counter_total("coh_pingpong_alternations_total") > 0
    assert reg.counter_total("coh_invalidation_events_total") > 0
    n_reqs = broker.ledger.n_reads + broker.ledger.n_writes
    trace = tel.chrome_trace()
    reqs = [e for e in trace["traceEvents"] if e["cat"] == "request"]
    decides = [e for e in trace["traceEvents"] if e["cat"] == "batch"]
    assert len(reqs) == n_reqs
    assert len(decides) == broker.n_batches
    for ev in reqs:
        assert ev["args"]["queue_s"] >= 0.0
        assert ev["args"]["decide_s"] >= 0.0
    json.loads(tel.spans.to_chrome_json())    # schema is valid JSON
    assert check_metrics_conformance(broker)["bit_exact"]


def test_staleness_counter_matches_versions():
    """Sequential requests are always served the authority head, so
    staleness-at-serve is exactly 0 for every read; one observation
    per served read either way."""
    async def main():
        async with CoherenceBroker(_config(n=3, m=1)) as broker:
            await broker.read(0, "artifact-0")
            for _ in range(3):
                await broker.write(1, "artifact-0")
            await broker.read(0, "artifact-0")
            return broker.telemetry.registry.histogram_totals(
                "coh_staleness_at_serve")
    totals = asyncio.run(main())
    (count, total), = totals.values()
    assert count == 2 and total == 0


# ---------------------------------------------------------------------------
# Unified stats schema + deprecation shim.


def test_stats_nested_schema():
    async def main():
        async with CoherenceBroker(_config()) as broker:
            await broker.read(0, "artifact-0")
            await broker.write(1, "artifact-0")
            return broker.stats()
    stats = asyncio.run(main())
    assert stats["schema_version"] == 1
    for section in ("topology", "decision", "ledger", "latency",
                    "telemetry", "mesi"):
        assert section in stats, section
    assert stats["decision"]["n_actions"] == 2
    assert stats["decision"]["n_batches"] == 2
    assert stats["ledger"]["n_reads"] == 1
    # the protocol's state plane is S/I-valued (writers retain S)
    assert stats["mesi"]["occupancy"]["S"] >= 1
    assert stats["mesi"]["occupancy"]["I"] >= 1
    assert stats["mesi"]["invalidation_events"] >= 1


def test_stats_legacy_aliases_warn_once():
    async def main():
        async with CoherenceBroker(_config()) as broker:
            await broker.read(0, "artifact-0")
            return broker.stats()
    stats = asyncio.run(main())
    obs_stats._warned.discard("n_actions")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert stats["n_actions"] == 1      # legacy flat alias
        assert stats["n_actions"] == 1      # second access: no new warn
        json.dumps(stats)                   # serialization never warns
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1


# ---------------------------------------------------------------------------
# Trace schema v4.


def test_trace_v4_round_trip_and_v3_defaults():
    async def main():
        async with CoherenceBroker(_config()) as broker:
            await asyncio.gather(*(
                broker.read(a, "artifact-1") for a in range(6)))
            await broker.write(0, "artifact-1")
            return broker.trace
    trace = asyncio.run(main())
    payload = json.loads(trace.to_json())
    assert payload["schema_version"] == 4
    assert payload["steps"][0]["batch_size"] == 6
    assert payload["steps"][0]["decide_s"] > 0.0
    back = ServiceTrace.from_json(trace.to_json())
    assert [s.decide_s for s in back.steps] == \
        [s.decide_s for s in trace.steps]
    rep = back.latency_report()
    assert rep["n_steps"] == 2 and rep["max_batch"] == 6
    assert rep["decide_s_total"] > 0.0
    # a v3 payload (no per-step decide fields) loads with defaults
    for step in payload["steps"]:
        del step["decide_s"], step["batch_size"]
    payload["schema_version"] = 3
    v3 = ServiceTrace.from_json(json.dumps(payload))
    assert v3.steps[0].decide_s == 0.0
    assert v3.steps[0].batch_size == -1
    assert v3.steps[0].size == 6            # falls back to len(agents)


# ---------------------------------------------------------------------------
# TCP frontend `metrics` verb + launcher --verify-metrics.


def test_tcp_metrics_verb():
    from repro.launch.service import serve_tcp

    async def rpc(reader, writer, obj):
        writer.write(json.dumps(obj).encode() + b"\n")
        await writer.drain()
        return json.loads(await reader.readline())

    async def main():
        async with CoherenceBroker(_config(n=4, m=2, tokens=16)) as broker:
            server = await serve_tcp(broker, "127.0.0.1", 0)
            port = server.sockets[0].getsockname()[1]
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", port)
            await rpc(reader, writer, {"op": "read", "agent": 0,
                                       "artifact": "artifact-0"})
            m = await rpc(reader, writer, {"op": "metrics"})
            assert m["ok"]
            assert "coh_fetch_tokens_total" in m["prometheus"]
            assert m["snapshot"]["counters"]["coh_reads_total"][
                "values"][0]["value"] == 1
            writer.close()
            server.close()
            await server.wait_closed()

    async def disabled():
        cfg = _config(n=4, m=2, tokens=16, telemetry=False)
        async with CoherenceBroker(cfg) as broker:
            server = await serve_tcp(broker, "127.0.0.1", 0)
            port = server.sockets[0].getsockname()[1]
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", port)
            m = await rpc(reader, writer, {"op": "metrics"})
            assert not m["ok"] and "telemetry" in m["error"]
            writer.close()
            server.close()
            await server.wait_closed()
    asyncio.run(main())
    asyncio.run(disabled())


def test_launch_verify_metrics_smoke():
    from repro.launch import service as launch_service
    summary = launch_service.main([
        "--family", "uniform", "--clients", "5", "--artifacts", "3",
        "--artifact-tokens", "32", "--rounds", "5", "--verify-metrics"])
    report = summary["metrics_conformance"]
    assert report["bit_exact"]
    assert report["counters_compared"] >= 15


# ---------------------------------------------------------------------------
# Compile/warmup instrumentation.


def test_compile_log_records_fresh_trace():
    before = obs_runtime.compile_count("scan")

    async def main():
        # a shape no other test uses -> guaranteed fresh jit trace
        cfg = _config(n=11, m=3, tokens=48)
        async with CoherenceBroker(cfg) as broker:
            await broker.read(0, "artifact-0")
    asyncio.run(main())
    assert obs_runtime.compile_count("scan") >= before + 1
    warm = [e for e in obs_runtime.compile_events()
            if e["kind"] == "warmup" and "agents=11" in e["label"]]
    assert warm and warm[-1]["dur_s"] > 0.0
