"""Chunk-granular content plane: geometry, kernel parity, byte-exact
oracle, fused-grid integration and the live chunked broker.

The load-bearing properties:

  * chunk geometry round-trips (split -> reassemble identity) and the
    content-addressed store's chunk index always reassembles to the
    canonical artifact;
  * ``chunk_tick_pallas`` == ``chunk_tick_ref`` == the production
    ``acs`` scan path, bit-for-bit, on random inputs (the kernel's
    conformance surface);
  * the byte-exact oracle (``oracle.check_content_trace``) closes the
    loop across scan / Pallas / real-payload-store / whole-artifact
    baseline on every workload family;
  * the fused engine runs a whole (family x locality x volatility)
    content grid as ONE compiled program per chunk size, Pallas route
    bit-identical to scan, and delta coherence strictly dominates
    whole-artifact lazy;
  * the live broker ships actual chunk deltas that clients patch into
    byte-exact copies, and its captured trace replays through the
    content oracle against the live wire ledger.
"""

import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.content.chunks import (BYTES_PER_TOKEN, ChunkStore,
                                  apply_delta, chunk_digest, chunk_sizes,
                                  n_chunks, reassemble, split_chunks)
from repro.core import acs
from repro.core.protocol import ArtifactStore
from repro.kernels.chunk_diff import chunk_tick_pallas, chunk_tick_ref
from repro.kernels.mesi_transition import mesi_tick_pallas
from repro.sim import engine, workloads
from repro.sim import oracle

pytestmark = pytest.mark.content


# ---------------------------------------------------------------------------
# Geometry + content-addressed store.


class TestChunkGeometry:
    @pytest.mark.parametrize("T,ct,C,last", [
        (4096, 512, 8, 512), (4096, 1000, 5, 96), (96, 16, 6, 16),
        (100, 40, 3, 20), (7, 8, 1, 7)])
    def test_sizes(self, T, ct, C, last):
        assert n_chunks(T, ct) == C
        sizes = chunk_sizes(T, ct)
        assert sizes.sum() == T and sizes[-1] == last
        assert (sizes[:-1] == ct).all()

    def test_split_reassemble_identity(self, rng):
        for _ in range(10):
            T = int(rng.integers(1, 200))
            ct = int(rng.integers(1, 64))
            content = rng.integers(0, 1000, T).tolist()
            chunks = split_chunks(content, ct)
            assert len(chunks) == n_chunks(T, ct)
            assert reassemble(chunks) == tuple(content)

    def test_apply_delta_patches(self):
        base = list(range(20))
        new = list(base)
        new[8:16] = [99] * 8
        delta = ((1, tuple(new[8:16])),)
        assert apply_delta(base, delta, 8) == tuple(new)

    def test_digest_is_content_address(self):
        assert chunk_digest([1, 2, 3]) == chunk_digest((1, 2, 3))
        assert chunk_digest([1, 2, 3]) != chunk_digest([1, 2, 4])

    def test_chunk_store(self):
        store = ArtifactStore()
        store.put("a", list(range(100)))
        cs = ChunkStore(store, 40)
        cs.register("a")
        assert cs.n_chunks_of("a") == 3
        assert cs.reassembled("a") == tuple(range(100))
        new = list(range(100))
        new[0] = 777
        mask = cs.put("a", new)
        np.testing.assert_array_equal(mask, [True, False, False])
        assert cs.reassembled("a") == tuple(new)
        assert tuple(store.get("a")) == tuple(new)
        # delta serves exactly the requested chunks
        delta = cs.delta("a", [0, 2])
        assert [i for i, _ in delta] == [0, 2]
        assert delta[0][1] == tuple(new[:40])
        # identical chunks are deduplicated by digest
        store2 = ArtifactStore()
        store2.put("x", [5] * 80)
        cs2 = ChunkStore(store2, 40)
        cs2.register("x")
        assert cs2.n_unique_chunks == 1

    def test_chunk_count_change_rejected(self):
        store = ArtifactStore()
        store.put("a", list(range(100)))
        cs = ChunkStore(store, 40)
        cs.register("a")
        with pytest.raises(ValueError, match="chunk count"):
            cs.put("a", list(range(140)))


# ---------------------------------------------------------------------------
# Kernel parity: pallas == ref == production scan bodies.


def _random_chunk_inputs(rng, B, n, m, C):
    cv = rng.integers(1, 6, (B, m, C)).astype(np.int32)
    cs = np.minimum(rng.integers(0, 6, (B, n, m, C)), cv[:, None]) \
        .astype(np.int32)
    dirty = (cv > 1).astype(np.int32)
    miss = rng.integers(0, 2, (B, n)).astype(np.int32)
    wact = (rng.integers(0, 2, (B, n)) & miss |
            rng.integers(0, 2, (B, n))).astype(np.int32)
    arts = rng.integers(0, m, (B, n)).astype(np.int32)
    wmask = rng.integers(0, 2, (B, n, C)).astype(np.int32)
    return cv, cs, dirty, miss, wact, arts, wmask


@pytest.mark.pallas
class TestChunkDiffKernel:
    @pytest.mark.parametrize("B,n,m,C", [(4, 3, 2, 5), (16, 4, 3, 4),
                                         (34, 2, 2, 7)])
    def test_matches_ref(self, B, n, m, C):
        rng = np.random.default_rng(B * 31 + C)
        inputs = _random_chunk_inputs(rng, B, n, m, C)
        T, ct = C * 16 - 3, 16   # ragged last chunk
        out_p = chunk_tick_pallas(
            *[jnp.asarray(x) for x in inputs], artifact_tokens=T,
            chunk_tokens=ct, block_sims=16, interpret=True)
        out_r = chunk_tick_ref(*inputs, artifact_tokens=T,
                               chunk_tokens=ct)
        for got, want, label in zip(out_p, out_r,
                                    ("cv", "cs", "dirty", "fetched",
                                     "counters")):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want), label)

    def test_matches_production_scan(self):
        """Kernel pair (mesi miss output -> chunk tick) == acs scan
        bodies over a multi-step episode."""
        cfg = acs.ACSConfig(n_agents=4, n_artifacts=3,
                            artifact_tokens=96, n_steps=1,
                            chunk_tokens=16, write_locality=0.3)
        n, m, C = 4, 3, acs.content_chunks(cfg)
        key = jax.random.PRNGKey(7)
        arrays, met = acs.init_arrays(cfg), acs.init_metrics()
        st = jnp.zeros((1, n, m), jnp.int32)
        ver = jnp.ones((1, m), jnp.int32)
        sy = jnp.zeros((1, n, m), jnp.int32)
        rd = jnp.zeros((1, n, m), jnp.int32)
        cv = jnp.ones((1, m, C), jnp.int32)
        cs = jnp.zeros((1, n, m, C), jnp.int32)
        dirty = jnp.zeros((1, m, C), jnp.int32)
        tot = np.zeros(4, np.int64)
        for step in range(8):
            k = jax.random.fold_in(key, step)
            a, d, w = acs.draw_actions(k, n, m, 0.5, 0.9)
            wch = acs.draw_write_chunks(k, n, C, 0.3)
            arrays, met, out = acs.apply_actions(
                cfg, arrays, met, a, d, w, write_chunks=wch)
            ai = jnp.asarray(a, jnp.int32)[None]
            wi = jnp.asarray(w, jnp.int32)[None]
            st, ver, sy, rd, _, miss = mesi_tick_pallas(
                st, ver, sy, rd, ai, d[None], wi,
                artifact_tokens=cfg.artifact_tokens, interpret=True)
            cv, cs, dirty, fetched, ccnt = chunk_tick_pallas(
                cv, cs, dirty, miss, ai * wi, d[None],
                jnp.asarray(wch, jnp.int32)[None],
                artifact_tokens=cfg.artifact_tokens,
                chunk_tokens=cfg.chunk_tokens, interpret=True)
            tot += np.asarray(ccnt[0], np.int64)
            np.testing.assert_array_equal(
                np.asarray(out.miss, np.int32), np.asarray(miss[0]))
            np.testing.assert_array_equal(
                np.asarray(out.fetched_chunks, np.int32),
                np.asarray(fetched[0]))
        np.testing.assert_array_equal(np.asarray(arrays.chunk_version),
                                      np.asarray(cv[0]))
        np.testing.assert_array_equal(np.asarray(arrays.chunk_sync),
                                      np.asarray(cs[0]))
        np.testing.assert_array_equal(np.asarray(arrays.chunk_dirty),
                                      np.asarray(dirty[0]))
        assert int(met.delta_bytes) == tot[0]
        assert int(met.full_bytes) == tot[1]
        assert int(met.n_chunks_fetched) == tot[2]


# ---------------------------------------------------------------------------
# Byte-exact oracle.

_SMALL = dict(n_agents=4, n_artifacts=3, n_runs=2, artifact_tokens=96,
              n_steps=8, chunk_tokens=16)


@pytest.mark.differential
class TestContentOracle:
    @pytest.mark.parametrize("family", sorted(workloads.FAMILIES))
    def test_families_byte_exact(self, family):
        w = workloads.make(family, **_SMALL)
        rep = oracle.content_differential_check(w)
        assert rep.ledger.delta_bytes <= rep.ledger.full_bytes
        assert "chunk_store" in rep.implementations
        assert "run_episode" in rep.implementations

    def test_access_count_strategy(self):
        w = workloads.make("zipf", strategy=acs.ACCESS_COUNT,
                           access_k=2, **_SMALL)
        rep = oracle.content_differential_check(w)
        assert rep.ledger.n_chunks_fetched > 0

    def test_ragged_chunks(self):
        # 96 tokens / 40-token chunks -> sizes (40, 40, 16)
        w = workloads.make("pipeline", **{**_SMALL,
                                          "chunk_tokens": 40})
        rep = oracle.content_differential_check(w)
        assert rep.chunk_version.shape[-1] == 3

    def test_strict_dominance_with_writes(self):
        """Any workload that re-fetches after a partial-span write
        ships strictly fewer bytes than whole-artifact lazy."""
        w = workloads.make("ping_pong", **_SMALL).with_locality(0.2)
        rep = oracle.content_differential_check(w)
        assert rep.ledger.delta_bytes < rep.ledger.full_bytes

    def test_full_locality_collapses_to_whole_artifact(self):
        """write_locality=1.0 dirties every chunk, so delta == full on
        every fill: the content plane degrades exactly to the paper's
        whole-artifact cost model."""
        w = workloads.make("ping_pong", **_SMALL).with_locality(1.0)
        rep = oracle.content_differential_check(w)
        assert rep.ledger.delta_bytes == rep.ledger.full_bytes

    def test_detects_corrupted_byte_ledger(self):
        """Sensitivity: a perturbed write span must break the
        conformance (the harness is not vacuous)."""
        w = workloads.make("bursty", **_SMALL)
        key = oracle.episode_key(w.seed, 0)
        trace = oracle.sample_trace(w.acs, key, w.rates(),
                                    locality=w.write_locality)
        writes = trace.acts & trace.writes
        if not writes.any():
            pytest.skip("no writes sampled")
        # complement every write span: any post-write re-fetch now
        # ships a different chunk set
        wc = trace.write_chunks.copy()
        wc[writes] = ~wc[writes]
        bad = dataclasses.replace(trace, write_chunks=wc)
        met = acs.run_episode(w.acs, key, rates=w.rates(),
                              locality=w.write_locality)
        rep = oracle.check_content_trace(w.acs, bad, name="perturbed")
        # the internally-consistent replay of the PERTURBED trace must
        # disagree with the engine's ledger for the true trace
        assert (rep.ledger.delta_bytes != int(met.delta_bytes)
                or rep.ledger.n_chunks_fetched
                != int(met.n_chunks_fetched))

    def test_rejects_unsupported_configs(self):
        w = workloads.make("zipf", strategy=acs.EAGER, **_SMALL)
        with pytest.raises(ValueError, match="content plane"):
            acs.init_arrays(w.acs)
        with pytest.raises(ValueError):
            oracle.check_content_trace(
                w.acs, oracle.Trace(
                    acts=np.zeros((8, 4), bool),
                    arts=np.zeros((8, 4), np.int32),
                    writes=np.zeros((8, 4), bool)))


# ---------------------------------------------------------------------------
# Fused engine integration.


class TestEngineContentGrid:
    def _zoo(self, **overrides):
        base = dict(chunk_tokens=24, n_steps=8, artifact_tokens=96)
        base.update(overrides)
        return [w.with_overrides(**base)
                for w in workloads.zoo(n_agents=4, n_artifacts=3,
                                       n_runs=2)]

    def test_one_compilation_and_dominance(self):
        zoo = self._zoo()
        with engine.trace_counter() as tc:
            cmps = engine.compare_workloads(zoo, tick_backend="scan")
            assert tc.count == 1
            engine.compare_workloads(zoo, tick_backend="scan")
            assert tc.count == 1, "steady-state rerun retraced"
        per_ep = (8 * 4 * 3 * (96 + acs.SIGNAL_TOKENS)
                  * BYTES_PER_TOKEN)
        for c in cmps:
            co, bc = c.coherent, c.broadcast
            assert 0 < co.delta_bytes_mean <= co.full_bytes_mean
            assert bc.delta_bytes_mean == per_ep  # analytic baseline
            assert co.full_bytes_mean < bc.delta_bytes_mean

    @pytest.mark.pallas
    def test_pallas_route_bit_identical(self):
        zoo = self._zoo()
        a = engine.compare_workloads(zoo, tick_backend="scan")
        b = engine.compare_workloads(zoo, tick_backend="pallas")
        for x, y in zip(a, b):
            assert (x.coherent.delta_bytes_mean
                    == y.coherent.delta_bytes_mean)
            assert (x.coherent.full_bytes_mean
                    == y.coherent.full_bytes_mean)
            assert (x.coherent.n_chunks_fetched_mean
                    == y.coherent.n_chunks_fetched_mean)
            assert (x.coherent.total_tokens_mean
                    == y.coherent.total_tokens_mean)

    def test_locality_and_volatility_are_traced(self):
        """Sweeping locality or volatility re-uses the compiled grid
        (they are traced operands, not baked constants) - and the
        results actually move."""
        zoo = self._zoo()
        with engine.trace_counter() as tc:
            lo = engine.compare_workloads(
                [w.with_locality(0.1) for w in zoo],
                tick_backend="scan")
            hi = engine.compare_workloads(
                [w.with_locality(1.0) for w in zoo],
                tick_backend="scan")
            assert tc.count == 1, "locality sweep must not retrace"
        for l, h in zip(lo, hi):
            assert (l.coherent.delta_bytes_mean
                    <= h.coherent.delta_bytes_mean)
        assert any(l.coherent.delta_bytes_mean
                   < h.coherent.delta_bytes_mean
                   for l, h in zip(lo, hi))

    def test_disabled_plane_reports_sentinels(self):
        w = workloads.make("zipf", n_agents=4, n_artifacts=3, n_runs=2,
                           artifact_tokens=96, n_steps=8)
        res = engine.run_workload(w, tick_backend="scan")
        assert res.stats.delta_bytes_mean == -1.0
        assert res.stats.full_bytes_mean == -1.0

    def test_token_ledger_unchanged_by_content_plane(self):
        """Enabling chunks must not move a single token counter - the
        content plane is a byte-accounting overlay, not a semantics
        change."""
        plain = workloads.make("bursty", n_agents=4, n_artifacts=3,
                               n_runs=2, artifact_tokens=96, n_steps=8)
        chunked = plain.with_overrides(chunk_tokens=16)
        a = engine.run_workload(plain, tick_backend="scan")
        b = engine.run_workload(chunked, tick_backend="scan")
        np.testing.assert_array_equal(a.per_run_total_tokens,
                                      b.per_run_total_tokens)


# ---------------------------------------------------------------------------
# Live chunked broker.


def _broker_config(backend="auto", chunk_tokens=24):
    from repro.service import BrokerConfig
    return BrokerConfig(
        n_agents=4, artifacts=("plan", "notes", "scratch"),
        artifact_tokens=96, strategy="lazy", backend=backend,
        chunk_tokens=chunk_tokens)


async def _scripted_session(cfg):
    """Deterministic client script exercising cold fills, span writes,
    delta re-fetches, hits and a no-op write."""
    from repro.service import CoherenceBroker, make_clients, verify_broker
    async with CoherenceBroker(cfg) as broker:
        clients = make_clients(broker)
        for c in clients:
            r = await c.read("plan")
            assert not r.hit and len(r.delta) == 4   # cold: all chunks
        new = list(broker.store.get("plan"))
        new[:24] = [111] * 24
        w = await clients[0].write("plan", new)
        assert w.dirty_chunks == (0,)
        r = await clients[1].read("plan")
        assert not r.hit and [i for i, _ in r.delta] == [0]
        assert r.delta[0][1] == tuple(new[:24])
        r2 = await clients[1].read("plan")
        assert r2.hit and r2.delta == () and r2.delta_bytes == 0
        w2 = await clients[2].write("notes",
                                    list(broker.store.get("notes")))
        assert w2.dirty_chunks == ()        # measured no-op
        # two writers of one artifact in one conceptual exchange
        newer = list(new)
        newer[48:72] = [222] * 24
        await clients[3].write("plan", newer)
        r3 = await clients[1].read("plan")
        assert not r3.hit
        verify_broker(broker)
        return dict(broker.wire), broker.stats()


@pytest.mark.service
class TestChunkedBroker:
    def test_scripted_session_scan(self):
        wire, stats = asyncio.run(_scripted_session(
            _broker_config(backend="scan")))
        assert 0 < wire["delta_bytes"] < wire["full_bytes"]
        assert stats["wire"]["bytes_savings_vs_full"] > 0

    @pytest.mark.pallas
    def test_scan_and_pallas_routes_agree(self):
        wire_s, _ = asyncio.run(_scripted_session(
            _broker_config(backend="scan")))
        wire_p, _ = asyncio.run(_scripted_session(
            _broker_config(backend="pallas")))
        assert wire_s == wire_p

    def test_client_mirror_catches_bad_delta(self):
        """White-box: a client patching a WRONG delta must raise - the
        mirror check is not vacuous."""
        from repro.service.broker import ReadResult
        from repro.service.client import CoherentClient, DeltaMismatch

        class _FakeBroker:
            config = _broker_config()

            async def read(self, agent, artifact):
                return ReadResult(tuple(range(96)), 1, False, 0.0,
                                  delta=((0, tuple(range(24)),),),
                                  delta_bytes=0)

        client = CoherentClient(_FakeBroker(), 0)
        client._mirror["plan"] = tuple([7] * 96)   # stale local copy
        with pytest.raises(DeltaMismatch):
            asyncio.run(client.read("plan"))

    def test_content_verify_catches_corruption(self):
        """White-box: corrupt the live chunk index after the run - the
        content leg of verify_broker must fire."""
        from repro.service import CoherenceBroker, make_clients
        from repro.service.trace import verify_broker_content

        async def run():
            async with CoherenceBroker(_broker_config()) as broker:
                clients = make_clients(broker)
                await clients[0].read("plan")
                new = list(broker.store.get("plan"))
                new[0] = 9
                await clients[1].write("plan", new)
                await clients[0].read("plan")
                # corrupt: silently drop a chunk version bump
                arrays = broker.decider.arrays
                broker.decider.arrays = arrays._replace(
                    chunk_version=arrays.chunk_version.at[0, 0].add(-1))
                with pytest.raises(oracle.ConformanceError):
                    verify_broker_content(broker)

        asyncio.run(run())

    def test_trace_json_roundtrip_with_chunks(self):
        from repro.service import CoherenceBroker, make_clients
        from repro.service.trace import ServiceTrace

        async def run():
            async with CoherenceBroker(_broker_config()) as broker:
                clients = make_clients(broker)
                await clients[0].read("plan")
                new = list(broker.store.get("plan"))
                new[30] = 5
                await clients[1].write("plan", new)
                return broker.trace

        trace = asyncio.run(run())
        back = ServiceTrace.from_json(trace.to_json())
        assert back == trace
        ot = back.to_oracle_trace()
        assert ot.write_chunks is not None
        assert ot.write_chunks.any()

    def test_rejects_eager_chunked_config(self):
        from repro.service import BrokerConfig
        with pytest.raises(ValueError, match="chunked broker"):
            BrokerConfig(n_agents=2, artifacts=("a",),
                         artifact_tokens=96, strategy="eager",
                         chunk_tokens=24)

    def test_rejects_chunked_k_staleness_config(self):
        # a chunked broker with K-staleness on could never be
        # oracle-verified (the content harness covers K=0 only), so it
        # must be unconstructible rather than silently unverifiable
        from repro.service import BrokerConfig
        with pytest.raises(ValueError, match="K-staleness"):
            BrokerConfig(n_agents=2, artifacts=("a",),
                         artifact_tokens=96, strategy="lazy",
                         chunk_tokens=24, max_stale_steps=2)


# ---------------------------------------------------------------------------
# Golden byte-ledger regression.


@pytest.mark.slow
def test_golden_content_ledgers(golden):
    """Exact byte ledgers for a fixed mini-grid; regenerate only via
    ``pytest --update-golden`` with the diff in review."""
    payload = {}
    for family in ("bursty", "ping_pong"):
        for ct in (16, 40):
            w = workloads.make(family, **{**_SMALL, "chunk_tokens": ct})
            rep = oracle.content_differential_check(w)
            payload[f"{family}/ct{ct}"] = {
                "delta_bytes": rep.ledger.delta_bytes,
                "full_bytes": rep.ledger.full_bytes,
                "n_chunks_fetched": rep.ledger.n_chunks_fetched,
                "n_fills": len(rep.fills),
            }
    golden("content", payload)
