"""Shared pytest fixtures.

NOTE: do NOT set XLA_FLAGS / host-device-count here - smoke tests and
benches must see the real single CPU device; only launch/dryrun.py sets
up the 512-device placeholder topology (and only when run as a script).
"""

import os

# Keep CPU compiles light and deterministic for the test suite.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20260305)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """The CPU LLVM execution engine allocates an mmap'd code region per
    compiled fragment; a full-suite run accumulates thousands of tiny
    eager/jit executables and eventually hits `LLVM compilation error:
    Cannot allocate memory`.  Dropping the compilation caches at module
    boundaries keeps the arena bounded."""
    yield
    import jax
    jax.clear_caches()
