"""Shared pytest fixtures.

NOTE: do NOT set XLA_FLAGS / host-device-count here - smoke tests and
benches must see the real single CPU device; only launch/dryrun.py sets
up the 512-device placeholder topology (and only when run as a script).
"""

import json
import os
import pathlib

# Keep CPU compiles light and deterministic for the test suite.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json with the current results "
             "instead of asserting against them")


@pytest.fixture
def golden(request):
    """Golden-fixture helper: ``golden(name, payload)`` asserts
    ``payload`` equals ``tests/golden/<name>.json`` exactly (after a
    JSON round-trip, so committed files are the single source of
    truth).  With ``--update-golden`` it rewrites the file instead -
    savings numbers can change only through a reviewed diff."""
    update = request.config.getoption("--update-golden")

    def check(name: str, payload: dict) -> None:
        path = GOLDEN_DIR / f"{name}.json"
        rendered = json.dumps(payload, indent=2, sort_keys=True,
                              default=float)
        if update:
            GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
            path.write_text(rendered + "\n")
            return
        if not path.exists():
            pytest.fail(
                f"golden file {path} missing - generate it with "
                f"pytest --update-golden and commit the result")
        stored = json.loads(path.read_text())
        current = json.loads(rendered)
        assert current == stored, (
            f"golden mismatch for {name!r}: results drifted from "
            f"{path}.  If the change is intentional, regenerate with "
            f"pytest --update-golden and include the diff in review.")

    return check


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20260305)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """The CPU LLVM execution engine allocates an mmap'd code region per
    compiled fragment; a full-suite run accumulates thousands of tiny
    eager/jit executables and eventually hits `LLVM compilation error:
    Cannot allocate memory`.  Dropping the compilation caches at module
    boundaries keeps the arena bounded."""
    yield
    import jax
    jax.clear_caches()
