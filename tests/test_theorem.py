"""Token Coherence Theorem: bounds, conditions, and simulation dominance."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import theorem


def test_broadcast_cost_worked_example():
    # paper SS4.3: n=5, S=50, m=3, |d|=4096 -> 3,072,000 tokens
    p = theorem.WorkloadParams.uniform(5, 50, 3, 4096, 0.0)
    assert theorem.broadcast_cost(p) == 3_072_000


def test_intro_worked_example():
    # paper SS1: 5 agents x 50 steps x one 8192-token artifact
    p = theorem.WorkloadParams.uniform(5, 50, 1, 8192, 0.0)
    assert theorem.broadcast_cost(p) == 2_048_000


def test_lower_bound_canonical_values():
    # paper SS4.5: n=4, S=40, V=0.05 -> 85%
    assert theorem.savings_lower_bound_uniform(4, 40, 0.05) == pytest.approx(0.85)
    # Table 1 scenario bounds: 85/80/65/40 %
    for v, lb in [(0.05, .85), (0.10, .80), (0.25, .65), (0.50, .40)]:
        assert theorem.savings_lower_bound_uniform(4, 40, v) == pytest.approx(lb)


def test_volatility_cliff_values():
    assert theorem.volatility_cliff(4, 40) == pytest.approx(0.9)
    assert theorem.volatility_cliff(5, 20) == pytest.approx(0.75)


def test_corollaries():
    # Corollary 1: W=0 -> bound = 1 - n/S = 90% for n=4, S=40
    assert theorem.max_savings_bound(4, 40) == pytest.approx(0.90)
    # Corollary 2: W >= S - n -> bound <= 0
    p = theorem.WorkloadParams.uniform(4, 40, 3, 4096, 0.9)  # W = 36 = S-n
    assert theorem.savings_lower_bound(p) <= 1e-9


@given(n=st.integers(2, 16), s=st.integers(5, 200),
       m=st.integers(1, 8), d=st.integers(64, 65536),
       v=st.floats(0.0, 1.0))
@settings(max_examples=200, deadline=None)
def test_bound_consistency_property(n, s, m, d, v):
    """Uniform closed form == general formula; coherence condition
    matches the sign of the bound (Theorem 1)."""
    p = theorem.WorkloadParams.uniform(n, s, m, d, v)
    general = theorem.savings_lower_bound(p)
    closed = theorem.savings_lower_bound_uniform(n, s, v)
    assert general == pytest.approx(closed, abs=1e-9)
    if theorem.coherence_condition(p):
        assert general > -1e-9


@given(n=st.integers(2, 16), s=st.integers(5, 200),
       v=st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_bound_monotone_in_volatility(n, s, v):
    """The lower bound decreases with V and the broadcast/coherent
    asymptotic separation holds: bound -> 1 - n/S as V -> 0."""
    lb = theorem.savings_lower_bound_uniform(n, s, v)
    lb0 = theorem.savings_lower_bound_uniform(n, s, 0.0)
    assert lb <= lb0 + 1e-12
    assert lb0 == pytest.approx(1 - n / s)


def test_prompt_cache_amplification_monotone():
    a_low = theorem.prompt_cache_amplification(0.05, 0.9)
    a_high = theorem.prompt_cache_amplification(0.5, 0.9)
    assert a_high["amplification"] > a_low["amplification"] > 1.0
    assert a_low["hit_rate_coherent"] == 1.0
