"""Golden-trace regression fixtures: per-scenario and per-workload
ledger totals pinned as JSON under ``tests/golden/``.

The simulator is deterministic (threefry PRNG, integer token
counters), so these compare **exactly**.  Any change to savings
numbers - a protocol tweak, a sampling reorder, an accounting fix -
must show up as a reviewed diff of the golden files, regenerated with::

    PYTHONPATH=src python -m pytest tests/test_golden_traces.py \
        --update-golden

Never silently drifting savings is the point: the paper's headline
claim (SS8.2) is a number.
"""

import pytest

from repro.core import acs
from repro.sim import SCENARIOS, compare_grid, engine, workloads

pytestmark = pytest.mark.slow

#: fixed golden grid for the workload zoo (small enough for CI, big
#: enough that every family's structure shows up in the totals).
ZOO_PARAMS = dict(n_agents=6, n_artifacts=4, n_runs=5,
                  artifact_tokens=1024, n_steps=30)


def _per_run(stats_result):
    return [int(x) for x in stats_result.per_run_total_tokens]


def test_scenario_ledgers_match_golden(golden):
    """Scenarios A-D (SS8.1): per-run broadcast/coherent token totals
    and the derived savings, bit-for-bit."""
    cmps = compare_grid(list(SCENARIOS.values()))
    payload = {}
    for key, cmp_ in zip(SCENARIOS, cmps):
        payload[key] = {
            "scenario": cmp_.scenario,
            "volatility": cmp_.volatility,
            "broadcast_total_mean": cmp_.broadcast.total_tokens_mean,
            "coherent_total_mean": cmp_.coherent.total_tokens_mean,
            "savings_mean": cmp_.savings_mean,
            "savings_std": cmp_.savings_std,
            "crr": cmp_.crr,
            "cache_hit_rate_mean": cmp_.chr_mean,
        }
    golden("scenarios", payload)


def test_workload_zoo_ledgers_match_golden(golden):
    """Every heterogeneous family: per-run totals for both variants,
    so a drift in either the baseline or the coherent path is caught
    (not just their ratio)."""
    zoo = workloads.zoo(**ZOO_PARAMS)
    payload = {"_grid": dict(ZOO_PARAMS)}
    for w in zoo:
        bc = engine.run_workload(w.with_strategy(acs.BROADCAST),
                                 tick_backend="scan")
        co = engine.run_workload(w, tick_backend="scan")
        # same savings definition as engine._comparison_of: per-run
        # coherent totals against the broadcast mean.
        savings = 1.0 - (co.per_run_total_tokens
                         / bc.stats.total_tokens_mean)
        payload[w.family] = {
            "name": w.name,
            "effective_volatility": w.effective_volatility(),
            "broadcast_per_run": _per_run(bc),
            "coherent_per_run": _per_run(co),
            "broadcast_total_mean": bc.stats.total_tokens_mean,
            "coherent_total_mean": co.stats.total_tokens_mean,
            "savings_mean": float(savings.mean()),
            "cache_hit_rate_mean": co.stats.cache_hit_rate_mean,
        }
    golden("workloads", payload)
