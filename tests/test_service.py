"""Coherence-service tier: the asyncio broker under true interleaving.

Covers the load-bearing properties of ``repro.service`` (see
tests/README.md "Service tier"):

  * invariant safety under concurrency - SWMR / monotonic versioning /
    bounded staleness checked live on every micro-batch, with many
    concurrent clients and adversarial ping-pong rates;
  * the live-service <-> conformance loop - captured ``ServiceTrace``s
    replay bit-exactly through the four-way differential oracle and
    match the live ledger / directory / versions;
  * scan vs Pallas decision backends produce identical ledgers;
  * adapters (framework shims), the sync portal, the TCP frontend and
    the example demo all run without any framework installed.

Async tests run via ``asyncio.run`` inside plain pytest functions (no
pytest-asyncio dependency).
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core.states import MESIState
from repro.service import (BrokerConfig, CoherenceBroker, CoherentClient,
                           CoherentTool, InvariantViolation, ServicePortal,
                           ServiceTrace, autogen_functions, crewai_tool,
                           drive_workload, langgraph_node, verify_broker)
from repro.service.batching import resolve_decide_backend
from repro.sim import workloads

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

pytestmark = pytest.mark.service


def _names(m: int) -> tuple:
    return tuple(f"artifact-{d}" for d in range(m))


def _config(n: int = 8, m: int = 4, tokens: int = 64, **kw) -> BrokerConfig:
    return BrokerConfig(n_agents=n, artifacts=_names(m),
                        artifact_tokens=tokens, **kw)


def _workload(family: str, n: int = 8, m: int = 4, tokens: int = 64,
              **kw):
    return workloads.make(family, n_agents=n, n_artifacts=m,
                          artifact_tokens=tokens, n_steps=10, **kw)


# ---------------------------------------------------------------------------
# Broker semantics.


def test_read_write_semantics():
    async def main():
        async with CoherenceBroker(_config()) as broker:
            r = await broker.read(0, "artifact-0")
            assert not r.hit and r.version == 1
            assert len(r.content) == 64
            r = await broker.read(0, "artifact-0")
            assert r.hit          # coherent copy: free
            w = await broker.write(1, "artifact-0",
                                   content=[7] * 64)
            assert w.version == 2
            r = await broker.read(0, "artifact-0")
            assert not r.hit and r.version == 2   # invalidated by peer
            assert r.content == (7,) * 64
            r = await broker.read(1, "artifact-0")
            assert r.hit          # the writer keeps a coherent copy (S)
        led = broker.ledger
        assert led.n_reads == 4 and led.n_writes == 1
        assert led.n_hits == 2 and led.n_fetches == 3
    asyncio.run(main())


def test_concurrent_requests_coalesce():
    """Concurrent distinct-agent requests land in one micro-batch; a
    same-agent duplicate spills to the next batch (one serialized slot
    per agent per pass)."""
    async def main():
        async with CoherenceBroker(_config()) as broker:
            await asyncio.gather(*(
                broker.read(a, "artifact-1") for a in range(8)))
            assert broker.n_batches == 1
            assert broker.trace.steps[0].agents == tuple(range(8))
            # two in-flight requests from one agent -> two batches
            await asyncio.gather(broker.read(3, "artifact-0"),
                                 broker.read(3, "artifact-2"))
            assert broker.n_batches == 3
    asyncio.run(main())


def test_rejects_bad_requests():
    async def main():
        async with CoherenceBroker(_config()) as broker:
            with pytest.raises(KeyError):
                await broker.read(0, "nope")
            with pytest.raises(ValueError):
                await broker.read(99, "artifact-0")
            with pytest.raises(ValueError):
                await broker.write(0, "artifact-0", content=[1, 2])
    asyncio.run(main())
    with pytest.raises(ValueError):
        BrokerConfig(n_agents=2, artifacts=("a",), strategy="broadcast")


# ---------------------------------------------------------------------------
# Invariant safety under concurrency.


def test_stress_concurrent_ping_pong_invariants():
    """Many clients, adversarial ping-pong write rates, jittered
    open-loop interleaving: per-batch invariant checks stay green and
    the captured trace replays bit-exactly through the oracle."""
    async def main():
        w = _workload("ping_pong", n=16, m=4)
        cfg = _config(n=16, m=4, check_invariants=True)
        async with CoherenceBroker(cfg) as broker:
            rep = await drive_workload(broker, w, n_rounds=12, seed=11,
                                       lockstep=False,
                                       think_time_s=0.002)
            assert rep.n_actions > 50
            assert broker.n_batches > 12   # interleaving split rounds
            # quiescent directory: no E/M persists, versions monotone
            assert (broker.directory_state < int(MESIState.E)).all()
            assert (broker.versions >= 1).all()
            report = verify_broker(broker, name="stress:ping_pong")
            assert set(report.implementations) >= {
                "protocol", "vectorized", "pallas", "model_check"}
        return broker
    asyncio.run(main())


def test_stress_bounded_staleness_enforced():
    """K-staleness enforcement on the live broker: the served-hit
    staleness metric never exceeds K (the per-batch invariant check
    raises otherwise)."""
    async def main():
        w = _workload("rag", n=12, m=4)
        cfg = _config(n=12, m=4, max_stale_steps=3, backend="scan")
        async with CoherenceBroker(cfg) as broker:
            await drive_workload(broker, w, n_rounds=15, seed=2,
                                 lockstep=False, think_time_s=0.001)
            consumed = int(broker.decider.metrics.max_consumed_staleness)
            assert consumed <= 3
    asyncio.run(main())


def test_invariant_checker_fires_on_corruption():
    """White-box: corrupt the directory (two M holders) and the next
    flush must raise InvariantViolation - proving the checks are armed,
    not decorative."""
    async def main():
        async with CoherenceBroker(_config()) as broker:
            await broker.read(0, "artifact-0")
            a = broker.decider.arrays
            broker.decider.arrays = a._replace(
                state=a.state.at[0:2, 0].set(int(MESIState.M)))
            with pytest.raises(InvariantViolation):
                await broker.read(1, "artifact-1")
    asyncio.run(main())


# ---------------------------------------------------------------------------
# The live-service <-> conformance loop.


@pytest.mark.differential
@pytest.mark.parametrize("strategy", ["lazy", "eager", "access_count"])
def test_oracle_replay_lockstep(strategy):
    async def main():
        w = _workload("hierarchical", n=8, m=4)
        cfg = _config(strategy=strategy, access_k=3)
        async with CoherenceBroker(cfg) as broker:
            await drive_workload(broker, w, n_rounds=10, seed=4)
            report = verify_broker(broker, name=f"lockstep:{strategy}")
            assert report.strategy == strategy
    asyncio.run(main())


@pytest.mark.differential
def test_trace_roundtrip_and_replay():
    """ServiceTrace JSON round-trips and the deserialized trace replays
    to the same ledger as the live broker charged."""
    async def main():
        w = _workload("pipeline", n=6, m=3)
        async with CoherenceBroker(_config(n=6, m=3)) as broker:
            await drive_workload(broker, w, n_rounds=8, seed=6)
            return broker
    broker = asyncio.run(main())
    trace = ServiceTrace.from_json(broker.trace.to_json())
    assert trace.n_actions == broker.trace.n_actions
    from repro.service.trace import replay_trace
    report = replay_trace(trace, name="roundtrip")
    assert report.ledger.fetch_tokens == broker.ledger.fetch_tokens
    assert report.ledger.n_hits == broker.ledger.n_hits


@pytest.mark.pallas
def test_pallas_backend_matches_scan():
    """Identical lockstep load through both decision routes: ledgers,
    directory, versions and traces must agree bit-for-bit (and both
    replay through the oracle)."""
    async def run(backend):
        w = _workload("bursty", n=8, m=4)
        cfg = _config(strategy="eager", backend=backend)
        async with CoherenceBroker(cfg) as broker:
            await drive_workload(broker, w, n_rounds=10, seed=9)
            verify_broker(broker, name=f"backend:{backend}")
            return broker

    b_scan = asyncio.run(run("scan"))
    b_pal = asyncio.run(run("pallas"))
    assert b_pal.decider.backend == "pallas"
    assert (dataclasses.astuple(b_scan.ledger)
            == dataclasses.astuple(b_pal.ledger))
    assert np.array_equal(b_scan.directory_state, b_pal.directory_state)
    assert np.array_equal(b_scan.versions, b_pal.versions)
    # identical decisions step for step (latencies are wall-clock and
    # excluded)
    for s1, s2 in zip(b_scan.trace.steps, b_pal.trace.steps):
        assert (s1.agents, s1.arts, s1.writes, s1.miss, s1.version) == \
               (s2.agents, s2.arts, s2.writes, s2.miss, s2.version)


def test_backend_resolution_guards():
    cfg = _config(max_stale_steps=2).acs_config()
    assert resolve_decide_backend(cfg, "auto") == "scan"
    with pytest.raises(ValueError):
        resolve_decide_backend(cfg, "pallas")


# ---------------------------------------------------------------------------
# Adapters + portal.


def test_adapters_over_one_portal():
    config = _config(n=4, m=3, tokens=32)
    with ServicePortal(config) as portal:
        # CrewAI-style sync tool
        tool = crewai_tool(portal.client(0))
        out = tool.run("write", "artifact-0", "hello coherence")
        assert "version 2" in out
        # the committed writer keeps a coherent (S) copy
        assert "coherent cache" in tool.run("read", "artifact-0")

        # AutoGen-style function map (sync flavor); first read from a
        # peer agent is a coherence fill
        schemas, fmap = autogen_functions(portal.client(1))
        assert {s["name"] for s in schemas} == {"read_artifact",
                                                "write_artifact"}
        assert "authority fetch" in fmap["read_artifact"]("artifact-0")
        assert "coherent cache" in fmap["read_artifact"]("artifact-0")
        assert "v2" in fmap["read_artifact"]("artifact-0")

        # LangGraph-style async node, driven on the portal loop
        node = langgraph_node(CoherentClient(portal.broker, 2),
                              reads=("artifact-0", "artifact-1"))
        update = portal.call(node({"artifact_updates":
                                   {"artifact-1": "notes v1"}}))
        assert update["artifact_versions"]["artifact-1"] == 2
        assert update["artifacts"]["artifact-0"][:5] == (104, 101, 108,
                                                         108, 111)
        # framework-neutral tool spec is OpenAI-function shaped
        spec = CoherentTool(portal.client(3)).spec
        assert spec["parameters"]["required"] == ["operation",
                                                  "artifact"]
        verify_broker(portal.broker, name="adapters")


def test_coherent_tool_async_guard():
    async def main():
        async with CoherenceBroker(_config(n=2, m=2, tokens=16)) as broker:
            tool = CoherentTool(CoherentClient(broker, 0))
            with pytest.raises(TypeError):
                tool("read", "artifact-0")     # sync call on async client
            res = await tool.acall("read", "artifact-0")
            assert res.version == 1 and not res.hit
    asyncio.run(main())


# ---------------------------------------------------------------------------
# TCP frontend + entry point + example.


def test_tcp_frontend_smoke():
    from repro.launch.service import serve_tcp

    async def main():
        async with CoherenceBroker(_config(n=4, m=2, tokens=16)) as broker:
            server = await serve_tcp(broker, "127.0.0.1", 0)
            port = server.sockets[0].getsockname()[1]
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", port)

            async def rpc(obj):
                writer.write(json.dumps(obj).encode() + b"\n")
                await writer.drain()
                return json.loads(await reader.readline())

            r = await rpc({"op": "read", "agent": 0,
                           "artifact": "artifact-0"})
            assert r["ok"] and r["version"] == 1 and not r["hit"]
            w = await rpc({"op": "write", "agent": 1,
                           "artifact": "artifact-0"})
            assert w["ok"] and w["version"] == 2
            r = await rpc({"op": "read", "agent": 0,
                           "artifact": "artifact-0"})
            assert r["version"] == 2 and not r["hit"]
            s = await rpc({"op": "stats"})
            assert s["stats"]["n_actions"] == 3
            bad = await rpc({"op": "read", "agent": 0,
                             "artifact": "nope"})
            assert not bad["ok"] and "unknown artifact" in bad["error"]
            writer.close()
            server.close()
            await server.wait_closed()
    asyncio.run(main())


def test_launch_cli_verify_smoke():
    from repro.launch import service as launch_service
    summary = launch_service.main([
        "--family", "uniform", "--clients", "6", "--artifacts", "3",
        "--artifact-tokens", "32", "--rounds", "6", "--verify"])
    assert summary["oracle"]["bit_exact"]
    assert summary["actions"] == summary["oracle"]["n_actions"]
    assert 0.0 <= summary["savings_vs_broadcast"] <= 1.0


@pytest.mark.slow
def test_example_demo_smoke():
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "examples" /
                             "coherent_service_demo.py"), "--smoke"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": str(REPO_ROOT / "src"),
             "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd=str(REPO_ROOT))
    assert proc.returncode == 0, proc.stderr
    assert "oracle replay: bit-exact" in proc.stdout


# ---------------------------------------------------------------------------
# Perf-gate plumbing for BENCH_service.json.


def _gate(argv):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_gate", REPO_ROOT / "scripts" / "bench_gate.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.main(argv)


@pytest.mark.slow
def test_bench_gate_service_replay_and_injection(capsys):
    assert _gate(["--replay-baseline"]) == 0
    assert _gate(["--replay-baseline",
                  "--inject-latency-regression", "4.0"]) == 1
    assert _gate(["--replay-baseline",
                  "--inject-savings-drift", "0.05"]) == 1
    out = capsys.readouterr().out
    assert "service.p99_ms" in out
    assert "service.savings" in out
