"""Per-architecture smoke tests (reduced same-family configs, CPU).

For every assigned arch: (1) one forward/train step with shape + NaN
checks, (2) gradient finiteness, (3) prefill+decode logits exactly match
the full forward pass - the property that makes coherence-gated KV reuse
safe (a cache fill must reproduce what a rebroadcast would compute).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, smoke_config, n_params_analytic
from repro.models import transformer as tf
from repro.models.common import norm_apply

ARCH_NAMES = list(ARCHS)


def make_batch(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[2], (b, cfg.vision.n_image_tokens, cfg.d_model),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[2], (b, s, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(20260716)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_no_nans(name, key):
    cfg = smoke_config(name)
    params = models.init_params(cfg, key)
    batch = make_batch(cfg, key)
    loss = jax.jit(lambda p, b: models.forward_train(p, cfg, b))(
        params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"
    # untrained loss should be near ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
        2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_gradients_finite(name, key):
    cfg = smoke_config(name)
    params = models.init_params(cfg, key)
    batch = make_batch(cfg, key, b=1, s=16)
    grads = jax.jit(jax.grad(
        lambda p: models.forward_train(p, cfg, batch)))(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat), f"{name}: non-finite grads"
    # at least the embedding must receive gradient signal
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_full_forward(name, key):
    """Serving-path equivalence: cache fill + decode == rebroadcast."""
    cfg = smoke_config(name)
    params = models.init_params(cfg, key)
    b, s = 2, 16
    batch = make_batch(cfg, key, b, s)
    tokens = batch["tokens"]
    ctx = batch.get("vision_embeds", batch.get("frames"))
    ctx_len = 0 if ctx is None else ctx.shape[1]

    x = tf._embed_tokens(params, cfg, tokens)
    context = tf.encode(params, cfg, ctx) if cfg.encoder_layers else ctx
    pos = jnp.arange(s)[None, :]
    xf, _, _ = tf._run_layers(params, cfg, x, positions=pos,
                              context=context)
    xf = norm_apply(params["final_norm"], xf, cfg.norm)
    ref_logits = tf._logits(params, cfg, xf)

    p_len = s - 4
    cache = models.init_cache(cfg, b, s, ctx_len=ctx_len)
    lg, cache = models.prefill(params, cfg, tokens[:, :p_len], cache,
                               context=ctx)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(ref_logits[:, p_len - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(p_len, s):
        lg, cache = models.decode_step(params, cfg, tokens[:, t:t + 1],
                                       cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(ref_logits[:, t]),
                                   rtol=2e-3, atol=2e-3)
    assert int(cache["length"][0]) == s


def test_layer_patterns():
    """Structural checks of the layer patterns the assignment implies."""
    specs = tf.layer_specs(ARCHS["jamba-1.5-large-398b"])
    assert sum(1 for sp in specs if sp.mixer == "attn") == 9  # 72/8
    assert sum(1 for sp in specs if sp.mixer == "mamba") == 63
    assert sum(1 for sp in specs if sp.moe) == 36            # every 2nd
    specs = tf.layer_specs(ARCHS["llama-3.2-vision-90b"])
    assert sum(1 for sp in specs if sp.mixer == "cross") == 20
    specs = tf.layer_specs(ARCHS["deepseek-v2-lite-16b"])
    assert not specs[0].moe and all(sp.moe for sp in specs[1:])
    prefix, period = tf.split_pattern(specs)
    assert (prefix, period) == (1, 1)


def test_param_counts_match_billed_sizes():
    """Analytic totals vs the assignment's billed sizes."""
    expected = {  # arch -> (billed label in B, tolerance)
        "command-r-35b": (35, 0.20),
        "gemma-2b": (2.5, 0.15),
        "qwen3-1.7b": (1.7, 0.10),
        "yi-9b": (9, 0.10),
        "olmoe-1b-7b": (7, 0.10),
        "deepseek-v2-lite-16b": (16, 0.10),
        "jamba-1.5-large-398b": (398, 0.05),
        "rwkv6-1.6b": (1.6, 0.10),
        "llama-3.2-vision-90b": (90, 0.10),
        "whisper-medium": (0.769, 0.10),
    }
    for name, (billed, tol) in expected.items():
        n = n_params_analytic(ARCHS[name]) / 1e9
        assert abs(n - billed) / billed < tol, (name, n, billed)
