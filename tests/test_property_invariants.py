"""Property-based tests (hypothesis) over the system's core invariants:
the ACS state machine preserves SWMR / monotonic versioning / validity
coherence / bounded staleness on arbitrary seeded episodes and
configurations - including fully random heterogeneous rate matrices
(``repro.sim.workloads.random_workload``)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import acs, invariants
from repro.core.theorem import savings_lower_bound_uniform
from repro.sim import workloads


#: jitted episode per distinct config (frozen dataclass -> hashable);
#: one compile per config instead of thousands of eager op compiles.
#: ``rates`` (heterogeneous rate matrices) is a *traced* argument of
#: the cached program, so arbitrarily many random workloads also share
#: one compilation per (shape, strategy).
_EPISODE_CACHE: dict = {}


def run_arrays(cfg: acs.ACSConfig, seed: int,
               rates: acs.RateMatrices | None = None):
    fn = _EPISODE_CACHE.get(cfg)
    if fn is None:
        def episode(key, rates):
            arrays = acs.init_arrays(cfg)
            met = acs.init_metrics()

            def body(carry, inp):
                arrays, met = carry
                step, k = inp
                arrays, met = acs.tick(cfg, arrays, met, k, step,
                                       rates=rates)
                return (arrays, met), (arrays.state, arrays.version)

            keys = jax.random.split(key, cfg.n_steps)
            steps = jnp.arange(cfg.n_steps, dtype=jnp.int32)
            (arrays, met), snaps = jax.lax.scan(
                body, (arrays, met), (steps, keys))
            return arrays, met, snaps

        fn = jax.jit(episode)
        _EPISODE_CACHE[cfg] = fn
    arrays, met, (states, versions) = fn(jax.random.PRNGKey(seed), rates)
    snapshots = list(zip(np.asarray(states), np.asarray(versions)))
    return arrays, met, snapshots


# NOTE: shapes are drawn from a small fixed set - every distinct (n, m)
# is a fresh XLA compilation, and unbounded shape diversity exhausts the
# CPU LLVM code arena over a full-suite run (see conftest).
@given(n=st.sampled_from([2, 4]), m=st.sampled_from([1, 3]),
       v=st.floats(0.0, 1.0), seed=st.integers(0, 2**16),
       strategy=st.sampled_from([acs.LAZY, acs.EAGER, acs.ACCESS_COUNT,
                                 acs.TTL]))
@settings(max_examples=12, deadline=None)
def test_episode_preserves_invariants(n, m, v, seed, strategy):
    cfg = acs.ACSConfig(n_agents=n, n_artifacts=m, artifact_tokens=32,
                        n_steps=8, volatility=v, strategy=strategy)
    arrays, met, snaps = run_arrays(cfg, seed)
    prev_version = np.ones(m, np.int32)
    for state, version in snaps:
        assert invariants.single_writer(state)
        assert invariants.monotonic_version(prev_version, version)
        prev_version = version
    # validity coherence: every valid entry under a write-invalidate
    # strategy is at the canonical version
    if strategy in (acs.LAZY, acs.EAGER, acs.ACCESS_COUNT):
        state, version = snaps[-1]
        sync = np.asarray(arrays.last_sync)
        valid = state > 0
        assert (sync[valid] == np.broadcast_to(
            version, sync.shape)[valid]).all()


@given(n=st.sampled_from([3, 5]), v=st.floats(0.0, 0.5),
       seed=st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_savings_exceed_theorem_bound_property(n, v, seed):
    """Theorem 1 holds on arbitrary (n, V, seed) when S > n + W."""
    s = 30
    cfg = acs.ACSConfig(n_agents=n, n_artifacts=2, artifact_tokens=256,
                        n_steps=s, volatility=v, strategy=acs.LAZY)
    _, met, _ = run_arrays(cfg, seed)
    bcast = dataclasses.replace(cfg, strategy=acs.BROADCAST)
    _, met_b, _ = run_arrays(bcast, seed)
    savings = 1 - float(met.total_tokens) / float(met_b.total_tokens)
    lb = savings_lower_bound_uniform(n, s, v)
    # the analytic bound is per-artifact-W; the stochastic draw can
    # exceed V*S slightly, so allow the bound a small epsilon
    assert savings > lb - 0.12


@given(n=st.sampled_from([2, 4]), m=st.sampled_from([1, 3]),
       wl_seed=st.integers(0, 2**10), seed=st.integers(0, 2**16),
       strategy=st.sampled_from([acs.LAZY, acs.EAGER, acs.ACCESS_COUNT,
                                 acs.TTL]))
@settings(max_examples=12, deadline=None)
def test_random_rate_matrices_preserve_invariants(n, m, wl_seed, seed,
                                                  strategy):
    """SWMR + monotonic versioning + validity coherence hold for every
    randomly generated heterogeneous workload, every strategy."""
    w = workloads.random_workload(wl_seed, n_agents=n, n_artifacts=m,
                                  artifact_tokens=32, n_steps=8,
                                  strategy=strategy)
    arrays, met, snaps = run_arrays(w.acs, seed, rates=w.rates())
    prev_version = np.ones(m, np.int32)
    for state, version in snaps:
        assert invariants.single_writer(state)
        assert invariants.monotonic_version(prev_version, version)
        prev_version = version
    if strategy in (acs.LAZY, acs.EAGER, acs.ACCESS_COUNT):
        state, version = snaps[-1]
        sync = np.asarray(arrays.last_sync)
        valid = state > 0
        assert (sync[valid] == np.broadcast_to(
            version, sync.shape)[valid]).all()


@given(wl_seed=st.integers(0, 2**10), seed=st.integers(0, 2**16),
       k=st.sampled_from([1, 3]))
@settings(max_examples=10, deadline=None)
def test_bounded_staleness_holds_on_random_workloads(wl_seed, seed, k):
    """Invariant 3: with K-staleness enforcement on, no served cache
    hit carries staleness beyond K - on arbitrary rate matrices."""
    w = workloads.random_workload(wl_seed, n_agents=3, n_artifacts=2,
                                  artifact_tokens=32, n_steps=12,
                                  strategy=acs.LAZY, max_stale_steps=k)
    _, met, _ = run_arrays(w.acs, seed, rates=w.rates())
    assert int(met.max_consumed_staleness) <= k


def test_consumed_staleness_metric_is_not_vacuous():
    """Without enforcement a read-only workload drifts well past K=2;
    with enforcement the same workload is capped - so the bound above
    is doing real work."""
    cfg = acs.ACSConfig(n_agents=2, n_artifacts=1, artifact_tokens=16,
                        n_steps=20, p_act=1.0, volatility=0.0,
                        strategy=acs.LAZY)
    _, met0, _ = run_arrays(cfg, 0)
    assert int(met0.max_consumed_staleness) > 2
    _, met_k, _ = run_arrays(
        dataclasses.replace(cfg, max_stale_steps=2), 0)
    assert int(met_k.max_consumed_staleness) <= 2
    # the revalidation round-trips are priced (12 tokens each)
    assert int(met_k.signal_tokens) > int(met0.signal_tokens)


def test_zoo_families_preserve_invariants():
    """Every structured workload family preserves the invariants on a
    fixed seed (deterministic companion to the hypothesis sweeps)."""
    for w in workloads.zoo(n_agents=4, n_artifacts=3, n_runs=1,
                           artifact_tokens=32, n_steps=8):
        arrays, met, snaps = run_arrays(w.acs, w.seed, rates=w.rates())
        prev = np.ones(3, np.int32)
        for state, version in snaps:
            assert invariants.single_writer(state), w.name
            assert invariants.monotonic_version(prev, version), w.name
            prev = version


@given(seed=st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_coherent_never_exceeds_broadcast(seed):
    cfg = acs.ACSConfig(n_agents=4, n_artifacts=3, artifact_tokens=512,
                        n_steps=20, volatility=1.0, strategy=acs.LAZY)
    _, met, _ = run_arrays(cfg, seed)
    _, met_b, _ = run_arrays(
        dataclasses.replace(cfg, strategy=acs.BROADCAST), seed)
    assert float(met.total_tokens) <= float(met_b.total_tokens)


# ---------------------------------------------------------------------------
# Content plane (chunk-granular delta coherence, ``repro.content``).


@pytest.mark.content
@given(seed=st.integers(0, 2**16),
       n_tokens=st.integers(1, 200), chunk_tokens=st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_chunk_reassembly_identity(seed, n_tokens, chunk_tokens):
    """split -> reassemble is the identity for every geometry,
    ragged last chunk included."""
    from repro.content.chunks import (chunk_sizes, n_chunks, reassemble,
                                      split_chunks)
    rng_ = np.random.default_rng(seed)
    content = rng_.integers(0, 10000, n_tokens).tolist()
    chunks = split_chunks(content, chunk_tokens)
    sizes = chunk_sizes(n_tokens, chunk_tokens)
    assert len(chunks) == n_chunks(n_tokens, chunk_tokens)
    assert [len(c) for c in chunks] == sizes.tolist()
    assert reassemble(chunks) == tuple(content)


def _content_replay(wl_seed, seed, locality, chunk_tokens=16,
                    strategy=acs.LAZY):
    from repro.sim import oracle
    w = workloads.random_workload(
        wl_seed, n_agents=3, n_artifacts=2, artifact_tokens=48,
        n_steps=8, strategy=strategy,
        chunk_tokens=chunk_tokens).with_locality(locality)
    key = oracle.episode_key(seed, 0)
    trace = oracle.sample_trace(w.acs, key, w.rates(),
                                locality=w.write_locality)
    return w, trace, oracle.replay_content_vectorized(w.acs, trace)


@pytest.mark.content
@given(wl_seed=st.integers(0, 2**10), seed=st.integers(0, 2**16),
       locality=st.floats(0.05, 1.0))
@settings(max_examples=10, deadline=None)
def test_dirty_bitmap_monotone_and_delta_bounded(wl_seed, seed,
                                                 locality):
    """On arbitrary rate matrices: (1) the dirty bitmap only grows
    under writes, (2) every fill ships delta <= whole-artifact bytes,
    (3) total delta <= total full."""
    w, trace, (ledger, _, _, dirty_final, fills) = _content_replay(
        wl_seed, seed, locality)
    # dirty snapshots on one artifact, in serialization order, only grow
    last = {}
    for f in fills:
        prev = last.get(f.artifact)
        if prev is not None:
            assert (prev <= f.dirty).all(), "dirty bitmap shrank"
        last[f.artifact] = f.dirty
        assert f.delta_inc <= f.full_inc
    assert ledger.delta_bytes <= ledger.full_bytes
    # final bitmap dominates every snapshot seen on that artifact
    for f in fills:
        assert (f.dirty <= dirty_final[f.artifact].astype(bool)).all()


@pytest.mark.content
@given(wl_seed=st.integers(0, 2**10), seed=st.integers(0, 2**16),
       locality=st.floats(0.05, 0.9))
@settings(max_examples=10, deadline=None)
def test_delta_fetch_subset_of_dirty(wl_seed, seed, locality):
    """Invariant: a re-fetch (reader already synced once, so
    ``sync_before > 0`` everywhere) ships only chunks some write
    dirtied - the delta set is a subset of the dirty bitmap."""
    _, _, (_, _, _, _, fills) = _content_replay(wl_seed, seed, locality)
    for f in fills:
        if (f.sync_before > 0).all():      # not a cold fill
            fetched = np.asarray(f.fetched, bool)
            assert (fetched <= f.dirty).all(), (
                f"delta fetch shipped never-written chunks: "
                f"{fetched} vs dirty {f.dirty}")
