"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles.

Shape/dtype sweeps per kernel + hypothesis property tests for the MESI
tick kernel (which must agree with BOTH the numpy oracle and the
production ACS semantics).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core import acs
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mesi_transition import mesi_tick_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

TOLS = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestRMSNorm:
    @pytest.mark.parametrize("rows,d", [(8, 128), (128, 256), (33, 512),
                                        (1, 2048), (260, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, rows, d, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(rows * d))
        x = rand(k1, (rows, d), dtype)
        w = rand(k2, (d,), dtype)
        out = rmsnorm_pallas(x, w, interpret=True)
        expect = ref.rmsnorm_ref(x, w)
        assert out.dtype == x.dtype
        assert_allclose(np.asarray(out, np.float32),
                        np.asarray(expect, np.float32), **TOLS[dtype])

    def test_batched_shape(self):
        x = rand(jax.random.PRNGKey(0), (4, 16, 256), jnp.float32)
        w = jnp.ones((256,), jnp.float32)
        out = rmsnorm_pallas(x, w, interpret=True)
        assert out.shape == (4, 16, 256)
        assert_allclose(np.asarray(out), np.asarray(ref.rmsnorm_ref(x, w)),
                        rtol=1e-5, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,lq,lk,d", [
        (1, 4, 4, 128, 128, 64),     # MHA square
        (2, 8, 2, 128, 256, 64),     # GQA, decode-style suffix
        (1, 8, 1, 256, 256, 128),    # MQA
        (1, 4, 2, 384, 384, 128),    # multi-block q and k
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, b, hq, hkv, lq, lk, d, causal):
        keys = jax.random.split(jax.random.PRNGKey(42), 3)
        q = rand(keys[0], (b, hq, lq, d), jnp.float32)
        k = rand(keys[1], (b, hkv, lk, d), jnp.float32)
        v = rand(keys[2], (b, hkv, lk, d), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=causal,
                                     block_q=128, block_k=128,
                                     interpret=True)
        expect = ref.attention_ref(q, k, v, causal=causal)
        assert_allclose(np.asarray(out), np.asarray(expect),
                        rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.bfloat16])
    def test_bf16(self, dtype):
        keys = jax.random.split(jax.random.PRNGKey(7), 3)
        q = rand(keys[0], (1, 4, 128, 64), dtype)
        k = rand(keys[1], (1, 2, 128, 64), dtype)
        v = rand(keys[2], (1, 2, 128, 64), dtype)
        out = flash_attention_pallas(q, k, v, interpret=True)
        expect = ref.attention_ref(q, k, v)
        assert out.dtype == dtype
        assert_allclose(np.asarray(out, np.float32),
                        np.asarray(expect, np.float32), **TOLS[dtype])

    def test_block_shape_invariance(self):
        """Softmax statistics must be block-size independent."""
        keys = jax.random.split(jax.random.PRNGKey(3), 3)
        q = rand(keys[0], (1, 2, 256, 64), jnp.float32)
        k = rand(keys[1], (1, 2, 256, 64), jnp.float32)
        v = rand(keys[2], (1, 2, 256, 64), jnp.float32)
        a = flash_attention_pallas(q, k, v, block_q=128, block_k=64,
                                   interpret=True)
        b = flash_attention_pallas(q, k, v, block_q=256, block_k=256,
                                   interpret=True)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,hq,hkv,l,d", [
        (1, 8, 8, 256, 64),
        (2, 8, 2, 512, 64),
        (4, 16, 2, 1024, 128),
        (1, 8, 1, 256, 128),
    ])
    def test_matches_oracle_full_cache(self, b, hq, hkv, l, d):
        keys = jax.random.split(jax.random.PRNGKey(l), 3)
        q = rand(keys[0], (b, hq, d), jnp.float32)
        kc = rand(keys[1], (b, hkv, l, d), jnp.float32)
        vc = rand(keys[2], (b, hkv, l, d), jnp.float32)
        out = decode_attention_pallas(q, kc, vc, interpret=True)
        expect = ref.decode_attention_ref(q, kc, vc)
        assert_allclose(np.asarray(out), np.asarray(expect),
                        rtol=2e-4, atol=2e-4)

    def test_ragged_kv_lengths(self):
        """One compiled kernel serves any cache occupancy."""
        keys = jax.random.split(jax.random.PRNGKey(5), 3)
        b, hq, hkv, l, d = 4, 8, 2, 512, 64
        q = rand(keys[0], (b, hq, d), jnp.float32)
        kc = rand(keys[1], (b, hkv, l, d), jnp.float32)
        vc = rand(keys[2], (b, hkv, l, d), jnp.float32)
        kv_len = jnp.array([64, 200, 512, 1], jnp.int32)
        out = decode_attention_pallas(q, kc, vc, kv_len, interpret=True)
        expect = ref.decode_attention_ref(q, kc, vc, kv_len)
        assert_allclose(np.asarray(out), np.asarray(expect),
                        rtol=2e-4, atol=2e-4)

    def test_decode_consistent_with_prefill_last_row(self):
        """decode(q_last, cache) == last row of full flash attention."""
        keys = jax.random.split(jax.random.PRNGKey(9), 3)
        b, h, l, d = 1, 4, 256, 64
        q = rand(keys[0], (b, h, l, d), jnp.float32)
        k = rand(keys[1], (b, h, l, d), jnp.float32)
        v = rand(keys[2], (b, h, l, d), jnp.float32)
        full = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        dec = decode_attention_pallas(q[:, :, -1], k, v, interpret=True)
        assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1]),
                        rtol=2e-4, atol=2e-4)


def _random_tick_inputs(rng, B, n, m):
    state = rng.integers(0, 2, (B, n, m)).astype(np.int32)  # I or S
    version = rng.integers(1, 5, (B, m)).astype(np.int32)
    sync = np.where(state > 0, version[:, None, :], 0).astype(np.int32)
    reads = rng.integers(0, 3, (B, n, m)).astype(np.int32)
    acts = rng.integers(0, 2, (B, n)).astype(np.int32)
    arts = rng.integers(0, m, (B, n)).astype(np.int32)
    writes = rng.integers(0, 2, (B, n)).astype(np.int32)
    return state, version, sync, reads, acts, arts, writes


class TestMESITickKernel:
    @pytest.mark.parametrize("B,n,m", [(4, 4, 3), (16, 3, 2), (64, 8, 4),
                                       (130, 4, 3)])
    @pytest.mark.parametrize("eager,access_k", [(False, 0), (True, 0),
                                                (False, 3)])
    def test_matches_numpy_oracle(self, B, n, m, eager, access_k):
        rng = np.random.default_rng(B * n + m)
        inputs = _random_tick_inputs(rng, B, n, m)
        out = mesi_tick_pallas(*[jnp.asarray(x) for x in inputs],
                               artifact_tokens=4096, eager=eager,
                               access_k=access_k, block_sims=32,
                               interpret=True)
        exp_state, exp_ver, exp_sync, exp_reads, cnt = ref.mesi_tick_ref(
            *inputs, artifact_tokens=4096, eager=eager, access_k=access_k)
        np.testing.assert_array_equal(np.asarray(out[0]), exp_state)
        np.testing.assert_array_equal(np.asarray(out[1]), exp_ver)
        np.testing.assert_array_equal(np.asarray(out[2]), exp_sync)
        np.testing.assert_array_equal(np.asarray(out[3]), exp_reads)
        counters = np.asarray(out[4])
        np.testing.assert_array_equal(counters[:, 0], cnt["fetch_tokens"])
        np.testing.assert_array_equal(counters[:, 1], cnt["signal_tokens"])
        np.testing.assert_array_equal(counters[:, 2], cnt["push_tokens"])
        np.testing.assert_array_equal(counters[:, 3], cnt["n_fetches"])
        np.testing.assert_array_equal(counters[:, 4], cnt["n_hits"])

    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_matches_production_acs_tick(self, data):
        """Kernel semantics == repro.core.acs tick (lazy), the production
        state machine, on arbitrary action vectors."""
        n, m = 3, 2
        cfg = acs.ACSConfig(n_agents=n, n_artifacts=m, artifact_tokens=64,
                            n_steps=1, strategy=acs.LAZY)
        arrays = acs.init_arrays(cfg)
        met = acs.init_metrics()
        script = data.draw(st.lists(
            st.tuples(st.booleans(), st.integers(0, m - 1), st.booleans()),
            min_size=n, max_size=n))
        acts = np.array([int(s[0]) for s in script], np.int32)
        arts = np.array([s[1] for s in script], np.int32)
        writes = np.array([int(s[2]) for s in script], np.int32)
        # replay through acs eagerly
        for a, (act, d, w) in enumerate(script):
            if not act:
                continue
            arrays = arrays._replace(
                agent_actions=arrays.agent_actions.at[a].add(1))
            if w:
                arrays, met = acs._do_write(cfg, arrays, met, a, d)
            else:
                arrays, met = acs._do_read(cfg, arrays, met, a, d)
        out = mesi_tick_pallas(
            jnp.zeros((1, n, m), jnp.int32),
            jnp.ones((1, m), jnp.int32),
            jnp.zeros((1, n, m), jnp.int32),
            jnp.zeros((1, n, m), jnp.int32),
            jnp.asarray(acts)[None], jnp.asarray(arts)[None],
            jnp.asarray(writes)[None],
            artifact_tokens=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(out[0][0]),
                                      np.asarray(arrays.state))
        np.testing.assert_array_equal(np.asarray(out[1][0]),
                                      np.asarray(arrays.version))
        assert int(out[4][0, 0]) == int(met.fetch_tokens)
        assert int(out[4][0, 1]) == int(met.signal_tokens)
        assert int(out[4][0, 3]) == int(met.n_fetches)
        assert int(out[4][0, 4]) == int(met.n_hits)

    def test_swmr_preserved_by_kernel(self):
        rng = np.random.default_rng(0)
        inputs = _random_tick_inputs(rng, 64, 6, 4)
        out = mesi_tick_pallas(*[jnp.asarray(x) for x in inputs],
                               artifact_tokens=16, interpret=True)
        state = np.asarray(out[0])
        assert ((state == 3).sum(axis=1) <= 1).all()
