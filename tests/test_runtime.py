"""Runtime tests: sharding rules, fault-tolerant training loop,
coherent serving system."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, smoke_config
from repro.models import transformer as tf
from repro.runtime import sharding as shd
from repro.runtime import steps as step_factories
from repro.runtime.coherent_serving import (CoherentServingSystem,
                                            run_workload)
from repro.runtime.train_loop import TrainLoopConfig, run_training


class TestShardingRules:
    def test_param_specs_cover_all_archs(self):
        """Every parameter of every arch gets a spec; big matrices get
        a model-axis shard, norms stay replicated."""
        key = jax.random.PRNGKey(0)
        for name in ARCHS:
            cfg = smoke_config(name)
            shapes = jax.eval_shape(lambda k: tf.init_params(cfg, k), key)
            specs = shd.param_specs(shapes)
            flat_shapes = dict(shd._flatten_with_paths(shapes))
            flat_specs = dict(shd._flatten_with_paths(
                specs, ))
            for path, spec in flat_specs.items():
                assert isinstance(spec, P), path
                shape = flat_shapes[path].shape
                assert len(spec) <= len(shape), (path, spec, shape)

    def test_key_projections_are_tensor_parallel(self):
        assert shd.spec_for("/blocks/sub0/mixer/wq", 3) == \
            P(None, None, "model")
        assert shd.spec_for("/blocks/sub0/mixer/wo", 3) == \
            P(None, "model", None)
        assert shd.spec_for("/blocks/sub0/ffn/expert_gate", 4) == \
            P(None, "model", None, None)  # MoE experts: EP
        assert shd.spec_for("/embed", 2) == P("model", None)
        assert shd.spec_for("/blocks/sub0/norm1/scale", 2) == P()

    def test_zero_spec_adds_data_axis(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        base = P(None, "model")
        out = shd.zero_spec(base, (8, 4), mesh)
        assert out == P("data", "model")

    def test_batch_specs_microbatch_dim(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = shd.batch_specs(
            {"tokens": jax.ShapeDtypeStruct((4, 2, 8), jnp.int32)},
            mesh, batch_dim=1)
        assert spec["tokens"][0] is None


class TestTrainLoop:
    def test_loss_decreases_and_checkpoints(self, tmp_path):
        cfg = smoke_config("qwen3-1.7b")
        loop = TrainLoopConfig(total_steps=30, checkpoint_every=10)
        report = run_training(cfg, loop, tmp_path)
        assert report.steps_run == 30
        assert report.checkpoints == [10, 20, 30]
        # synthetic zipf stream is learnable: loss must drop
        assert report.losses[-1] < report.losses[0] - 0.5

    def test_crash_and_resume(self, tmp_path):
        """Fault tolerance: crash at step 25, restart resumes from the
        step-20 checkpoint and completes."""
        cfg = smoke_config("qwen3-1.7b")
        loop = TrainLoopConfig(total_steps=40, checkpoint_every=10)
        with pytest.raises(RuntimeError, match="injected crash"):
            run_training(cfg, loop, tmp_path, crash_at_step=25)
        report = run_training(cfg, loop, tmp_path)  # restart
        assert report.resumed_from == 20
        assert report.steps_run == 20
        assert report.final_step == 40

    def test_resume_reproduces_uninterrupted_run(self, tmp_path):
        """Elastic-restart contract: crash+resume losses == straight
        run losses (pure-function data stream + checkpointed state)."""
        cfg = smoke_config("rwkv6-1.6b")
        loop = TrainLoopConfig(total_steps=16, checkpoint_every=8)
        straight = run_training(cfg, loop, tmp_path / "a")
        with pytest.raises(RuntimeError):
            run_training(cfg, loop, tmp_path / "b", crash_at_step=12)
        resumed = run_training(cfg, loop, tmp_path / "b")
        np.testing.assert_allclose(straight.losses[8:], resumed.losses,
                                   rtol=1e-4)


class TestMicrobatching:
    def test_microbatched_grads_match_full_batch(self):
        """Gradient accumulation is exact (fp32 accumulators)."""
        cfg = smoke_config("gemma-2b")
        key = jax.random.PRNGKey(0)
        params = tf.init_params(cfg, key)
        batch = {
            "tokens": jax.random.randint(key, (4, 16), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(key, (4, 16), 0,
                                         cfg.vocab_size)}
        g_full = jax.grad(
            lambda p: step_factories.loss_fn(p, cfg, batch))(params)

        from repro.optim import adamw
        opt_cfg = adamw.AdamWConfig()
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        params_shape = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        opts = step_factories.StepOptions(n_microbatches=4, zero=False,
                                          donate=False)
        fn, _, _ = step_factories.make_train_step(
            cfg, opt_cfg, mesh, params_shape, shapes, opts)
        opt_state = adamw.init_state(opt_cfg, params)
        mb = step_factories.microbatch_split(batch, 4)
        new_params, _, metrics = fn(params, opt_state, mb)
        # compare the applied update direction against full-batch AdamW
        p2, _, m2 = step_factories.make_train_step(
            cfg, opt_cfg, mesh, params_shape, shapes,
            step_factories.StepOptions(n_microbatches=1, zero=False,
                                       donate=False))[0](
            params, adamw.init_state(opt_cfg, params), batch), None, None
        ref_params = p2[0]
        err = max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(new_params),
                            jax.tree.leaves(ref_params)))
        assert err < 5e-3  # same update up to fp32 accumulation order


class TestCoherentServing:
    def make(self, sorted_=False, strategy="lazy"):
        cfg = smoke_config("gemma-2b")
        return CoherentServingSystem(
            cfg, 4, {f"a{i}": [1] * 128 for i in range(3)},
            strategy=strategy, volatility_sorted=sorted_,
            n_active_params=1_000_000)

    def test_savings_vs_broadcast(self):
        system = self.make()
        stats = run_workload(system, 40, 0.10, seed=1)
        assert stats.token_savings > 0.5
        assert stats.flops_savings > 0.5
        assert stats.cache_hits > stats.fetches

    def test_volatility_sorted_suffix_never_worse(self):
        """The free suffix re-sort can only shrink recompute depth."""
        for seed in (1, 2, 3):
            base = run_workload(self.make(False), 40,
                                [0.5, 0.1, 0.02], seed=seed)
            srt = run_workload(self.make(True), 40,
                               [0.5, 0.1, 0.02], seed=seed)
            assert srt.prefill_tokens <= base.prefill_tokens + 1, seed

    def test_materialized_prefill_runs_backbone(self):
        from repro import models
        system = self.make()
        run_workload(system, 5, 0.1, seed=0)
        cfg = system.cfg
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        logits = system.materialize_prefill(params, 0)
        assert logits.shape[-1] == cfg.vocab_size
        assert bool(jnp.isfinite(logits).all())

    def test_swmr_holds_in_serving_system(self):
        from repro.core import invariants
        system = self.make()
        run_workload(system, 30, 0.3, seed=7)
        m = np.array([[int(ag.runtime.state_of(f"a{d}"))
                       for d in range(3)] for ag in system.agents])
        assert invariants.single_writer(m)
