"""Four-way differential conformance: protocol / vectorized / Pallas /
model-checker replay one sampled trace and must agree bit-for-bit.

These are the CI-runnable acceptance tests for the conformance harness
(``repro.sim.oracle``): heterogeneous workload families, every
invalidation strategy, multiple grid cells.  Small shapes keep the
replay legs (pure-Python protocol, eager JAX) fast; the *semantics*
under test are size-independent.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import acs
from repro.sim import oracle, workloads

pytestmark = pytest.mark.differential

SMALL = dict(n_agents=4, n_artifacts=3, n_runs=2,
             artifact_tokens=32, n_steps=10)

FOUR_WAY = ("protocol", "vectorized", "pallas", "model_check",
            "run_episode")


def small(family: str, **kw) -> workloads.Workload:
    params = dict(SMALL)
    params.update(kw)
    return workloads.make(family, **params)


class TestFourWayLazy:
    @pytest.mark.parametrize("family", sorted(workloads.FAMILIES))
    def test_all_families_agree(self, family):
        """Every workload family: all four implementations produce
        identical ledgers, MESI states, versions, and sync maps."""
        report = oracle.differential_check(small(family))
        assert report.implementations == FOUR_WAY
        assert report.trace.n_actions > 0
        # the ledger is internally consistent
        led = report.ledger
        assert led.n_hits + led.n_fetches == led.n_reads + led.n_writes
        assert led.signal_tokens >= (
            led.n_invalidation_signals * acs.SIGNAL_TOKENS)

    @pytest.mark.parametrize("run", [0, 1, 2])
    def test_multiple_grid_cells(self, run):
        """Each engine grid cell (fold_in run key) replays exactly."""
        report = oracle.differential_check(small("ping_pong"), run=run)
        assert report.implementations == FOUR_WAY

    def test_larger_fleet(self):
        report = oracle.differential_check(
            small("hierarchical", n_agents=6, n_artifacts=4, n_steps=8))
        assert report.implementations == FOUR_WAY


class TestThreeWayStrategies:
    """Eager and access-count: protocol / vectorized / Pallas (the spec
    has no push or expiry action, so the model-check leg is lazy-only).
    """

    @pytest.mark.parametrize("family", ["bursty", "zipf", "pipeline"])
    @pytest.mark.parametrize("code", [acs.EAGER, acs.ACCESS_COUNT])
    def test_strategies_agree(self, family, code):
        report = oracle.differential_check(small(family).with_strategy(code))
        assert "model_check" not in report.implementations
        assert {"protocol", "vectorized", "pallas"} <= set(
            report.implementations)

    def test_eager_actually_pushes(self):
        """Non-vacuity: the eager trace must contain push traffic, or
        the three-way push_tokens agreement proves nothing."""
        report = oracle.differential_check(
            small("ping_pong").with_strategy(acs.EAGER))
        assert report.ledger.push_tokens > 0


class TestHarnessSensitivity:
    """The harness must be able to *fail*: divergent semantics on the
    same trace produce different ledgers and raise."""

    def test_detects_strategy_divergence(self):
        w = small("zipf")
        trace = oracle.sample_trace(
            w.acs, oracle.episode_key(w.seed), w.rates())
        led_lazy, _, _, _ = oracle.replay_vectorized(w.acs, trace)
        eager_cfg = dataclasses.replace(w.acs, strategy=acs.EAGER)
        led_eager, _, _, _ = oracle.replay_vectorized(eager_cfg, trace)
        assert led_eager.push_tokens > led_lazy.push_tokens
        with pytest.raises(oracle.ConformanceError):
            oracle._expect("push_tokens", led_eager.push_tokens,
                           led_lazy.push_tokens, "sensitivity")

    def test_detects_state_divergence(self):
        with pytest.raises(oracle.ConformanceError):
            oracle._expect("state", np.zeros((2, 2), np.int32),
                           np.ones((2, 2), np.int32), "sensitivity")

    def test_rejects_out_of_scope_strategies(self):
        w = small("zipf").with_strategy(acs.TTL)
        with pytest.raises(ValueError, match="differential"):
            oracle.differential_check(w)
        w = small("zipf").with_overrides(max_stale_steps=2)
        with pytest.raises(ValueError, match="max_stale_steps"):
            oracle.differential_check(w)

    def test_model_leg_rejects_illegal_micro_action(self):
        """Enabled-ness checking is real: a hand-built trace whose
        first action is a write by an agent the model has in Invalid
        state must go through Fetch+Upgrade - skipping them (a
        corrupted decomposition) is rejected by the Next relation."""
        cfg = acs.ACSConfig(n_agents=2, n_artifacts=1,
                            artifact_tokens=8, n_steps=1)
        mc_cfg = oracle.mc.CheckConfig(
            n_agents=2, max_stale_steps=1 << 28,
            max_version=1 << 28, max_steps=1 << 28)
        init = (1, (oracle.mc.I, oracle.mc.I), (0, 0), (0, 0))
        enabled = dict(oracle.mc.successors(mc_cfg, init))
        assert "Write(0)" not in enabled      # I cannot write directly
        assert "Fetch(0)" in enabled
        # and the oracle's decomposition threads the legal path
        trace = oracle.Trace(
            acts=np.ones((1, 2), bool),
            arts=np.zeros((1, 2), np.int32),
            writes=np.array([[True, False]]),
        )
        state, version, sync = oracle.replay_model_check(cfg, trace)
        assert version[0] == 2                # the write committed
        assert state[0, 0] == int(oracle.MESIState.S)


class TestScenarioCompatibility:
    def test_scalar_scenarios_also_replay(self):
        """The harness accepts plain ScenarioConfig objects (scalar
        volatility) - the paper's canonical workloads are a degenerate
        workload family."""
        from repro.sim import canonical
        scn = canonical("diff-scalar", 0.3, 4242, n_steps=8,
                        artifact_tokens=16)
        report = oracle.differential_check(scn)
        assert report.implementations == FOUR_WAY
