"""Exhaustive model checking of CCS (paper SS6): invariants + mutant."""

import dataclasses

import pytest

from repro.core import model_check as mc


def test_invariants_hold_over_full_state_space():
    r = mc.check(mc.CheckConfig())
    assert r.ok, f"violation: {r.violation}"
    # same order as the paper's ~2,400 states for 3 agents
    assert 1_000 <= r.states_explored <= 10_000
    assert r.deadlocks == 0
    assert r.monotonic_ok


def test_invariants_hold_for_larger_spaces():
    r = mc.check(mc.CheckConfig(max_version=4, max_steps=5))
    assert r.ok and r.states_explored > 10_000
    assert r.deadlocks == 0


def test_invariants_hold_for_four_agents():
    # beyond the paper's own n=3 verification
    r = mc.check(mc.CheckConfig(n_agents=4, max_version=2, max_steps=2))
    assert r.ok
    assert r.deadlocks == 0


def test_broken_upgrade_violates_swmr():
    """SS6.3: removing invalidation is a correctness bug, not a perf knob."""
    r = mc.find_swmr_counterexample()
    assert r.violation is not None
    assert r.violation["invariant"] == "SingleWriter"
    # shortest trace: Upgrade(a), Write(a), Upgrade(b), Write(b)
    assert len(r.violation["trace"]) <= 5
    acts = [a.split("(")[0] for a in r.violation["trace"]]
    assert acts.count("Write") == 2 and acts.count("Upgrade") == 2


def test_staleness_bound_is_enforced_not_vacuous():
    """Reads are refused past the budget: with a tiny K, agents must
    re-sync; states with staleness > K are unreachable."""
    r = mc.check(mc.CheckConfig(max_stale_steps=1, max_steps=4,
                                max_version=2))
    assert r.ok
    # some reads are actually blocked: the K=1 space is smaller than K=3
    r3 = mc.check(mc.CheckConfig(max_stale_steps=3, max_steps=4,
                                 max_version=2))
    assert r.states_explored < r3.states_explored


def test_initial_state_matches_spec():
    cfg = mc.CheckConfig()
    version, states, steps, sync = mc.initial_state(cfg)
    assert version == 1
    assert all(s == mc.S for s in states)
    assert all(x == 0 for x in steps)
    assert all(x == 1 for x in sync)
