"""RWKV6 WKV recurrence kernel: oracle sweeps + consistency with the
production model recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ref
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


def make_inputs(key, b, t, h, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, h, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, h, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, h, dh), jnp.float32).astype(dtype)
    # decay in (0, 1), like exp(-exp(.)) in the model
    w = jax.nn.sigmoid(jax.random.normal(
        ks[3], (b, t, h, dh), jnp.float32)).astype(dtype)
    bonus = (jax.random.normal(ks[4], (h, dh), jnp.float32) * 0.1)
    return r, k, v, w, bonus


@pytest.mark.parametrize("b,t,h,dh", [
    (1, 16, 2, 16), (2, 64, 4, 32), (1, 128, 2, 64), (2, 32, 1, 8)])
def test_matches_oracle(b, t, h, dh):
    r, k, v, w, bonus = make_inputs(jax.random.PRNGKey(t + dh), b, t, h, dh)
    y, s = rwkv6_scan_pallas(r, k, v, w, bonus, chunk=16, interpret=True)
    y_ref, s_ref = ref.rwkv6_scan_ref(r, k, v, w, bonus)
    assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5,
                    atol=1e-5)
    assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5,
                    atol=1e-5)


def test_chunk_invariance_and_state_carry():
    """Different chunk sizes and a split run (carrying the state across
    two calls) must agree - the streaming-serving contract."""
    b, t, h, dh = 2, 64, 2, 16
    r, k, v, w, bonus = make_inputs(jax.random.PRNGKey(0), b, t, h, dh)
    y1, s1 = rwkv6_scan_pallas(r, k, v, w, bonus, chunk=8, interpret=True)
    y2, s2 = rwkv6_scan_pallas(r, k, v, w, bonus, chunk=64,
                               interpret=True)
    assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    # split at t/2 with explicit state carry
    half = t // 2
    ya, sa = rwkv6_scan_pallas(r[:, :half], k[:, :half], v[:, :half],
                               w[:, :half], bonus, chunk=8,
                               interpret=True)
    yb, sb = rwkv6_scan_pallas(r[:, half:], k[:, half:], v[:, half:],
                               w[:, half:], bonus, initial_state=sa,
                               chunk=8, interpret=True)
    assert_allclose(np.asarray(jnp.concatenate([ya, yb], axis=1)),
                    np.asarray(y1), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(sb), np.asarray(s1), rtol=1e-5, atol=1e-5)


def test_matches_production_model_recurrence():
    """Kernel == repro.models.rwkv6._wkv_step composition (the exact
    math the rwkv6-1.6b config runs through lax.scan)."""
    from repro.models.rwkv6 import _wkv_step
    b, t, h, dh = 1, 12, 2, 8
    r, k, v, w, bonus = make_inputs(jax.random.PRNGKey(3), b, t, h, dh)
    state = jnp.zeros((b, h, dh, dh), jnp.float32)
    ys = []
    for i in range(t):
        state, y = _wkv_step(state, r[:, i], k[:, i], v[:, i], w[:, i],
                             bonus)
        ys.append(y)
    y_model = jnp.stack(ys, axis=1)
    y_kernel, s_kernel = rwkv6_scan_pallas(r, k, v, w, bonus, chunk=4,
                                           interpret=True)
    assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                    rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(s_kernel), np.asarray(state),
                    rtol=1e-5, atol=1e-5)


def test_bf16_inputs():
    b, t, h, dh = 1, 32, 2, 16
    r, k, v, w, bonus = make_inputs(jax.random.PRNGKey(5), b, t, h, dh,
                                    dtype=jnp.bfloat16)
    y, s = rwkv6_scan_pallas(r, k, v, w, bonus, chunk=8, interpret=True)
    y_ref, s_ref = ref.rwkv6_scan_ref(r, k, v, w, bonus)
    assert y.dtype == jnp.bfloat16
    assert_allclose(np.asarray(y, np.float32),
                    np.asarray(y_ref, np.float32), rtol=3e-2, atol=3e-2)
