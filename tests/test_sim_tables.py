"""Integration tests: the DES reproduces the paper's SS8 results.

Tolerances follow the paper's own reproducibility contract (SS11.1):
comparisons are relative (coherent vs broadcast) and expected within a
few percentage points of the archived values.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import acs
from repro.core.theorem import savings_lower_bound_uniform
from repro.sim import (SCENARIOS, compare, pointer_semantics_scenario,
                       run_scenario, step_scaling_scenario)


@pytest.fixture(scope="module")
def scenario_b_comparison():
    return compare(SCENARIOS["B"])


def test_broadcast_baseline_matches_formula(scenario_b_comparison):
    """T_broadcast = n*S*m*(|d| + envelope); deterministic."""
    bc = scenario_b_comparison.broadcast
    expected = 40 * 4 * 3 * (4096 + acs.SIGNAL_TOKENS)
    assert bc.total_tokens_mean == pytest.approx(expected)
    assert bc.total_tokens_std == 0.0
    # within 0.5% of the paper's 1,979.6K measured baseline
    assert bc.total_tokens_mean == pytest.approx(1_979_600, rel=0.005)


def test_scenario_b_savings_match_paper(scenario_b_comparison):
    """Paper Table 1: 92.3% +- 1.4 at V = 0.10."""
    c = scenario_b_comparison
    assert c.savings_mean == pytest.approx(0.923, abs=0.02)
    assert c.chr_mean == pytest.approx(0.668, abs=0.08)
    assert c.crr == pytest.approx(0.077, abs=0.02)


def test_savings_exceed_theorem_lower_bound(scenario_b_comparison):
    lb = savings_lower_bound_uniform(4, 40, 0.10)
    assert scenario_b_comparison.savings_mean > lb


def test_all_canonical_scenarios_beat_bounds_and_match_paper():
    paper = {"A": 0.950, "C": 0.883, "D": 0.842}
    bounds = {"A": 0.85, "C": 0.65, "D": 0.40}
    for key, target in paper.items():
        scn = dataclasses.replace(SCENARIOS[key], n_runs=5)
        c = compare(scn)
        assert c.savings_mean == pytest.approx(target, abs=0.025), key
        assert c.savings_mean > bounds[key], key


def test_ttl_is_deterministic_and_matches_paper_exactly():
    """Paper Table 2 signature: 589.8K +- 0 (sigma exactly zero)."""
    res = run_scenario(SCENARIOS["B"].with_strategy(acs.TTL))
    assert res.stats.total_tokens_std == 0.0
    assert res.stats.fetch_tokens_mean == 144 * 4096  # 12 sweeps x 12 pairs
    assert res.stats.total_tokens_mean == pytest.approx(589_800, rel=0.001)


def test_step_scaling_positive_savings_below_bound_validity():
    """Paper Table 5, S=5: formula bound < 0 yet savings ~ 85.8%."""
    scn = dataclasses.replace(step_scaling_scenario(5), n_runs=5)
    c = compare(scn)
    assert savings_lower_bound_uniform(4, 5, 0.4) < 0
    # paper observes 85.8%; our simulator lands ~78% (cold-start fills
    # amortize differently at tiny S) - strongly positive either way,
    # which is the claim under test.
    assert c.savings_mean > 0.70


def test_pointer_semantics_strategy_reversal():
    """Paper SS8.8: eager beats lazy by an order of magnitude on the
    synchronous critical path under pointer semantics."""
    scn = dataclasses.replace(pointer_semantics_scenario(), n_runs=5)
    eager = run_scenario(scn.with_strategy(acs.EAGER)).stats
    lazy = run_scenario(scn.with_strategy(acs.LAZY)).stats
    assert lazy.sync_tokens_mean > 10 * eager.sync_tokens_mean
    assert eager.cache_hit_rate_mean > 0.95
    assert lazy.cache_hit_rate_mean < 0.60


def test_coherent_strategies_never_serve_stale_versions_but_ttl_does():
    """Lazy/eager invalidation means a *valid* entry is always at the
    canonical version (version lag 0).  TTL decouples freshness from
    writes (SS5.5), so reads may observe lagging content - exactly the
    staleness class Invariant 3 is designed to bound."""
    scn = dataclasses.replace(SCENARIOS["B"], n_runs=5)
    lazy = run_scenario(scn.with_strategy(acs.LAZY)).stats
    eager = run_scenario(scn.with_strategy(acs.EAGER)).stats
    ttl = run_scenario(scn.with_strategy(acs.TTL)).stats
    assert lazy.max_version_lag_max == 0
    assert eager.max_version_lag_max == 0
    assert ttl.max_version_lag_max > 0


def test_bounded_staleness_enforcement_costs_tokens_but_caps_staleness():
    scn = dataclasses.replace(SCENARIOS["B"], n_runs=5)
    free = run_scenario(scn).stats
    k = 3
    bounded = run_scenario(scn.with_overrides(max_stale_steps=k)).stats
    # enforcement adds validation signals
    assert bounded.signal_tokens_mean >= free.signal_tokens_mean
    assert bounded.total_tokens_mean >= free.total_tokens_mean


def test_same_seed_reproduces_exactly():
    a = run_scenario(SCENARIOS["A"]).per_run_total_tokens
    b = run_scenario(SCENARIOS["A"]).per_run_total_tokens
    assert (a == b).all()
